//! Prefix-cache subsystem guarantees (PR 10):
//!
//! 1. The per-instance radix tree is a deterministic value object:
//!    property-tested over random op sequences, a mid-sequence JSON
//!    roundtrip never changes future matches, inserts, or evictions.
//! 2. The `fig-cache` sweep is byte-identical across thread counts.
//! 3. Cache-disabled runs carry no cache bytes anywhere (the figures'
//!    JSONL artifacts are checked against pre-cache HEAD by CI's
//!    `cache-verify` job; here we pin the encoding-as-absence contract).
//! 4. An armed cache on a prefix-free workload is inert: identical
//!    report, counters, and TPS series, zero lookups.
//! 5. Armed-cache runs snapshot/kill/resume byte-identically, radix
//!    trees, LRU stamps, and cache counters included (schema v5).

use gyges::cache::{CacheCounters, PrefixTree};
use gyges::config::{Policy, PolicyId};
use gyges::coordinator::{ClusterSim, RunStatus, SimOutcome, SystemKind};
use gyges::experiments::cache::{cache_cfg, fig_cache_jobs, CACHE_QPS, CACHE_SEED};
use gyges::experiments::sweep::{results_to_jsonl, run_sweep_parallel, run_sweep_serial};
use gyges::experiments::{fig12_jobs, fig14_jobs};
use gyges::sim::SimTime;
use gyges::snapshot::state::SimSnapshot;
use gyges::util::{proptest, Prng};
use gyges::workload::{PrefixMix, ProductionStream, StreamSource};

/// Full observable state of one run, cache counters included.
fn sig(out: &SimOutcome) -> String {
    format!(
        "{}|{:?}|{:?}|{:?}|{:?}",
        out.report.to_json(),
        out.counters,
        out.recorder.tps_series(),
        out.cache,
        out.error
    )
}

/// One random op against a tree: a path over a tiny block alphabet so
/// shared prefixes (and LRU collisions under a small cap) are common.
fn random_op(rng: &mut Prng) -> (Vec<u64>, f64, u64) {
    let len = rng.gen_range(1, 6) as usize;
    let path: Vec<u64> = (0..len).map(|d| rng.gen_range(0, 4) + (d as u64) * 10).collect();
    let at = rng.f64() * 100.0;
    let cap = rng.gen_range(3, 12);
    (path, at, cap)
}

#[test]
fn prop_radix_roundtrip_mid_sequence_preserves_future_behaviour() {
    proptest::forall(
        "radix JSON roundtrip is behaviour-preserving",
        proptest::Config { cases: 32, seed: 0xCAC_4E7 },
        |rng: &mut Prng| (rng.next(), rng.gen_range(4, 40), rng.gen_range(0, 4)),
        |&(seed, ops, split)| {
            let mut rng = Prng::new(seed);
            let mut a = PrefixTree::new();
            // Warm the tree, then roundtrip it through its snapshot
            // codec at a random midpoint.
            for _ in 0..(ops / (split + 1)).max(1) {
                let (path, at, cap) = random_op(&mut rng);
                a.match_and_insert(&path, SimTime::from_secs_f64(at), cap);
                gyges::prop_assert!(a.len() <= cap, "cap violated: {} > {cap}", a.len());
            }
            let mut b = PrefixTree::from_json(&a.to_json())
                .map_err(|e| format!("roundtrip failed: {e}"))?;
            gyges::prop_assert!(
                a.fingerprint() == b.fingerprint(),
                "roundtrip changed the fingerprint (seed {seed:#x})"
            );
            // Identical ops on both sides must stay identical forever —
            // matches, evictions, and tie-breaking free-slot reuse.
            for _ in 0..ops {
                let (path, at, cap) = random_op(&mut rng);
                let t = SimTime::from_secs_f64(at);
                let oa = a.match_and_insert(&path, t, cap);
                let ob = b.match_and_insert(&path, t, cap);
                gyges::prop_assert!(
                    oa == ob && a.fingerprint() == b.fingerprint(),
                    "post-roundtrip divergence (seed {seed:#x}): {oa:?} vs {ob:?}"
                );
                gyges::prop_assert!(
                    a.match_len(&path) as usize <= path.len(),
                    "match_len exceeds path length"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn fig_cache_sweep_is_deterministic_across_thread_counts() {
    let jobs = fig_cache_jobs(45.0);
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    for workers in [2, 7] {
        let parallel = results_to_jsonl(&run_sweep_parallel(&jobs, workers));
        assert_eq!(serial, parallel, "fig-cache diverged at {workers} workers");
    }
    // Every armed row must serialize its cache block; the shared-prefix
    // stream guarantees lookups.
    assert!(serial.lines().all(|l| l.contains("\"cache\"")), "armed rows must carry cache");
}

#[test]
fn cache_disabled_figures_carry_no_cache_bytes() {
    // The paper figures never arm the cache: their sweep rows must not
    // contain a cache key anywhere (CI's cache-verify job additionally
    // cmp-checks the full artifacts against pre-cache HEAD bytes).
    use gyges::config::ModelConfig;
    let mut jobs = fig12_jobs(30.0, &[ModelConfig::qwen2_5_32b()]);
    jobs.extend(fig14_jobs(30.0, &[4.0]));
    let results = run_sweep_serial(&jobs);
    assert!(results.iter().all(|r| r.cache.is_none()), "figures must not arm the cache");
    let jsonl = results_to_jsonl(&results);
    assert!(!jsonl.contains("\"cache\""), "cache bytes leaked into a disabled run");
    assert!(!jsonl.contains("\"prefix\""), "prefix bytes leaked into a plain trace");
}

#[test]
fn armed_cache_is_inert_on_prefix_free_workloads() {
    // Arming the cache on a workload with no prefix paths must not move
    // a single byte of the report: observe() skips empty paths, so the
    // prefill model never sees a cached-token credit.
    let jobs = fig12_jobs(30.0, &[gyges::config::ModelConfig::qwen2_5_32b()]);
    let job = &jobs[2];
    assert_eq!(job.key, "qwen2.5-32b/gyges");
    let plain = gyges::experiments::sweep::build_job_sim(job).run();
    let mut armed_sim = gyges::experiments::sweep::build_job_sim(job);
    armed_sim.arm_cache();
    let armed = armed_sim.run();
    assert_eq!(armed.cache, Some(CacheCounters::default()), "no lookups on prefix-free work");
    // Compare everything except the armed-only counter block.
    let strip = |o: &SimOutcome| {
        format!("{}|{:?}|{:?}|{:?}", o.report.to_json(), o.counters, o.recorder.tps_series(), o.error)
    };
    assert_eq!(strip(&plain), strip(&armed), "armed-but-unused cache changed the run");
}

#[test]
fn armed_cache_snapshot_kill_resume_is_byte_identical() {
    // A cache-aware policy on the shared-prefix stream, checkpointed
    // every 5 s with a full JSON roundtrip at each pause: the resumed
    // run must reproduce the uninterrupted bytes, hit/miss counters and
    // per-instance radix trees included.
    let cfg = cache_cfg();
    let id = PolicyId { base: Policy::Gyges, cache: true, slo: false, admit: false };
    let spec = ProductionStream {
        seed: CACHE_SEED,
        qps: CACHE_QPS,
        segment_s: 15.0,
        horizon_s: 60.0,
        longs: None,
        slo: None,
        prefix: Some(PrefixMix::paper()),
    };
    let build = || {
        let source = StreamSource::new(spec.clone());
        ClusterSim::with_source(cfg.clone(), SystemKind::Gyges, Box::new(source)).with_policy(id)
    };
    let reference_out = build().run();
    let hits = reference_out.cache.expect("cache-aware policy arms the cache");
    assert!(hits.lookups > 0 && hits.hit_blocks > 0, "stream must exercise the cache: {hits:?}");
    let reference = sig(&reference_out);
    let mut sim = build();
    let mut saw_cache = false;
    let mut t = 5.0;
    while t < 600.0 {
        match sim.run_until(Some(SimTime::from_secs_f64(t))) {
            RunStatus::Done => break,
            RunStatus::Paused => {
                let snap = sim.snapshot().expect("paused run must snapshot");
                let text = snap.to_string_pretty();
                saw_cache |= text.contains("\"cache\"");
                let parsed = SimSnapshot::parse(&text).expect("snapshot must parse");
                assert_eq!(parsed, snap, "JSON roundtrip must be lossless");
                sim = ClusterSim::from_snapshot(cfg.clone(), &parsed).expect("restore");
            }
        }
        t += 5.0;
    }
    let _ = sim.run_until(None);
    assert!(saw_cache, "schema v5 must serialize the armed cache state");
    assert_eq!(sig(&sim.finish()), reference, "armed-cache resume diverged");
}
