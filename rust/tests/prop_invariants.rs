//! Property-based tests over the coordinator's core invariants:
//! routing, batching, page accounting, padding equivalence, plans.
//!
//! Uses the in-crate proptest-lite harness (seeded generation + replay
//! info on failure); case counts scale with GYGES_PROPTEST_CASES.

use gyges::config::{ClusterConfig, ModelConfig};
use gyges::coordinator::{
    make_policy, ActiveRequest, ClusterView, HostIndex, Instance, LoadIndex, Route,
    TransformState,
};
use gyges::kvcache::{KvLayout, KvManager};
use gyges::sim::{EngineModel, SimTime};
use gyges::transform::{Mechanism, TransformExec, TransformPlan};
use gyges::util::proptest::{forall, Config};
use gyges::util::Prng;
use gyges::weights::ffn::{ffn, gelu, pad_columns, pad_rows, Mat};
use gyges::weights::LayerPadPlan;

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

fn engine(c: &ClusterConfig) -> EngineModel {
    EngineModel::new(c.model.clone(), c.gpu.clone())
}

/// Build a random cluster state: mix of TP1/TP2/TP4 instances with random
/// load, one 8-GPU host.
fn random_instances(rng: &mut Prng, e: &EngineModel) -> Vec<Instance> {
    let mut out = Vec::new();
    let mut gpu = 0usize;
    let mut id = 0usize;
    while gpu < 8 {
        let degree = match rng.index(4) {
            0 if gpu + 4 <= 8 => 4u64,
            1 if gpu + 2 <= 8 => 2,
            _ => 1,
        };
        let workers: Vec<usize> = (gpu..gpu + degree as usize).collect();
        gpu += degree as usize;
        let mut inst = Instance::new(id, 0, workers, degree);
        // random resident requests within capacity
        let cap = inst.kv_capacity(e);
        let mut committed = 0u64;
        for r in 0..rng.index(6) {
            let len = 500 + rng.gen_range(0, e.max_seq(degree).max(600).min(40_000));
            if committed + len + 200 > cap {
                break;
            }
            committed += len + 200;
            let mut req = ActiveRequest::new((id * 100 + r) as u64, SimTime::ZERO, len, 200);
            req.phase = gyges::coordinator::Phase::Decode;
            inst.enqueue_running(req);
        }
        out.push(inst);
        id += 1;
    }
    out
}

/// INVARIANT: every policy's Assign choice can actually hold the request
/// (capacity + max-seq), and ScaleUp groups are disjoint TP1 instances on
/// one host with exactly `to_tp` members.
#[test]
fn prop_routing_decisions_are_sound() {
    let c = cfg();
    let e = engine(&c);
    for policy_kind in [
        gyges::config::Policy::Gyges,
        gyges::config::Policy::RoundRobin,
        gyges::config::Policy::LeastLoadFirst,
    ] {
        forall(
            &format!("routing-sound-{policy_kind:?}"),
            Config { cases: 200, seed: 0xA11C },
            |rng| {
                let instances = random_instances(rng, &e);
                let input = 100 + rng.gen_range(0, 60_000);
                (instances, input)
            },
            |(instances, input)| {
                let mut policy = make_policy(policy_kind);
                let req = ActiveRequest::new(9999, SimTime::ZERO, *input, 256);
                // The simulator always routes through the incremental
                // HostIndex + LoadIndex; a fresh policy over a scanning
                // view must make the same decision (index/scan
                // equivalence).
                let index = HostIndex::build(instances, 1);
                index.debug_verify(instances);
                let load = LoadIndex::build(instances, &e);
                load.debug_verify(instances, &e);
                let view = ClusterView {
                    instances,
                    engine: &e,
                    cfg: &c,
                    now: SimTime::from_secs_f64(1000.0),
                    tp1: Some(&index),
                    load: Some(&load),
                    blocked_hosts: None,
                    cache: None,
                };
                let scan_view = ClusterView {
                    instances,
                    engine: &e,
                    cfg: &c,
                    now: SimTime::from_secs_f64(1000.0),
                    tp1: None,
                    load: None,
                    blocked_hosts: None,
                    cache: None,
                };
                let mut scan_policy = make_policy(policy_kind);
                let indexed_route = policy.route(&req, &view);
                let scanned_route = scan_policy.route(&req, &scan_view);
                if indexed_route != scanned_route {
                    return Err(format!(
                        "index/scan divergence: {indexed_route:?} vs {scanned_route:?}"
                    ));
                }
                match indexed_route {
                    Route::Assign(id) => {
                        let inst = &instances[id];
                        if inst.retired {
                            return Err(format!("assigned to retired instance {id}"));
                        }
                        if !inst.fits(&e, &req) {
                            return Err(format!(
                                "assigned to instance {id} (tp{}) that cannot hold {} tokens",
                                inst.degree,
                                req.final_len()
                            ));
                        }
                        Ok(())
                    }
                    Route::ScaleUp { members, to_tp } => {
                        if members.len() != to_tp as usize {
                            return Err(format!("group size {} != to_tp {to_tp}", members.len()));
                        }
                        let mut seen = std::collections::BTreeSet::new();
                        let host = instances[members[0]].host;
                        for &m in members.iter() {
                            if !seen.insert(m) {
                                return Err("duplicate member".into());
                            }
                            let inst = &instances[m];
                            if inst.degree != 1 || inst.retired || inst.host != host {
                                return Err(format!("bad member {m}"));
                            }
                        }
                        // the merged degree must actually hold the request
                        if e.max_seq(to_tp) < req.final_len() {
                            return Err(format!(
                                "scale-up to tp{to_tp} still cannot hold {}",
                                req.final_len()
                            ));
                        }
                        Ok(())
                    }
                    Route::Defer => Ok(()),
                    // Drop / Preempt are decision-stage outcomes of the
                    // composed (-admit / -slo) policies; a plain policy
                    // must never emit them.
                    other => {
                        Err(format!("plain policy emitted a composed-stage decision: {other:?}"))
                    }
                }
            },
        );
    }
}

/// INVARIANT: a `LoadIndex` maintained incrementally through a long
/// random mutation sequence (admits, prefill completions, decode steps,
/// retirements, fresh spawns, transform toggles) always matches a
/// from-scratch rebuild, and indexed routing decisions stay identical to
/// the scanning fallback after every mutation.
#[test]
fn prop_load_index_survives_mutation_sequences() {
    let c = cfg();
    let e = engine(&c);
    let transform_state = || {
        let plan = TransformPlan::build(&c.model, 1, 2, 1);
        let exec = TransformExec::new(&c.model, &c.gpu, plan, 0.2, Mechanism::Gyges);
        TransformState { exec, blocked_until: None }
    };
    forall(
        "load-index-mutations",
        Config { cases: 40, seed: 0x10AD },
        |rng| {
            let ops: Vec<u64> = (0..60).map(|_| rng.next()).collect();
            ops
        },
        |ops| {
            let mut instances: Vec<Instance> =
                (0..8).map(|i| Instance::new(i, i / 4, vec![i], 1)).collect();
            let mut idx = LoadIndex::build(&instances, &e);
            let mut next_req = 1000u64;
            for &op in ops {
                let iid = (op % instances.len() as u64) as usize;
                let touched = match (op >> 8) % 6 {
                    0 => {
                        // admit a request (load grows)
                        if !instances[iid].retired {
                            let len = 500 + (op >> 16) % 2000;
                            let req = ActiveRequest::new(next_req, SimTime::ZERO, len, 50);
                            instances[iid].admit(req);
                            next_req += 1;
                        }
                        iid
                    }
                    1 => {
                        // prefill completion → decode or instant finish
                        let front = instances[iid].prefill_queue.front().map(|r| r.id);
                        if let Some(id) = front {
                            if let Some(r) = instances[iid].complete_prefill(id) {
                                if r.done() {
                                    let ctx = r.context_len();
                                    instances[iid].release_kv(ctx);
                                } else {
                                    instances[iid].enqueue_running(r);
                                }
                            }
                        }
                        iid
                    }
                    2 => {
                        // decode step (finishes shrink the load)
                        let (mut stepped, mut finished) = (Vec::new(), Vec::new());
                        instances[iid].decode_advance(4, &mut stepped, &mut finished);
                        iid
                    }
                    3 => {
                        // retire + drain, as a merge would
                        instances[iid].retired = true;
                        let _ = instances[iid].take_work();
                        iid
                    }
                    4 => {
                        // spawn fresh capacity, as a split would
                        let id = instances.len();
                        let degree = if (op >> 16) & 1 == 0 { 1 } else { 2 };
                        let host = (op >> 20) as usize % 2;
                        instances.push(Instance::new(id, host, vec![id], degree));
                        id
                    }
                    _ => {
                        // toggle transforming (bucket-neutral; filters only)
                        if instances[iid].transforming.is_some() {
                            instances[iid].transforming = None;
                        } else if !instances[iid].retired {
                            instances[iid].transforming = Some(transform_state());
                        }
                        iid
                    }
                };
                idx.note(&instances[touched], &e);
                idx.debug_verify(&instances, &e);

                let input = 100 + (op >> 24) % 60_000;
                let req = ActiveRequest::new(9_999_999, SimTime::ZERO, input, 128);
                let hidx = HostIndex::build(&instances, 2);
                let indexed = ClusterView {
                    instances: &instances,
                    engine: &e,
                    cfg: &c,
                    now: SimTime::from_secs_f64(50.0),
                    tp1: Some(&hidx),
                    load: Some(&idx),
                    blocked_hosts: None,
                    cache: None,
                };
                let scanning = ClusterView {
                    instances: &instances,
                    engine: &e,
                    cfg: &c,
                    now: SimTime::from_secs_f64(50.0),
                    tp1: None,
                    load: None,
                    blocked_hosts: None,
                    cache: None,
                };
                for pk in [gyges::config::Policy::Gyges, gyges::config::Policy::RoundRobin] {
                    let mut pi = make_policy(pk);
                    let mut ps = make_policy(pk);
                    let ri = pi.route(&req, &indexed);
                    let rs = ps.route(&req, &scanning);
                    if ri != rs {
                        return Err(format!(
                            "index/scan divergence after mutation {op:#x} ({pk:?}, {} tokens): {ri:?} vs {rs:?}",
                            req.final_len()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// INVARIANT: KV page accounting never leaks — allocated pages equal the
/// sum of live block tables, and finishing everything returns the pool to
/// empty.
#[test]
fn prop_kv_page_accounting_balances() {
    let model = ModelConfig::qwen2_5_32b();
    forall(
        "kv-page-accounting",
        Config { cases: 150, seed: 0x5ACC },
        |rng| {
            // random op sequence: (admit | append | finish)
            let ops: Vec<(u8, u64)> = (0..rng.index(60))
                .map(|_| (rng.index(3) as u8, 1 + rng.gen_range(0, 2000)))
                .collect();
            ops
        },
        |ops| {
            let mut mgr = KvManager::new(&model, 1, KvLayout::HeaderCentric, 2 * gyges::util::GIB);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for (op, arg) in ops {
                match op {
                    0 => {
                        if mgr.admit(next_id, *arg).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 => {
                        if let Some(&id) = live.first() {
                            let _ = mgr.append(id, *arg % 600 + 1);
                        }
                    }
                    _ => {
                        if let Some(id) = live.pop() {
                            mgr.finish(id).map_err(|e| format!("finish: {e}"))?;
                        }
                    }
                }
                let table_pages = mgr.tables.total_blocks();
                if table_pages != mgr.pool.allocated_pages() {
                    return Err(format!(
                        "leak: tables reference {table_pages} pages, pool says {}",
                        mgr.pool.allocated_pages()
                    ));
                }
            }
            for id in live.drain(..) {
                mgr.finish(id).map_err(|e| format!("final finish: {e}"))?;
            }
            if mgr.pool.allocated_pages() != 0 {
                return Err(format!("{} pages leaked", mgr.pool.allocated_pages()));
            }
            Ok(())
        },
    );
}

/// INVARIANT (Eq. 2): FFN′ == FFN for random shapes, shards and paddings.
#[test]
fn prop_padded_ffn_identity() {
    forall(
        "padded-ffn-identity",
        Config { cases: 120, seed: 0xFF17 },
        |rng| {
            let b = 1 + rng.index(4);
            let h = 2 + rng.index(12);
            let shards = [1usize, 2, 4][rng.index(3)];
            let shard_w = 1 + rng.index(8);
            let pads: Vec<usize> = (0..shards).map(|_| rng.index(5)).collect();
            let seed = rng.next();
            (b, h, shards, shard_w, pads, seed)
        },
        |(b, h, shards, shard_w, pads, seed)| {
            let mut rng = Prng::new(*seed);
            let i = shards * shard_w;
            let x = Mat::from_fn(*b, *h, |_, _| rng.normal());
            let up = Mat::from_fn(*h, i, |_, _| rng.normal());
            let down = Mat::from_fn(i, *h, |_, _| rng.normal());
            let up_p = pad_columns(&up, *shards, pads);
            let down_p = pad_rows(&down, *shards, pads);
            let raw = ffn(&x, &up, &down, gelu);
            let padded = ffn(&x, &up_p, &down_p, gelu);
            let err = raw.max_abs_diff(&padded);
            if err > 1e-10 {
                return Err(format!("identity violated: max err {err}"));
            }
            Ok(())
        },
    );
}

/// INVARIANT: padded shards are page-aligned and scale-up page release is
/// conserved (what one worker releases equals what the others would need
/// to receive on scale-down).
#[test]
fn prop_pad_plan_conservation() {
    let models = ModelConfig::all();
    forall(
        "pad-plan-conservation",
        Config { cases: 100, seed: 0x9AD },
        |rng| {
            let m = models[rng.index(models.len())].clone();
            let max_tp = [1u64, 2, 4][rng.index(3)];
            (m, max_tp)
        },
        |(m, max_tp)| {
            if m.inter_size % max_tp != 0 {
                return Ok(()); // not a valid TP degree for this model
            }
            let plan = LayerPadPlan::plan(m, *max_tp);
            for t in &plan.tensors {
                if t.padded_shard_bytes % gyges::util::VMM_PAGE != 0 {
                    return Err(format!("{:?} shard not page aligned", t.proj));
                }
            }
            if *max_tp > 1 {
                let released = plan.pages_released_per_worker(1, *max_tp) * gyges::util::VMM_PAGE;
                let received = plan.bytes_received_per_worker(*max_tp, 1);
                if released != received {
                    return Err(format!("release {released} != receive {received}"));
                }
            }
            let f = plan.overhead_fraction();
            if f < 0.0 {
                return Err(format!("negative overhead {f}"));
            }
            // The paper's <=14% bound holds for production-size tensors;
            // toy models (gyges-tiny) legitimately pad much more because
            // a shard is smaller than one 2 MiB page.
            let shard_bytes = m.up_proj_bytes() / max_tp;
            if shard_bytes >= 16 * 1024 * 1024 && f > 0.25 {
                return Err(format!("overhead {f} out of range for large shards"));
            }
            Ok(())
        },
    );
}

/// INVARIANT: transformation plans cover every layer exactly once per
/// module, in reversed order, for random stagger widths.
#[test]
fn prop_transform_plan_coverage() {
    let models = ModelConfig::all();
    forall(
        "transform-plan-coverage",
        Config { cases: 100, seed: 0x9147 },
        |rng| {
            let m = models[rng.index(models.len())].clone();
            let stagger = 1 + rng.index(8);
            let up = rng.chance(0.5);
            (m, stagger, up)
        },
        |(m, stagger, up)| {
            let (from, to) = if *up { (1, 4) } else { (4, 1) };
            let plan = TransformPlan::build(m, from, to, *stagger);
            let mut mlp = vec![0u32; m.num_layers as usize];
            let mut kv = vec![0u32; m.num_layers as usize];
            let mut last_layer = m.num_layers;
            for s in 0..plan.num_steps() {
                for op in plan.ops_for_step(s) {
                    match op.kind {
                        gyges::transform::OpKind::MlpWeights => mlp[op.layer as usize] += 1,
                        gyges::transform::OpKind::KvCache => kv[op.layer as usize] += 1,
                    }
                    if op.layer > last_layer {
                        return Err("traversal not descending".into());
                    }
                    last_layer = op.layer;
                }
            }
            if mlp.iter().any(|&c| c != 1) || kv.iter().any(|&c| c != 1) {
                return Err("layer transformed != exactly once".into());
            }
            Ok(())
        },
    );
}
