//! Determinism guarantees the experiment harness depends on:
//!
//! 1. Same trace + seed → identical `RunReport` (and identical per-request
//!    records) across repeated runs of the simulator.
//! 2. The parallel sweep driver's merged output is byte-identical to the
//!    serial driver's, for the Figure 12/13 experiment sets.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, ClusterSim, SystemKind};
use gyges::experiments::sweep::{
    results_to_jsonl, run_sweep_parallel, run_sweep_serial, SweepJob, SweepResult,
};
use gyges::experiments::{fig12_jobs, fig13_jobs};
use gyges::metrics::RequestRecord;
use gyges::workload::Trace;
use std::sync::Arc;

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

/// Full observable state of one run, for exact comparison.
fn snapshot(out: &gyges::coordinator::SimOutcome) -> (String, Vec<(u64, RequestRecord)>) {
    let records: Vec<(u64, RequestRecord)> =
        out.recorder.records().map(|(id, r)| (id, r.clone())).collect();
    (out.report.to_json().to_string(), records)
}

#[test]
fn repeated_runs_are_identical() {
    let trace = Trace::hybrid_paper(0xD0, 180.0);
    let first = run_system(cfg(), SystemKind::Gyges, None, trace.clone());
    let (report0, records0) = snapshot(&first);
    for _ in 0..2 {
        let again = run_system(cfg(), SystemKind::Gyges, None, trace.clone());
        let (report, records) = snapshot(&again);
        assert_eq!(report0, report, "RunReport must be identical run-to-run");
        assert_eq!(records0, records, "per-request records must be identical");
        assert_eq!(first.counters, again.counters, "counters must be identical");
    }
}

#[test]
fn repeated_runs_identical_across_systems() {
    let trace = Trace::production(0xD1, 3.0, 120.0);
    for sys in [SystemKind::Gyges, SystemKind::Seesaw, SystemKind::LoongServe] {
        let a = run_system(cfg(), sys, None, trace.clone());
        let b = run_system(cfg(), sys, None, trace.clone());
        assert_eq!(snapshot(&a), snapshot(&b), "{} diverged", sys.name());
    }
}

#[test]
fn parallel_sweep_matches_serial_fig12_set() {
    // One model at a short horizon keeps the test fast while exercising
    // the real Figure-12 job construction.
    let jobs = fig12_jobs(90.0, &[ModelConfig::qwen2_5_32b()]);
    assert_eq!(jobs.len(), 3);
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    let parallel = results_to_jsonl(&run_sweep_parallel(&jobs, 4));
    assert_eq!(serial, parallel, "fig12 sweep: parallel must merge byte-identically");
    // A second parallel run must not be affected by thread scheduling.
    let parallel2 = results_to_jsonl(&run_sweep_parallel(&jobs, 2));
    assert_eq!(serial, parallel2);
}

#[test]
fn parallel_sweep_matches_serial_fig13_set() {
    let jobs = fig13_jobs();
    assert_eq!(jobs.len(), 3);
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    let parallel = results_to_jsonl(&run_sweep_parallel(&jobs, 8));
    assert_eq!(serial, parallel, "fig13 sweep: parallel must merge byte-identically");
}

/// The incremental HostIndex/LoadIndex routing fast path must be a pure
/// optimisation: the full Figure-13 output (reports, per-second TPS
/// series, every counter) is byte-identical to the same simulator routing
/// through full instance-table scans.
#[test]
fn fig13_output_identical_with_and_without_routing_index() {
    let jobs = fig13_jobs();
    let indexed = results_to_jsonl(&run_sweep_serial(&jobs));
    let scanned: Vec<SweepResult> = jobs
        .iter()
        .map(|job| {
            let gyges::experiments::sweep::JobTrace::Full(trace) = &job.trace else {
                panic!("fig13 jobs are materialized")
            };
            let mut sim = ClusterSim::new(job.cfg.clone(), job.system, (**trace).clone());
            if let Some(p) = job.policy {
                sim = sim.with_policy(p);
            }
            sim.disable_routing_index();
            let out = sim.run();
            SweepResult {
                key: job.key.clone(),
                tps_series: out.recorder.tps_series(),
                report: out.report,
                counters: out.counters,
                error: out.error.map(|e| e.to_string()),
                cache: out.cache,
            }
        })
        .collect();
    assert_eq!(
        indexed,
        results_to_jsonl(&scanned),
        "indexed routing must be decision-identical to the scan baseline on fig13"
    );
}

#[test]
fn mixed_system_sweep_is_deterministic() {
    let trace = Arc::new(Trace::hybrid_paper(0xD2, 90.0));
    let jobs: Vec<SweepJob> = [
        (SystemKind::Gyges, Some(Policy::Gyges.into())),
        (SystemKind::Gyges, Some(Policy::RoundRobin.into())),
        (SystemKind::KunServe, None),
        (SystemKind::LoongServe, None),
        (SystemKind::Seesaw, None),
    ]
    .into_iter()
    .enumerate()
    .map(|(k, (sys, policy))| {
        SweepJob::new(format!("job{k}/{}", sys.name()), cfg(), sys, policy, Arc::clone(&trace))
    })
    .collect();
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    let parallel = results_to_jsonl(&run_sweep_parallel(&jobs, 5));
    assert_eq!(serial, parallel);
}
