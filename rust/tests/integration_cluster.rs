//! Integration tests: end-to-end cluster behaviour across modules
//! (workload → scheduler → transformation → metrics), plus failure
//! injection on the serving loop.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, ClusterSim, SystemKind};
use gyges::sim::{SimDuration, SimTime};
use gyges::workload::{SloClass, Trace, TraceRequest};

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

fn mk_trace(reqs: &[(f64, u64, u64)]) -> Trace {
    let mut t = Trace::default();
    for (i, &(at, input, output)) in reqs.iter().enumerate() {
        t.requests.push(TraceRequest {
            id: i as u64,
            arrival: SimTime::from_secs_f64(at),
            input_len: input,
            output_len: output,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    t.sort();
    t
}

#[test]
fn full_lifecycle_scale_up_serve_scale_down() {
    // One long request forces 4×TP1 → TP4; afterwards the cluster returns
    // to 8×TP1 and keeps serving shorts.
    let mut reqs: Vec<(f64, u64, u64)> = vec![(1.0, 50_000, 128)];
    for i in 0..400 {
        reqs.push((i as f64 * 0.5, 1000, 60));
    }
    let out = run_system(cfg(), SystemKind::Gyges, None, mk_trace(&reqs));
    assert_eq!(out.report.completed, out.report.total, "all must finish");
    assert!(out.counters.scale_ups >= 1);
    assert!(out.counters.scale_downs >= 1);
    // TTFT of the long request stays finite and bounded.
    let long = out.recorder.get(0).unwrap();
    let ttft = long.ttft().unwrap().as_secs_f64();
    assert!(ttft < 120.0, "long TTFT {ttft}");
}

#[test]
fn every_system_serves_the_same_trace() {
    let trace = Trace::hybrid_paper(3, 120.0);
    for sys in [
        SystemKind::Gyges,
        SystemKind::GygesNoOverlap,
        SystemKind::Basic,
        SystemKind::Seesaw,
        SystemKind::KunServe,
        SystemKind::LoongServe,
    ] {
        let out = run_system(cfg(), sys, None, trace.clone());
        assert_eq!(
            out.report.completed, out.report.total,
            "{}: incomplete",
            sys.name()
        );
    }
}

#[test]
fn overload_degrades_gracefully_not_fatally() {
    // Demand far above capacity: the simulator must still terminate with
    // every request eventually served (queueing, not dropping).
    let mut reqs = Vec::new();
    for i in 0..2000 {
        reqs.push((i as f64 * 0.01, 1000, 120)); // 100 qps
    }
    let out = run_system(cfg(), SystemKind::Gyges, None, mk_trace(&reqs));
    assert_eq!(out.report.completed, 2000);
    // p99 TTFT reflects the overload.
    assert!(out.report.ttft_p99_s > out.report.ttft_p50_s);
    // Deferral latency is measured: requests deferred under overload were
    // later placed, and their waiting time accumulated.
    assert!(out.counters.deferred > 0);
    assert!(out.counters.backlog_retries > 0);
    assert!(out.counters.backlog_wait > SimDuration::ZERO);
}

#[test]
fn backlog_cooldown_bounds_retry_storms() {
    // An unserveable long request (transformation disabled, so ScaleUp
    // degrades to Defer) parks in the backlog while shorts stream through.
    // Without the cooldown every finish re-routes it; with the cooldown
    // the retries collapse to one per deadline window.
    let mut c = cfg();
    c.backlog_retry_cooldown_s = 1.0;
    let mut reqs: Vec<(f64, u64, u64)> = vec![(0.5, 50_000, 64)];
    for i in 0..360 {
        reqs.push((i as f64 / 12.0, 1000, 40)); // 12 qps, well under capacity
    }
    let mut sim = ClusterSim::new(c, SystemKind::Gyges, mk_trace(&reqs));
    sim.disable_transformation();
    let out = sim.run();
    // All shorts finish; the long can never be placed.
    assert_eq!(out.report.completed, 360);
    assert!(out.counters.deferred >= 1);
    assert!(out.counters.backlog_requeues > 0, "the long must have been retried");
    assert!(
        out.counters.backlog_suppressed > 0,
        "finish-triggered drains inside the cooldown window must be suppressed"
    );
    assert!(
        out.counters.backlog_wakeup_events > 0,
        "suppressed drains must be replaced by scheduled wakeups"
    );
    // Retries are bounded by the wakeup cadence, not the finish rate:
    // ~30 s of traffic with a 1 s cooldown cannot retry hundreds of times.
    assert!(
        out.counters.backlog_retries < 360,
        "retry storm: {} retries for {} finishes",
        out.counters.backlog_retries,
        out.report.completed
    );
    // The run still terminates (no wakeup self-perpetuation): reaching
    // here with an empty queue proves it, and the event ledger balances.
    let c = &out.counters;
    assert_eq!(
        c.events,
        c.arrival_events
            + c.step_events
            + c.transform_done_events
            + c.stale_events
            + c.backlog_wakeup_events
    );
}

#[test]
fn unserveable_request_is_deferred_not_crashing() {
    // 200K input exceeds even TP4's max-seq → stays deferred while the
    // rest of the system keeps working.
    let reqs = vec![(0.5, 200_000, 64), (1.0, 1000, 32), (1.5, 1000, 32)];
    let out = run_system(cfg(), SystemKind::Gyges, None, mk_trace(&reqs));
    assert_eq!(out.report.completed, 2, "the two shorts must finish");
    assert!(out.counters.deferred >= 1);
}

#[test]
fn burst_of_longs_reuses_one_tp4_under_gyges() {
    let mut reqs: Vec<(f64, u64, u64)> =
        (0..4).map(|k| (10.0 + 20.0 * k as f64, 50_000, 64)).collect();
    for i in 0..200 {
        reqs.push((i as f64 * 0.5, 1000, 40));
    }
    let gy = run_system(cfg(), SystemKind::Gyges, None, mk_trace(&reqs));
    assert_eq!(gy.report.completed, gy.report.total);
    assert!(
        gy.counters.scale_ups <= 2,
        "gyges should reuse the TP4 across the burst (got {} scale-ups)",
        gy.counters.scale_ups
    );
}

#[test]
fn policies_share_transformation_machinery_but_differ_in_routing() {
    let trace = Trace::hybrid_paper(9, 180.0);
    let mut tputs = Vec::new();
    for p in [Policy::Gyges, Policy::RoundRobin, Policy::LeastLoadFirst] {
        let out = run_system(cfg(), SystemKind::Gyges, Some(p.into()), trace.clone());
        assert_eq!(out.report.completed, out.report.total, "{p:?}");
        tputs.push(out.report.throughput_tps);
    }
    for t in &tputs {
        assert!(*t > 0.0);
    }
}

#[test]
fn multi_host_cluster_works() {
    let mut c = cfg();
    c.hosts = 2;
    let mut reqs: Vec<(f64, u64, u64)> = vec![(1.0, 50_000, 64), (2.0, 50_000, 64)];
    for i in 0..200 {
        reqs.push((i as f64 * 0.25, 1000, 40));
    }
    let out = run_system(c, SystemKind::Gyges, None, mk_trace(&reqs));
    assert_eq!(out.report.completed, out.report.total);
}

#[test]
fn seesaw_blocking_visible_in_tail_latency() {
    let mut reqs: Vec<(f64, u64, u64)> = vec![(5.0, 50_000, 64)];
    for i in 0..120 {
        reqs.push((i as f64 * 0.25, 1000, 40));
    }
    let trace = mk_trace(&reqs);
    let long_id = trace
        .requests
        .iter()
        .find(|r| r.input_len == 50_000)
        .unwrap()
        .id;
    let gy = run_system(cfg(), SystemKind::Gyges, None, trace.clone());
    let ss = run_system(cfg(), SystemKind::Seesaw, None, trace);
    // The long request pays Seesaw's blocking CPU round-trip in full.
    let gy_ttft = gy.recorder.get(long_id).unwrap().ttft().unwrap().as_secs_f64();
    let ss_ttft = ss.recorder.get(long_id).unwrap().ttft().unwrap().as_secs_f64();
    assert!(
        ss_ttft > gy_ttft + 5.0,
        "seesaw long TTFT {ss_ttft} vs gyges {gy_ttft}"
    );
}

#[test]
fn static_layout_replacement_is_respected() {
    let trace = Trace::hybrid_paper(5, 60.0);
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, trace);
    sim.replace_instances(|host, base| {
        vec![
            (host, (base..base + 4).collect(), 4),
            (host, vec![base + 4], 1),
            (host, vec![base + 5], 1),
            (host, vec![base + 6], 1),
            (host, vec![base + 7], 1),
        ]
    });
    sim.disable_transformation();
    let out = sim.run();
    assert_eq!(out.counters.scale_ups, 0);
    assert!(out.report.completed > 0);
}
