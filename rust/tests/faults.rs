//! Deterministic fault injection guarantees (PR 6):
//!
//! 1. A zero-fault [`FaultPlan`] is byte-identical to today's simulator
//!    for every named paper sweep — the fault subsystem costs nothing
//!    when disarmed.
//! 2. A seeded fault storm is byte-identical across the serial driver
//!    and the work-stealing parallel driver at any thread count —
//!    property-tested over random storm seeds and workloads.
//! 3. Crash / recovery semantics: a host crash kills every instance on
//!    the host, requeues its in-flight requests through the backlog,
//!    and the restored host rejoins and serves.
//! 4. Snapshot/resume stays byte-identical at adversarial instants with
//!    faults armed: paused mid-outage (degraded host serialized) and
//!    with retry backoff timers armed.
//! 5. Liveness under total capacity loss: an unserveable-but-retryable
//!    backlog with a bounded retry policy terminates through counted
//!    drops, not an event-cap `SimError` (the PR 6 backlog fix).

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{ClusterSim, RunStatus, SimOutcome, SystemKind};
use gyges::experiments::named_sweep_jobs;
use gyges::experiments::sweep::{
    results_to_jsonl, run_sweep_parallel, run_sweep_serial, SweepJob,
};
use gyges::faults::{Fault, FaultKind, FaultPlan};
use gyges::sim::{SimDuration, SimTime};
use gyges::snapshot::state::SimSnapshot;
use gyges::util::proptest;
use gyges::util::Prng;
use gyges::workload::{SloClass, Trace, TraceRequest};
use std::sync::Arc;

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

/// Paper defaults plus a bounded, backoff-ed retry policy (the chaos
/// experiment's admission-control posture).
fn retry_cfg(max_attempts: u32, backoff_base_s: f64) -> ClusterConfig {
    let mut cfg = cfg();
    cfg.retry_max_attempts = max_attempts;
    cfg.retry_backoff_base_s = backoff_base_s;
    cfg
}

/// Full observable state of one run (everything a sweep row serializes).
fn sig(out: &SimOutcome) -> String {
    format!(
        "{}|{:?}|{:?}|{:?}",
        out.report.to_json(),
        out.counters,
        out.recorder.tps_series(),
        out.error
    )
}

/// Pause `sim` at `at`, roundtrip its state through the JSON envelope,
/// and return the restored simulator — or `None` if the run finished
/// before the checkpoint instant.
fn checkpoint_roundtrip(
    sim: &mut ClusterSim,
    at: SimTime,
    cfg: &ClusterConfig,
) -> Option<ClusterSim> {
    match sim.run_until(Some(at)) {
        RunStatus::Done => None,
        RunStatus::Paused => {
            let snap = sim.snapshot().expect("paused run must snapshot");
            let text = snap.to_string_pretty();
            let parsed = SimSnapshot::parse(&text).expect("snapshot must parse");
            assert_eq!(parsed, snap, "JSON roundtrip must be lossless");
            Some(ClusterSim::from_snapshot(cfg.clone(), &parsed).expect("restore must succeed"))
        }
    }
}

/// Arming an EMPTY fault plan must not perturb a single byte of any
/// named paper sweep — proves the fault subsystem is free when unused
/// (the ISSUE 6 zero-fault acceptance criterion for fig12/13/14).
#[test]
fn zero_fault_plan_is_byte_identical_for_named_sweeps() {
    for name in ["fig12", "fig13", "fig14"] {
        let jobs = named_sweep_jobs(name, 30.0).expect("known sweep name");
        let plain = results_to_jsonl(&run_sweep_serial(&jobs));
        let armed: Vec<SweepJob> =
            jobs.iter().cloned().map(|j| j.with_faults(FaultPlan::empty())).collect();
        let faulted = results_to_jsonl(&run_sweep_serial(&armed));
        assert_eq!(
            plain, faulted,
            "{name}: an empty FaultPlan must leave the sweep byte-identical"
        );
    }
}

/// Same seed → same storm → same bytes, regardless of which sweep
/// driver runs the jobs or how many threads steal work.
#[test]
fn prop_fault_storms_are_deterministic_across_sweep_threads() {
    proptest::forall(
        "fault storm determinism",
        proptest::Config { cases: 5, seed: 0xFA_017 },
        |rng: &mut Prng| {
            let storm_seed = rng.next();
            let trace_seed = rng.next();
            let horizon = 20.0 + rng.f64() * 20.0;
            (storm_seed, trace_seed, horizon)
        },
        |&(storm_seed, trace_seed, horizon)| {
            let cfg = retry_cfg(6, 0.2);
            let plan =
                FaultPlan::storm(storm_seed, horizon, cfg.hosts, cfg.gpus_per_host, 6.0);
            let trace = Arc::new(Trace::hybrid_paper(trace_seed, horizon));
            let jobs: Vec<SweepJob> =
                [Policy::Gyges, Policy::RoundRobin, Policy::LeastLoadFirst]
                    .into_iter()
                    .map(|p| {
                        SweepJob::new(
                            format!("storm/{}", p.name()),
                            cfg.clone(),
                            SystemKind::Gyges,
                            Some(p.into()),
                            trace.clone(),
                        )
                        .with_faults(plan.clone())
                    })
                    .collect();
            let serial = results_to_jsonl(&run_sweep_serial(&jobs));
            for threads in [2usize, 4] {
                let parallel = results_to_jsonl(&run_sweep_parallel(&jobs, threads));
                gyges::prop_assert!(
                    parallel == serial,
                    "storm {storm_seed:#x} / trace {trace_seed:#x} diverged at {threads} \
                     threads:\n  serial:   {serial}\n  parallel: {parallel}"
                );
            }
            Ok(())
        },
    );
}

/// A host crash mid-run kills the host's instances, requeues their
/// in-flight work through the backlog, and the MTTR restore rejoins the
/// host — the run still completes requests on the other side.
#[test]
fn host_crash_requeues_in_flight_and_recovery_rejoins() {
    let mut plan = FaultPlan::empty();
    plan.faults.push(Fault {
        at: SimTime::from_secs_f64(10.0),
        kind: FaultKind::HostCrash { host: 0, mttr: SimDuration::from_secs_f64(5.0) },
    });
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, Trace::hybrid_paper(0xFEED, 30.0));
    sim.set_fault_plan(plan).expect("plan must fit the cluster");
    let out = sim.run();
    assert!(out.error.is_none(), "faulted run must terminate cleanly: {:?}", out.error);
    let c = &out.counters;
    assert_eq!(c.fault_events, 1, "exactly one injected fault: {c:?}");
    assert!(c.crashed_instances > 0, "the crash must kill instances: {c:?}");
    assert!(c.crash_requeued > 0, "in-flight work at t=10s must requeue: {c:?}");
    assert_eq!(c.recovery_events, 1, "the MTTR restore must fire: {c:?}");
    assert_eq!(c.dropped, 0, "unlimited retry never sheds load: {c:?}");
    assert!(
        out.report.completed == out.report.total,
        "every request must eventually finish once the host rejoins: {}/{}",
        out.report.completed,
        out.report.total
    );
}

/// PR 6 `tps_buckets` caveat regression: a crash that requeues running
/// requests must unwind the per-second TPS credits the lost run had
/// already banked. The final series must equal a never-crashed replay
/// of the same completions — i.e. the sum of each surviving record's
/// own credit ledger — and the bucket total must equal the token total
/// (both failed before the unwind: phantom pre-crash credits survived).
#[test]
fn crash_requeue_unwinds_tps_buckets() {
    let mut plan = FaultPlan::empty();
    plan.faults.push(Fault {
        at: SimTime::from_secs_f64(10.0),
        kind: FaultKind::HostCrash { host: 0, mttr: SimDuration::from_secs_f64(5.0) },
    });
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, Trace::hybrid_paper(0xFEED, 30.0));
    sim.set_fault_plan(plan).expect("plan must fit the cluster");
    let out = sim.run();
    assert!(out.error.is_none(), "faulted run must terminate cleanly: {:?}", out.error);
    assert!(out.counters.crash_requeued > 0, "crash must requeue in-flight work");
    // Replay: a run that only ever saw the surviving completions would
    // credit exactly each record's ledger, nothing more.
    let mut replay: Vec<u64> = Vec::new();
    let mut tokens = 0u64;
    for (id, r) in out.recorder.records() {
        tokens += r.generated;
        let ledger: u64 = r.tok_buckets.iter().map(|&(_, c)| u64::from(c)).sum();
        assert_eq!(ledger, r.generated, "request {id}: ledger must count every live token");
        for &(sec, c) in &r.tok_buckets {
            let idx = sec as usize;
            if idx >= replay.len() {
                replay.resize(idx + 1, 0);
            }
            replay[idx] += u64::from(c);
        }
    }
    // Trailing zero buckets are resize high-water marks; compare content.
    let trim = |b: &[u64]| {
        let mut v = b.to_vec();
        while v.last() == Some(&0) {
            v.pop();
        }
        v
    };
    let got = out.recorder.tps_buckets();
    assert_eq!(trim(got), trim(&replay), "buckets diverged from the never-crashed replay");
    assert_eq!(got.iter().sum::<u64>(), tokens, "bucket total must equal live token total");
}

/// Snapshot/resume with faults ARMED: checkpoints landing mid-outage
/// (host degraded, KV lost) and inside retry-backoff windows must all
/// resume to the uninterrupted faulted run's exact bytes — and the walk
/// must actually visit both adversarial states.
#[test]
fn resume_with_armed_faults_is_byte_identical() {
    let cfg = retry_cfg(6, 0.2);
    let plan = || {
        let mut p = FaultPlan::empty();
        p.faults.push(Fault {
            at: SimTime::from_secs_f64(4.0),
            kind: FaultKind::TransformAbort { worker: 0 },
        });
        p.faults.push(Fault {
            at: SimTime::from_secs_f64(10.0),
            kind: FaultKind::HostCrash { host: 0, mttr: SimDuration::from_secs_f64(5.0) },
        });
        p.faults.push(Fault {
            at: SimTime::from_secs_f64(16.0),
            kind: FaultKind::InstanceStall { worker: 2, dur: SimDuration::from_secs_f64(1.0) },
        });
        p.faults.push(Fault {
            at: SimTime::from_secs_f64(18.0),
            kind: FaultKind::LinkDown { host: 0, dur: SimDuration::from_secs_f64(2.0) },
        });
        p
    };
    let build = || {
        let mut sim =
            ClusterSim::new(cfg.clone(), SystemKind::Gyges, Trace::hybrid_paper(0xC0FFEE, 25.0));
        sim.set_fault_plan(plan()).expect("plan must fit the cluster");
        sim
    };
    let reference = sig(&build().run());
    let mut sim = build();
    let (mut saw_degraded, mut saw_retry) = (false, false);
    let mut t = 0.5;
    while t < 400.0 {
        match checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(t), &cfg) {
            Some(restored) => sim = restored,
            None => break,
        }
        saw_degraded |= sim.degraded_hosts() > 0;
        saw_retry |= sim.armed_retries() > 0;
        t += 0.5;
    }
    let _ = sim.run_until(None);
    let resumed = sig(&sim.finish());
    assert!(saw_degraded, "walk must checkpoint mid-outage (host 0 down 10s–15s)");
    assert!(saw_retry, "walk must checkpoint with retry backoff timers armed");
    assert_eq!(resumed, reference, "armed-fault resume diverged from the uninterrupted run");
}

/// PR 6 backlog-liveness regression: when a crash removes ALL capacity
/// (hosts=1) and the MTTR is effectively forever, a bounded retry
/// policy must walk every backlog entry to attempt-exhaustion and drop
/// it — terminating the run with counted drops instead of spinning
/// wakeup-only events into the event cap.
#[test]
fn total_capacity_loss_with_bounded_retry_terminates_with_drops() {
    let cfg = retry_cfg(3, 0.1);
    let mut trace = Trace::default();
    for i in 0..24u64 {
        trace.requests.push(TraceRequest {
            id: 0,
            arrival: SimTime::from_secs_f64(i as f64 * 0.25),
            input_len: 2000,
            output_len: 2000, // long decode: plenty in flight at the crash
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    trace.sort_and_renumber();
    let mut plan = FaultPlan::empty();
    plan.faults.push(Fault {
        at: SimTime::from_secs_f64(6.5),
        kind: FaultKind::HostCrash { host: 0, mttr: SimDuration::from_secs_f64(100_000.0) },
    });
    let mut sim = ClusterSim::new(cfg.clone(), SystemKind::Gyges, trace);
    sim.disable_transformation(); // keep all 8 TP1s so the kill count is exact
    sim.set_fault_plan(plan).expect("plan must fit the cluster");
    let out = sim.run();
    assert!(
        out.error.is_none(),
        "must terminate via counted drops, not an event-cap SimError: {:?}",
        out.error
    );
    let c = &out.counters;
    assert_eq!(
        c.crashed_instances as usize,
        cfg.gpus_per_host,
        "hosts=1 crash is total fleet loss: {c:?}"
    );
    assert!(c.crash_requeued > 0, "in-flight work must requeue before dropping: {c:?}");
    assert!(c.dropped > 0, "bounded retry must shed the unserveable backlog: {c:?}");
    assert!(
        out.report.completed < out.report.total,
        "dropped requests must show up as incomplete: {}/{}",
        out.report.completed,
        out.report.total
    );
    assert_eq!(
        c.dropped + out.report.completed as u64,
        out.report.total as u64,
        "every admitted request is either completed or counted dropped: {c:?}"
    );
}
