//! Calendar-queue ↔ heap-queue equivalence (ISSUE 7 satellite).
//!
//! The calendar backend replaces the `BinaryHeap` on every hot path, so
//! this property test is the proof that the swap is invisible: random
//! interleaved push / pop / advance_to / snapshot-restore sequences must
//! produce byte-identical `(time, seq, event)` pop streams on both
//! backends, including past-push clamping and mid-sequence restores
//! (onto the same AND the opposite backend — snapshots carry no backend
//! marker).

use gyges::prop_assert;
use gyges::sim::{EventQueue, QueueBackend, SimTime};
use gyges::util::proptest::{forall, Config};
use gyges::util::Prng;

/// One scripted queue operation. Times are *offsets* so the script is
/// meaningful regardless of where the clock sits when it runs.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Push at `now + offset`; negative offsets (`past == true`)
    /// exercise the clamp-to-now path.
    Push { offset: u64, past: bool },
    Pop,
    /// `advance_to(now + offset)` — may strand queued entries behind
    /// the clock, which later pops must legally move backwards to.
    Advance { offset: u64 },
    /// Snapshot via `entries()/seq()/now()` and rebuild both queues via
    /// `restore`, each onto a random backend.
    Restore,
}

fn gen_script(r: &mut Prng, len: usize) -> Vec<Op> {
    (0..len)
        .map(|_| match r.index(10) {
            0..=4 => Op::Push { offset: r.gen_range(0, 50_000_000), past: r.chance(0.2) },
            5..=7 => Op::Pop,
            8 => Op::Advance { offset: r.gen_range(0, 20_000_000) },
            _ => Op::Restore,
        })
        .collect()
}

/// Drive both queues through the script in lockstep, asserting every
/// observable (pop stream, peek, len, now, seq) matches at every step.
fn run_lockstep(script: &[Op], restore_seed: u64) -> Result<(), String> {
    let mut cal = EventQueue::with_backend(QueueBackend::Calendar);
    let mut heap = EventQueue::with_backend(QueueBackend::Heap);
    let mut restore_rng = Prng::new(restore_seed);
    let mut next_payload: u64 = 0;

    for (step, &op) in script.iter().enumerate() {
        match op {
            Op::Push { offset, past } => {
                // A "past" push targets a time below now (clamped); a
                // normal one targets now + offset.
                let base = cal.now().0;
                let at = if past {
                    SimTime(base.saturating_sub(offset))
                } else {
                    SimTime(base + offset)
                };
                cal.push(at, next_payload);
                heap.push(at, next_payload);
                next_payload += 1;
            }
            Op::Pop => {
                let (a, b) = (cal.pop(), heap.pop());
                prop_assert!(a == b, "step {step}: pop diverged: {a:?} vs {b:?}");
            }
            Op::Advance { offset } => {
                let t = SimTime(cal.now().0 + offset);
                cal.advance_to(t);
                heap.advance_to(t);
            }
            Op::Restore => {
                // entries() is the snapshot surface; both backends must
                // serialize the identical (time, seq, payload) list.
                let ce: Vec<(SimTime, u64, u64)> =
                    cal.entries().into_iter().map(|(t, s, &p)| (t, s, p)).collect();
                let he: Vec<(SimTime, u64, u64)> =
                    heap.entries().into_iter().map(|(t, s, &p)| (t, s, p)).collect();
                prop_assert!(ce == he, "step {step}: entries diverged: {ce:?} vs {he:?}");
                // Restore onto random backends: the snapshot must not
                // care which backend wrote it or which one reads it.
                let pick = |r: &mut Prng| {
                    if r.chance(0.5) { QueueBackend::Calendar } else { QueueBackend::Heap }
                };
                let (ca, cb) = (pick(&mut restore_rng), pick(&mut restore_rng));
                cal = EventQueue::restore_with_backend(ca, cal.now(), cal.seq(), ce)
                    .map_err(|e| format!("step {step}: calendar restore refused: {e}"))?;
                heap = EventQueue::restore_with_backend(cb, heap.now(), heap.seq(), he)
                    .map_err(|e| format!("step {step}: heap restore refused: {e}"))?;
            }
        }
        prop_assert!(
            cal.len() == heap.len(),
            "step {step}: len diverged: {} vs {}",
            cal.len(),
            heap.len()
        );
        prop_assert!(
            cal.peek_time() == heap.peek_time(),
            "step {step}: peek diverged: {:?} vs {:?}",
            cal.peek_time(),
            heap.peek_time()
        );
        prop_assert!(
            cal.now() == heap.now() && cal.seq() == heap.seq(),
            "step {step}: clock/seq diverged: ({:?},{}) vs ({:?},{})",
            cal.now(),
            cal.seq(),
            heap.now(),
            heap.seq()
        );
    }

    // Drain both to the end: the full residual pop stream must match.
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        prop_assert!(a == b, "drain diverged: {a:?} vs {b:?}");
        if a.is_none() {
            return Ok(());
        }
    }
}

#[test]
fn random_interleavings_pop_identically() {
    forall(
        "queue-backend-equivalence",
        Config { cases: 64, seed: 0x9_0E0E },
        |r| {
            let len = r.gen_range(20, 400) as usize;
            let restore_seed = r.next();
            (gen_script(r, len), restore_seed)
        },
        |(script, restore_seed)| run_lockstep(script, *restore_seed),
    );
}

#[test]
fn burst_of_equal_timestamps_keeps_fifo_across_backends() {
    // Heavy seq-tie-breaking pressure: many entries on few distinct
    // timestamps, popped across a mid-burst restore.
    let mut cal = EventQueue::with_backend(QueueBackend::Calendar);
    let mut heap = EventQueue::with_backend(QueueBackend::Heap);
    for i in 0..300u64 {
        let t = SimTime((i % 3) * 1_000);
        cal.push(t, i);
        heap.push(t, i);
    }
    for _ in 0..100 {
        assert_eq!(cal.pop(), heap.pop());
    }
    let entries: Vec<(SimTime, u64, u64)> =
        cal.entries().into_iter().map(|(t, s, &p)| (t, s, p)).collect();
    // Cross-backend swap: calendar snapshot → heap queue and vice versa.
    let mut cal2 =
        EventQueue::restore_with_backend(QueueBackend::Heap, cal.now(), cal.seq(), entries.clone())
            .unwrap();
    let mut heap2 = EventQueue::restore_with_backend(
        QueueBackend::Calendar,
        heap.now(),
        heap.seq(),
        entries,
    )
    .unwrap();
    loop {
        let (a, b) = (cal2.pop(), heap2.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn hour_scale_offsets_exercise_bucket_rotation() {
    // Offsets spanning ns..hours force the calendar through grows,
    // shrinks, and the sparse fallback scan while the heap oracle
    // watches.
    let mut cal = EventQueue::with_backend(QueueBackend::Calendar);
    let mut heap = EventQueue::with_backend(QueueBackend::Heap);
    let mut r = Prng::new(0x40C4_E0D4);
    let scales = [1_000u64, 1_000_000, 1_000_000_000, 3_600_000_000_000];
    for i in 0..1500u64 {
        if r.chance(0.6) || cal.is_empty() {
            let scale = scales[r.index(scales.len())];
            let at = SimTime(cal.now().0 + r.gen_range(0, scale));
            cal.push(at, i);
            heap.push(at, i);
        } else {
            assert_eq!(cal.pop(), heap.pop(), "diverged at op {i}");
        }
    }
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}
