//! Integration tests over the REAL PJRT serving path (requires
//! `make artifacts`; tests self-skip when artifacts are absent so
//! `cargo test` works before the python step).

use gyges::runtime::{argmax, Manifest, Oracle, TinyRuntime};
use gyges::serve::{synthetic_workload, RealServer, ServerConfig};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn oracle_reproduced_at_every_tp_degree() {
    let dir = require_artifacts!();
    let oracle = Oracle::load(&dir).unwrap();
    for tp in [1usize, 2, 4] {
        let mut rt = TinyRuntime::load(&dir, tp).unwrap();
        let mut sess = rt.new_session().unwrap();
        let got = rt.generate(&mut sess, &oracle.prompt, oracle.generated.len()).unwrap();
        assert_eq!(got, oracle.generated, "tp{tp} diverged from the python oracle");
    }
}

#[test]
fn transformation_chain_1_2_4_2_1_preserves_decode() {
    let dir = require_artifacts!();
    let prompt = [7u32, 301, 55, 12];
    // Reference: uninterrupted TP1.
    let mut rt_ref = TinyRuntime::load(&dir, 1).unwrap();
    let mut s_ref = rt_ref.new_session().unwrap();
    let want = rt_ref.generate(&mut s_ref, &prompt, 8).unwrap();

    // Chain of live transformations between every generated token.
    let mut rt = TinyRuntime::load(&dir, 1).unwrap();
    let mut sess = rt.new_session().unwrap();
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = rt.step(&mut sess, t).unwrap();
    }
    let chain = [2usize, 4, 2, 1, 4, 1, 2, 1];
    let mut got = Vec::new();
    for &tp in &chain {
        rt.transform(&mut sess, tp).unwrap();
        let next = argmax(&logits) as u32;
        got.push(next);
        logits = rt.step(&mut sess, next).unwrap();
    }
    assert_eq!(got, want, "transformation chain changed the output");
}

#[test]
fn manifest_matches_rust_model_config() {
    let dir = require_artifacts!();
    let man = Manifest::load(&dir).unwrap();
    let m = gyges::config::ModelConfig::gyges_tiny();
    assert_eq!(man.hidden as u64, m.hidden_size);
    assert_eq!(man.heads as u64, m.num_heads);
    assert_eq!(man.head_dim as u64, m.head_dim);
    assert_eq!(man.layers as u64, m.num_layers);
    assert_eq!(man.vocab as u64, m.vocab_size);
}

#[test]
fn server_scales_up_for_long_and_down_after() {
    let dir = require_artifacts!();
    let mut server = RealServer::new(&dir, ServerConfig::default()).unwrap();
    let mut reqs = synthetic_workload(7, 1, 1, server.rt.man.vocab);
    // order: short then long then short (force up + down)
    reqs.sort_by_key(|r| r.prompt.len());
    let short2 = reqs[0].clone();
    let mut reqs = vec![reqs[0].clone(), reqs[1].clone(), short2];
    reqs[2].id = 99;
    let rep = server.serve(&reqs).unwrap();
    assert!(rep.transforms >= 2, "up for the long, down after: {}", rep.transforms);
    assert_eq!(rep.results.len(), 3);
}

#[test]
fn sequence_cap_is_enforced() {
    let dir = require_artifacts!();
    let mut rt = TinyRuntime::load(&dir, 1).unwrap();
    let mut sess = rt.new_session().unwrap();
    for i in 0..rt.man.s_max {
        rt.step(&mut sess, (i % 100) as u32).unwrap();
    }
    assert!(rt.step(&mut sess, 0).is_err(), "must refuse past S_MAX");
}

#[test]
fn unknown_tp_rejected() {
    let dir = require_artifacts!();
    assert!(TinyRuntime::load(&dir, 3).is_err());
    assert!(TinyRuntime::load(&dir, 8).is_err());
}
