//! Pipeline vs legacy routing lockstep (PR 8).
//!
//! The plain policies (`gyges` / `rr` / `llf`) are compositions of
//! filter/score pipeline stages since the scheduler redesign; the
//! pre-pipeline implementations survive behind the test-only
//! `legacy-policies` feature purely as the reference for this proof.
//! Here the figure sweeps whose rows the paper reproduction publishes
//! (fig12 / fig13 / fig14) are run twice at smoke horizons — once
//! through the pipeline compositions, once with the process-global
//! legacy switch thrown — and the serialized JSONL rows must match
//! byte for byte. CI's `policy-pipeline-verify` job repeats the fig12
//! leg end-to-end through the real binary (`--legacy-routing`).
//!
//! Only compiled with `--features legacy-policies` (`required-features`
//! in Cargo.toml): `set_legacy_routing` does not exist on the lib
//! integration tests link against otherwise.
//!
//! ONE #[test] on purpose: the legacy switch is process-global state,
//! and parallel test threads toggling it would race. Everything that
//! needs the switch lives in this single serial function.

use gyges::coordinator::set_legacy_routing;
use gyges::experiments::named_sweep_jobs;
use gyges::experiments::sweep::{results_to_jsonl, run_sweep_serial};

#[test]
fn figure_sweeps_are_byte_identical_pipeline_vs_legacy() {
    // fig13's trace is fully scripted (the horizon argument is ignored);
    // fig12/fig14 use CI's 45 s smoke horizon.
    for name in ["fig12", "fig13", "fig14"] {
        let jobs = named_sweep_jobs(name, 45.0)
            .unwrap_or_else(|| panic!("{name} is not a registered sweep"));
        set_legacy_routing(false);
        let pipeline = results_to_jsonl(&run_sweep_serial(&jobs));
        set_legacy_routing(true);
        let legacy = results_to_jsonl(&run_sweep_serial(&jobs));
        set_legacy_routing(false);
        assert_eq!(
            pipeline, legacy,
            "{name}: pipeline-composed plain policies drifted from the legacy reference"
        );
    }
}
