//! Streamed-trace replay guarantees (PR 4):
//!
//! 1. Feeding the simulator from trace segments — chunked in memory,
//!    JSONL segment files, or a seeded on-the-fly stream — produces
//!    sweep rows byte-identical to whole-trace replay, for random seeds
//!    and segment sizes, including segment boundaries landing exactly
//!    on arrival timestamps and empty trailing segments.
//! 2. Peak trace memory of streamed replay is bounded by one segment
//!    (asserted via the feed's buffered high-water mark, not
//!    wall-clock), while whole-trace replay buffers everything.
//! 3. Request ids stay globally unique and stable across segmentation.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{ClusterSim, SimOutcome, SystemKind};
use gyges::experiments::launch::{group_dir_name, streamed_named_jobs, trace_gen_named};
use gyges::experiments::sweep::{results_to_jsonl, run_sweep_serial, SweepJob};
use gyges::experiments::{named_sweep_jobs, shard::job_list_hash};
use gyges::sim::SimTime;
use gyges::util::proptest;
use gyges::workload::source::write_segments;
use gyges::workload::{
    ChunkedTrace, ProductionStream, SegmentFileSource, SloClass, StreamSource, Trace, TraceRequest,
};
use gyges::prop_assert;
use std::path::PathBuf;
use std::sync::Arc;

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gyges-streaming-{name}-{}", std::process::id()))
}

/// Full observable state of one run (everything a sweep row serializes).
fn snapshot(out: &SimOutcome) -> String {
    format!(
        "{}|{:?}|{:?}|{:?}",
        out.report.to_json(),
        out.counters,
        out.recorder.tps_series(),
        out.error
    )
}

fn two_policy_jobs(trace: Arc<Trace>) -> Vec<SweepJob> {
    [Policy::Gyges, Policy::RoundRobin]
        .into_iter()
        .map(|p| {
            SweepJob::new(
                format!("stream/{}", p.name()),
                cfg(),
                SystemKind::Gyges,
                Some(p.into()),
                Arc::clone(&trace),
            )
        })
        .collect()
}

#[test]
fn prop_streamed_rows_byte_identical_for_random_seeds_and_segmentations() {
    proptest::forall(
        "streamed-replay-byte-identity",
        proptest::Config { cases: 8, seed: 0x57E4 },
        |r| {
            let seed = r.next();
            let qps = 1.0 + r.f64() * 3.0;
            let horizon_s = 20.0 + r.f64() * 25.0;
            let segment_s = 0.5 + r.f64() * 12.0;
            (seed, qps, horizon_s, segment_s)
        },
        |&(seed, qps, horizon_s, segment_s)| {
            let trace = Arc::new(Trace::production(seed, qps, horizon_s));
            let jobs = two_policy_jobs(Arc::clone(&trace));
            let whole = results_to_jsonl(&run_sweep_serial(&jobs));
            let chunked: Vec<SweepJob> =
                jobs.iter().cloned().map(|j| j.replay_chunked(segment_s)).collect();
            let streamed = results_to_jsonl(&run_sweep_serial(&chunked));
            prop_assert!(
                whole == streamed,
                "rows diverged for seed {seed} qps {qps:.2} horizon {horizon_s:.2} \
                 segment {segment_s:.2}"
            );
            Ok(())
        },
    );
}

#[test]
fn boundary_on_arrival_timestamp_and_empty_trailing_segments_identical() {
    // Arrivals exactly ON a 10 s segment boundary (10.0 s converts to
    // exactly 10e9 ticks, the window edge) plus a horizon far beyond
    // the last arrival so trailing segments are empty.
    let mut trace = Trace::default();
    let arrivals = [0.5, 5.0, 10.0, 10.0, 12.5, 20.0, 29.999];
    for (i, &at) in arrivals.iter().enumerate() {
        trace.requests.push(TraceRequest {
            id: i as u64,
            arrival: SimTime::from_secs_f64(at),
            input_len: if i == 3 { 50_000 } else { 1000 },
            output_len: 60,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    trace.sort();
    let whole = ClusterSim::new(cfg(), SystemKind::Gyges, trace.clone()).run();
    let chunked = ChunkedTrace::with_horizon(trace.clone(), 10.0, 90.0);
    let streamed = ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(chunked)).run();
    assert_eq!(snapshot(&whole), snapshot(&streamed));
    // Ids survive segmentation: the recorder holds exactly the trace's
    // (unique, stable) ids in both modes.
    let ids: Vec<u64> = streamed.recorder.records().map(|(id, _)| id).collect();
    let mut want: Vec<u64> = trace.requests.iter().map(|r| r.id).collect();
    want.sort_unstable();
    assert_eq!(ids, want);
}

#[test]
fn segment_file_replay_identical_with_peak_memory_bounded_by_one_segment() {
    let root = tmp("fig12-files");
    let _ = std::fs::remove_dir_all(&root);
    let horizon_s = 120.0;
    let segment_s = 15.0;
    trace_gen_named("fig12-qwen", horizon_s, segment_s, &root, 0).unwrap();

    // Whole-trace reference (the canonical materialized job list).
    let jobs = named_sweep_jobs("fig12-qwen", horizon_s).unwrap();
    let whole = results_to_jsonl(&run_sweep_serial(&jobs));

    // Streamed jobs replay the segment files and must both match the
    // canonical rows byte-for-byte and fingerprint as the same sweep.
    let streamed_jobs = streamed_named_jobs("fig12-qwen", horizon_s, &root).unwrap();
    assert_eq!(job_list_hash(&jobs), job_list_hash(&streamed_jobs));
    let streamed = results_to_jsonl(&run_sweep_serial(&streamed_jobs));
    assert_eq!(whole, streamed, "file-streamed fig12 rows must equal whole-trace rows");

    // The memory bound, via the segment-size knob: replaying from files
    // buffers at most the largest segment, while whole-trace replay
    // buffers the entire trace.
    let group = root.join(group_dir_name(0));
    let source = SegmentFileSource::open(&group).unwrap();
    let out = ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(source)).run();
    assert!(out.error.is_none());
    let dir = gyges::workload::SegmentDir::open(&group).unwrap();
    let max_segment = dir.files.iter().map(|f| f.count).max().unwrap();
    let total = dir.requests as usize;
    assert!(
        out.trace_peak_buffered <= max_segment,
        "streamed peak {} must be bounded by the largest segment {max_segment}",
        out.trace_peak_buffered
    );
    assert!(max_segment < total, "knob sanity: many segments, none holding the whole trace");
    let trace = match &jobs[0].trace {
        gyges::experiments::sweep::JobTrace::Full(t) => (**t).clone(),
        _ => unreachable!("canonical jobs are materialized"),
    };
    let whole_out = ClusterSim::new(cfg(), SystemKind::Gyges, trace).run();
    assert_eq!(whole_out.trace_peak_buffered, total, "whole-trace replay buffers everything");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn stream_jobs_match_materialized_jobs_and_fingerprint_their_spec() {
    use gyges::experiments::sweep::JobTrace;
    let spec = ProductionStream {
        seed: 17,
        qps: 2.0,
        segment_s: 15.0,
        horizon_s: 90.0,
        longs: None,
        slo: None,
        prefix: None,
    };
    let full = Arc::new(spec.materialize());
    let mk = |trace: JobTrace, p: Policy| {
        let key = format!("ps/{}", p.name());
        SweepJob::with_job_trace(key, cfg(), SystemKind::Gyges, Some(p.into()), trace)
    };
    let materialized: Vec<SweepJob> = [Policy::Gyges, Policy::RoundRobin]
        .into_iter()
        .map(|p| mk(JobTrace::Full(Arc::clone(&full)), p))
        .collect();
    let streamed: Vec<SweepJob> = [Policy::Gyges, Policy::RoundRobin]
        .into_iter()
        .map(|p| mk(JobTrace::Stream(spec.clone()), p))
        .collect();
    assert_eq!(
        results_to_jsonl(&run_sweep_serial(&materialized)),
        results_to_jsonl(&run_sweep_serial(&streamed)),
        "JobTrace::Stream rows must equal the materialized trace's rows"
    );
    // The generating spec IS the workload identity: a different
    // segmentation of the same seed is a different (valid) draw, and
    // the manifest fingerprint must distinguish it.
    let other_seg = ProductionStream { segment_s: 30.0, ..spec.clone() };
    let streamed_other: Vec<SweepJob> = [Policy::Gyges, Policy::RoundRobin]
        .into_iter()
        .map(|p| mk(JobTrace::Stream(other_seg.clone()), p))
        .collect();
    assert_ne!(job_list_hash(&streamed), job_list_hash(&streamed_other));
    assert_ne!(job_list_hash(&streamed), job_list_hash(&materialized));
}

#[test]
fn production_stream_replay_matches_materialized_and_file_replay() {
    let spec = ProductionStream {
        seed: 9,
        qps: 2.0,
        segment_s: 20.0,
        horizon_s: 120.0,
        longs: None,
        slo: None,
        prefix: None,
    };
    let whole = ClusterSim::new(cfg(), SystemKind::Gyges, spec.materialize()).run();
    let streamed =
        ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(StreamSource::new(spec.clone())))
            .run();
    assert_eq!(snapshot(&whole), snapshot(&streamed));

    let dir = tmp("prod-stream");
    let _ = std::fs::remove_dir_all(&dir);
    write_segments(&dir, "production", 0, 20.0, &mut StreamSource::new(spec), 0).unwrap();
    let file_source = SegmentFileSource::open(&dir).unwrap();
    let from_files =
        ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(file_source)).run();
    assert_eq!(snapshot(&whole), snapshot(&from_files));
    assert!(from_files.trace_peak_buffered < whole.trace_peak_buffered);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ≥1-hour-horizon regime (ISSUE 4 acceptance): a fig12 job streams
/// a 3600 s trace from segment files with peak trace memory bounded by
/// one 300 s segment. Ignored by default — the simulated hour takes
/// real minutes; run with `cargo test --test streaming -- --ignored`.
#[test]
#[ignore = "multi-hour regime; run explicitly with -- --ignored"]
fn hour_horizon_fig12_streams_with_bounded_memory() {
    // GYGES_HOUR_SEGMENTS reuses an existing trace-gen dir (CI points
    // it at the sweep-launch job's segments instead of regenerating).
    let (root, owned) = match std::env::var_os("GYGES_HOUR_SEGMENTS") {
        Some(p) => (PathBuf::from(p), false),
        None => (tmp("fig12-hour"), true),
    };
    let group = root.join(group_dir_name(0));
    if gyges::workload::SegmentDir::open(&group).is_err() {
        trace_gen_named("fig12-qwen", 3600.0, 300.0, &root, 0).unwrap();
    }
    let dir = gyges::workload::SegmentDir::open(&group).unwrap();
    let source = SegmentFileSource::new(dir.clone());
    let out = ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(source)).run();
    assert!(out.error.is_none(), "{:?}", out.error);
    assert_eq!(out.report.completed, dir.requests as usize);
    let max_segment = dir.files.iter().map(|f| f.count).max().unwrap();
    assert!(out.trace_peak_buffered <= max_segment);
    assert!(dir.files.len() >= 12, "an hour at 300 s segments");
    if owned {
        let _ = std::fs::remove_dir_all(&root);
    }
}
