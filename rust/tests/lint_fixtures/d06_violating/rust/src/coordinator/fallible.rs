//! D06 fixture: unwrap/expect in non-test coordinator code.

pub fn first_live(ids: &[usize]) -> usize {
    let head = ids.first().unwrap();
    let checked: Option<usize> = Some(*head);
    checked.expect("just wrapped")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
