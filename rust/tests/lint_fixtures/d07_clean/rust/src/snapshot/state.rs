//! D07 fixture: write/read key parity holds.

use crate::util::Json;

pub fn encode(seq: u64, done: bool) -> Json {
    let mut o = Json::obj();
    o.set("seq", seq);
    o.set("done", done);
    o
}

pub fn decode(o: &Json) -> Result<(u64, bool), String> {
    let seq = o.req_u64("seq", "fixture")?;
    let done = o.get("done").and_then(|j| j.as_bool()).unwrap_or(false);
    Ok((seq, done))
}
