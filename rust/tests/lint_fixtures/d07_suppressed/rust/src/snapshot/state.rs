//! D07 fixture: the same drift, suppressed with reasons.

use crate::util::Json;

pub fn encode(seq: u64) -> Json {
    let mut o = Json::obj();
    o.set("seq", seq);
    // gyges-lint: allow(D07) forward-compat hint consumed by external tooling only
    o.set("lost", 1u64);
    o
}

pub fn decode(o: &Json) -> Result<u64, String> {
    // gyges-lint: allow(D07) written by the v1 encoder this decoder still accepts
    o.req_u64("ghost", "fixture")?;
    o.req_u64("seq", "fixture")
}
