//! D05 fixture: the same global, suppressed with a reason.

use std::sync::atomic::AtomicU8;

// gyges-lint: allow(D05) debug-only knob, set once before any sim starts; never snapshotted
pub static SNEAKY_MODE: AtomicU8 = AtomicU8::new(0);
