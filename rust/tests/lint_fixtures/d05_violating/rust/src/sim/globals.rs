//! D05 fixture: an unregistered process-global mutable static.

use std::sync::atomic::AtomicU8;

pub static SNEAKY_MODE: AtomicU8 = AtomicU8::new(0);
