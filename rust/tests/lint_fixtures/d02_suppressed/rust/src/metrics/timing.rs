//! D02 fixture: the same wall-clock read, suppressed with a reason.

// gyges-lint: allow(D02) opt-in profiling arm; never feeds simulated time or output bytes
use std::time::Instant;

pub fn measure<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now(); // gyges-lint: allow(D02) profiling only, results never serialized
    f();
    t0.elapsed().as_secs_f64()
}
