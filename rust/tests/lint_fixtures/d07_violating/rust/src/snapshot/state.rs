//! D07 fixture: one-sided snapshot schema drift in both directions.

use crate::util::Json;

pub fn encode(seq: u64) -> Json {
    let mut o = Json::obj();
    o.set("seq", seq);
    // Written but never read back: silently dropped on restore.
    o.set("lost", 1u64);
    o
}

pub fn decode(o: &Json) -> Result<u64, String> {
    // Required but never written: every restore of a fresh snapshot fails.
    o.req_u64("ghost", "fixture")?;
    o.req_u64("seq", "fixture")
}
