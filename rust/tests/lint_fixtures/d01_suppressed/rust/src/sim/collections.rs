//! D01 fixture: the same hash collection, suppressed with a reason.

// gyges-lint: allow(D01) scratch map is drained into a sorted Vec before any output
use std::collections::HashMap;

pub fn tally(ids: &[u64]) -> Vec<(u64, u64)> {
    // gyges-lint: allow(D01) scratch map is drained into a sorted Vec before any output
    let mut m: HashMap<u64, u64> = HashMap::new();
    for &id in ids {
        *m.entry(id).or_insert(0) += 1;
    }
    let mut out: Vec<(u64, u64)> = m.into_iter().collect();
    out.sort_unstable();
    out
}
