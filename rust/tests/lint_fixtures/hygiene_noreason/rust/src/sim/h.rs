//! Hygiene fixture: a suppression without a reason still suppresses,
//! but earns S01 (an error under --strict).

pub fn head(v: &[u64]) -> u64 {
    *v.first().unwrap() // gyges-lint: allow(D06)
}
