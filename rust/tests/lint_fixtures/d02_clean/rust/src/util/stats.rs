//! D02 fixture: `util/stats.rs` is on the wall-clock allowlist, so the
//! same read is clean here.

use std::time::Instant;

pub fn measure<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}
