//! D01 fixture: a hash collection in a determinism-critical dir.

use std::collections::HashMap;

pub fn tally(ids: &[u64]) -> HashMap<u64, u64> {
    let mut m = HashMap::new();
    for &id in ids {
        *m.entry(id).or_insert(0) += 1;
    }
    m
}
