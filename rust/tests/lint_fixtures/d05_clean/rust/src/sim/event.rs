//! D05 fixture: `(rust/src/sim/event.rs, DEFAULT_BACKEND)` is a
//! registered site, and `&'static` lifetimes are never statics.

use std::sync::atomic::AtomicU8;

static DEFAULT_BACKEND: AtomicU8 = AtomicU8::new(0);

pub fn backend_name() -> &'static str {
    "calendar"
}
