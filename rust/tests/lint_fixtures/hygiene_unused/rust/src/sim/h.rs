//! Hygiene fixture: a suppression that matches nothing earns S02
//! (an error under --strict) — stale allows must not linger.

// gyges-lint: allow(D06) this line no longer unwraps anything
pub fn head(v: &[u64]) -> Option<u64> {
    v.first().copied()
}
