//! An unlisted test file can opt out with a file-scoped marker.
// gyges-lint: allow(D03) exercised via include! from a registered harness, not a cargo target

#[test]
fn runs_through_the_including_harness() {
    assert_eq!(1 + 1, 2);
}
