//! D01 fixture: ordered collections are always fine.

use std::collections::BTreeMap;

pub fn tally(ids: &[u64]) -> BTreeMap<u64, u64> {
    let mut m = BTreeMap::new();
    for &id in ids {
        *m.entry(id).or_insert(0) += 1;
    }
    m
}
