//! D04 fixture: the same sites, suppressed with reasons.

pub struct Spec {
    pub qps: f64,
    pub seed: u64,
}

impl Spec {
    pub fn fingerprint_into(&self, bytes: &mut Vec<u8>) {
        bytes.extend_from_slice(&self.seed.to_le_bytes());
        // gyges-lint: allow(D04) legacy v1 hash truncated qps; kept for manifest compat
        bytes.extend_from_slice(&(self.qps as u64).to_le_bytes());
        // gyges-lint: allow(D04) constant pad byte, not a config knob
        let pad = 0.25;
        bytes.extend_from_slice(&(pad as u64).to_le_bytes());
    }
}
