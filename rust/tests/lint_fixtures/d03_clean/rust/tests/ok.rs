//! Registered test file: the table and the directory agree.

#[test]
fn registered() {
    assert_eq!(1 + 1, 2);
}
