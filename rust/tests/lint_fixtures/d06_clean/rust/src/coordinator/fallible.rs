//! D06 fixture: errors surface through a Result instead of panicking.

pub fn first_live(ids: &[usize]) -> Result<usize, String> {
    ids.first().copied().ok_or_else(|| "no live instances".to_string())
}

pub fn or_default(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}
