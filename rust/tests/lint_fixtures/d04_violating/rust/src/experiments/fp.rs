//! D04 fixture: f64s reaching a fingerprint without `.to_bits()`.

pub struct Spec {
    pub qps: f64,
    pub seed: u64,
}

impl Spec {
    pub fn fingerprint_into(&self, bytes: &mut Vec<u8>) {
        bytes.extend_from_slice(&self.seed.to_le_bytes());
        // Truncating cast: 1.5 and 1.9 qps alias to the same bytes.
        bytes.extend_from_slice(&(self.qps as u64).to_le_bytes());
        // Float literal mixed straight into the stream.
        let pad = 0.25;
        bytes.extend_from_slice(&(pad as u64).to_le_bytes());
    }
}
