//! Direction 1: a test file with no [[test]] entry — under the
//! explicit-table layout Cargo would silently never compile this.

#[test]
fn never_runs() {
    assert_eq!(1 + 1, 2);
}
