//! D06 fixture: the same calls, suppressed with reasons.

pub fn first_live(ids: &[usize]) -> usize {
    let head = ids.first().unwrap(); // gyges-lint: allow(D06) caller guarantees non-empty
    let checked: Option<usize> = Some(*head);
    // gyges-lint: allow(D06) constructed Some on the previous line
    checked.expect("just wrapped")
}
