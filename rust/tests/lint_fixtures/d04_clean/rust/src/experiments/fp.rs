//! D04 fixture: every f64 hashes its exact bit pattern.

use std::time::Duration;

pub struct Spec {
    pub qps: f64,
    pub seed: u64,
    pub arrival: Duration,
}

impl Spec {
    pub fn fingerprint_into(&self, bytes: &mut Vec<u8>) {
        for v in [self.seed, self.qps.to_bits(), self.arrival.as_secs_f64().to_bits()] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
}
