//! `gyges lint` acceptance tests: the per-rule fixture corpus under
//! `rust/tests/lint_fixtures/` (violating / suppressed-with-reason /
//! clean triplets), the D03 both-directions proof, the suppression
//! hygiene escalation, and the self-check that the repo's own tree
//! lints clean under `--strict`.
//!
//! Fixture layout: every case directory is a miniature repo root
//! (`rust/src/...`, plus `Cargo.toml` + `rust/tests/` for D03). The
//! fixture `.rs` files are deliberately NOT cargo targets — with the
//! explicit `[[test]]` table nothing under `lint_fixtures/` ever
//! compiles, so violating fixtures can contain arbitrary bad code.

use std::path::PathBuf;

use gyges::analysis::report::exit_code;
use gyges::analysis::{run_lint, Finding, Severity};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn lint_fixture(name: &str) -> Vec<Finding> {
    let root = repo_root().join("rust").join("tests").join("lint_fixtures").join(name);
    assert!(root.is_dir(), "missing fixture root {}", root.display());
    run_lint(&root).expect("fixture tree lints")
}

fn rule_list(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule).collect()
}

/// Violating fixture: at least one finding, every finding carries the
/// expected rule at Error severity, and the exit code is nonzero even
/// without --strict.
fn assert_violating(name: &str, rule: &str) -> Vec<Finding> {
    let findings = lint_fixture(name);
    assert!(!findings.is_empty(), "{name}: expected findings");
    for f in &findings {
        assert_eq!(f.rule, rule, "{name}: unexpected finding {f:?}");
        assert_eq!(f.severity, Severity::Error, "{name}: {f:?}");
    }
    assert_eq!(exit_code(&findings, false), 1, "{name}");
    assert_eq!(exit_code(&findings, true), 1, "{name}");
    findings
}

/// Suppressed/clean fixture: zero findings of any kind (a reasoned,
/// used suppression leaves no residue, so strict mode stays green).
fn assert_silent(name: &str) {
    let findings = lint_fixture(name);
    assert!(findings.is_empty(), "{name}: expected no findings, got {findings:?}");
    assert_eq!(exit_code(&findings, true), 0, "{name}");
}

#[test]
fn d01_hash_collections() {
    let f = assert_violating("d01_violating", "D01");
    assert!(f.iter().any(|x| x.path == "rust/src/sim/collections.rs"));
    assert_silent("d01_suppressed");
    assert_silent("d01_clean");
}

#[test]
fn d02_wall_clock() {
    let f = assert_violating("d02_violating", "D02");
    assert!(f.iter().all(|x| x.path == "rust/src/metrics/timing.rs"));
    assert_silent("d02_suppressed");
    assert_silent("d02_clean"); // same Instant::now, allowlisted file
}

#[test]
fn d03_test_table_both_directions() {
    let f = assert_violating("d03_violating", "D03");
    // Direction 1: unlisted test file => error anchored at the file.
    assert!(
        f.iter().any(|x| x.path == "rust/tests/orphan.rs"),
        "missing orphan-file direction: {f:?}"
    );
    // Direction 2: dangling [[test]] path => error anchored in Cargo.toml.
    assert!(
        f.iter().any(|x| x.path == "Cargo.toml" && x.msg.contains("gone")),
        "missing dangling-path direction: {f:?}"
    );
    assert_silent("d03_suppressed");
    assert_silent("d03_clean");
}

#[test]
fn d04_fingerprint_to_bits() {
    let f = assert_violating("d04_violating", "D04");
    assert_eq!(f.len(), 2, "qps cast + float literal: {f:?}");
    assert_silent("d04_suppressed");
    assert_silent("d04_clean");
}

#[test]
fn d05_global_registry() {
    let f = assert_violating("d05_violating", "D05");
    assert!(f[0].msg.contains("SNEAKY_MODE"));
    assert_silent("d05_suppressed");
    assert_silent("d05_clean"); // registered site + &'static lifetimes
}

#[test]
fn d06_unwrap_expect() {
    let f = assert_violating("d06_violating", "D06");
    assert_eq!(f.len(), 2, "unwrap + expect, test module excluded: {f:?}");
    assert_silent("d06_suppressed");
    assert_silent("d06_clean");
}

#[test]
fn d07_snapshot_key_parity() {
    let f = assert_violating("d07_violating", "D07");
    assert!(f.iter().any(|x| x.msg.contains("lost")), "write-without-read: {f:?}");
    assert!(f.iter().any(|x| x.msg.contains("ghost")), "read-without-write: {f:?}");
    assert_silent("d07_suppressed");
    assert_silent("d07_clean");
}

#[test]
fn hygiene_warnings_escalate_under_strict() {
    let noreason = lint_fixture("hygiene_noreason");
    assert_eq!(rule_list(&noreason), vec!["S01"]);
    assert_eq!(noreason[0].severity, Severity::Warning);
    assert_eq!(exit_code(&noreason, false), 0);
    assert_eq!(exit_code(&noreason, true), 1);

    let unused = lint_fixture("hygiene_unused");
    assert_eq!(rule_list(&unused), vec!["S02"]);
    assert_eq!(exit_code(&unused, false), 0);
    assert_eq!(exit_code(&unused, true), 1);
}

/// The repo's own tree must lint completely clean — zero errors AND
/// zero warnings — so the blocking CI job can run `--strict` from day
/// one. Every pre-existing violation is either fixed or carries a
/// reasoned inline suppression (inventory: PERF.md "Determinism
/// contract").
#[test]
fn self_check_repo_tree_is_clean_under_strict() {
    let root = repo_root();
    assert!(root.join("Cargo.toml").is_file(), "test must run from the crate root");
    assert!(root.join("rust").join("src").is_dir());
    let findings = run_lint(&root).expect("repo tree lints");
    assert!(
        findings.is_empty(),
        "repo tree has lint findings:\n{}",
        gyges::analysis::report::render_text(&findings, true)
    );
    assert_eq!(exit_code(&findings, true), 0);
}
