//! The shard layer's contract (ISSUE 3 acceptance criteria):
//!
//! 1. For ANY shard count N (including N > job count, so some shards are
//!    empty, and job lists containing error-carrying runs), running every
//!    shard and merging reproduces `run_sweep_serial`'s JSONL bytes
//!    exactly, regardless of the order shards are handed to the merge.
//! 2. The merge fails loudly on a missing, duplicated, foreign (different
//!    job list), or tampered shard — never a silently partial figure.
//! 3. The on-disk form (`write_shard` / `read_shard_dir` / the
//!    `sweep-shard`+`sweep-merge` CLI path) round-trips the same bytes.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::SystemKind;
use gyges::experiments::shard::{
    merge_shards, read_shard_dir, run_sweep_shard, write_shard, ShardError, ShardInput, ShardSpec,
};
use gyges::experiments::sweep::{results_to_jsonl, run_sweep_serial, SweepJob};
use gyges::workload::Trace;
use std::sync::Arc;

/// Three policies on a hybrid trace plus one event-capped job, so every
/// shard count exercises both healthy and error-carrying rows.
fn mixed_jobs() -> Vec<SweepJob> {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let trace = Arc::new(Trace::hybrid_paper(3, 45.0));
    let mut jobs: Vec<SweepJob> = [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges]
        .into_iter()
        .map(|p| {
            SweepJob::new(
                format!("hybrid/{}", p.name()),
                cfg.clone(),
                SystemKind::Gyges,
                Some(p.into()),
                Arc::clone(&trace),
            )
        })
        .collect();
    let mut capped = cfg.clone();
    capped.max_events = 10;
    jobs.push(SweepJob::new(
        "capped",
        capped,
        SystemKind::Gyges,
        Some(Policy::Gyges.into()),
        Arc::clone(&trace),
    ));
    jobs
}

/// Cheap job list (every run cut by a tiny event cap) for the negative
/// tests, where sim cost is irrelevant.
fn tiny_jobs(key_prefix: &str) -> Vec<SweepJob> {
    tiny_jobs_at(key_prefix, 30.0)
}

fn tiny_jobs_at(key_prefix: &str, horizon_s: f64) -> Vec<SweepJob> {
    let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    cfg.max_events = 10;
    let trace = Arc::new(Trace::hybrid_paper(5, horizon_s));
    (0..3)
        .map(|i| {
            SweepJob::new(
                format!("{key_prefix}{i}"),
                cfg.clone(),
                SystemKind::Gyges,
                Some(Policy::Gyges.into()),
                Arc::clone(&trace),
            )
        })
        .collect()
}

fn all_shards(sweep: &str, jobs: &[SweepJob], n: usize) -> Vec<ShardInput> {
    (0..n)
        .map(|k| {
            let (payload, manifest) = run_sweep_shard(sweep, jobs, ShardSpec::new(k, n).unwrap());
            ShardInput { manifest, payload }
        })
        .collect()
}

#[test]
fn sharded_merge_is_byte_identical_for_every_shard_count() {
    let jobs = mixed_jobs();
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    assert!(!serial.is_empty());
    for n in 1..=jobs.len() + 2 {
        let mut inputs = all_shards("mixed", &jobs, n);
        // Arrival order must not matter (CI artifact downloads are not
        // ordered); N > jobs.len() makes the tail shards empty.
        inputs.reverse();
        let merged = merge_shards(&inputs).unwrap_or_else(|e| panic!("N={n}: {e}"));
        assert_eq!(merged, serial, "N={n}: sharded+merged != serial bytes");
    }
}

#[test]
fn error_rows_survive_the_merge() {
    let jobs = mixed_jobs();
    let merged = merge_shards(&all_shards("mixed", &jobs, 3)).unwrap();
    let capped_row = merged
        .lines()
        .find(|l| l.contains("\"key\":\"capped\""))
        .expect("capped job row present");
    assert!(
        capped_row.contains("event cap"),
        "the event-capped job's error must ride through sharding: {capped_row}"
    );
}

#[test]
fn empty_job_list_merges_to_empty_output() {
    let serial = results_to_jsonl(&run_sweep_serial(&[]));
    for n in 1..=3 {
        let merged = merge_shards(&all_shards("empty", &[], n)).unwrap();
        assert_eq!(merged, serial);
        assert!(merged.is_empty());
    }
}

#[test]
fn merge_rejects_missing_shard() {
    let jobs = tiny_jobs("t");
    let mut inputs = all_shards("tiny", &jobs, 3);
    inputs.remove(1);
    assert_eq!(merge_shards(&inputs), Err(ShardError::MissingShard(1)));
}

#[test]
fn merge_rejects_duplicated_shard() {
    let jobs = tiny_jobs("t");
    let mut inputs = all_shards("tiny", &jobs, 3);
    inputs[2] = inputs[0].clone();
    assert_eq!(merge_shards(&inputs), Err(ShardError::DuplicateShard(0)));
}

#[test]
fn merge_rejects_shard_from_a_different_job_list() {
    let mut inputs = all_shards("tiny", &tiny_jobs("t"), 2);
    // Same sweep name, same shape — but a different canonical key list.
    let foreign = all_shards("tiny", &tiny_jobs("other"), 2);
    inputs[1] = foreign[1].clone();
    match merge_shards(&inputs) {
        Err(ShardError::Mismatch { field: "jobs_hash", .. }) => {}
        other => panic!("expected jobs_hash mismatch, got {other:?}"),
    }
}

#[test]
fn merge_rejects_same_keys_at_a_different_horizon() {
    // Identical job keys, different trace horizon: without the job-list
    // fingerprint these would merge into a silently mixed figure.
    let mut inputs = all_shards("tiny", &tiny_jobs_at("t", 30.0), 2);
    let foreign = all_shards("tiny", &tiny_jobs_at("t", 45.0), 2);
    inputs[1] = foreign[1].clone();
    match merge_shards(&inputs) {
        Err(ShardError::Mismatch { field: "jobs_hash", .. }) => {}
        res => panic!("expected jobs_hash mismatch, got {res:?}"),
    }
}

#[test]
fn merge_rejects_tampered_payload() {
    let jobs = tiny_jobs("t");
    let mut inputs = all_shards("tiny", &jobs, 2);
    // Simulate a corrupted / hand-edited artifact download.
    inputs[0].payload.push(' ');
    match merge_shards(&inputs) {
        Err(ShardError::PayloadHash { shard: 0, .. }) => {}
        other => panic!("expected payload-hash rejection, got {other:?}"),
    }
}

#[test]
fn merge_rejects_mismatched_shard_counts() {
    let jobs = tiny_jobs("t");
    let a = all_shards("tiny", &jobs, 2);
    let b = all_shards("tiny", &jobs, 3);
    let inputs = vec![a[0].clone(), b[1].clone()];
    match merge_shards(&inputs) {
        Err(ShardError::Mismatch { field: "shard_count", .. }) => {}
        other => panic!("expected shard_count mismatch, got {other:?}"),
    }
}

#[test]
fn shard_files_roundtrip_through_a_directory() {
    let jobs = tiny_jobs("t");
    let serial = results_to_jsonl(&run_sweep_serial(&jobs));
    let dir = std::env::temp_dir().join(format!("gyges-sharding-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for k in 0..2 {
        let w = write_shard(&dir, "tiny", &jobs, ShardSpec::new(k, 2).unwrap()).unwrap();
        assert!(w.data_path.exists() && w.manifest_path.exists());
    }
    let inputs = read_shard_dir(&dir, "tiny").unwrap();
    assert_eq!(inputs.len(), 2);
    assert_eq!(merge_shards(&inputs).unwrap(), serial);
    // A second sweep's files in the same directory are not picked up.
    write_shard(&dir, "tiny2", &jobs, ShardSpec::full()).unwrap();
    assert_eq!(read_shard_dir(&dir, "tiny").unwrap().len(), 2);
    // Renaming a foreign shard to match the requested prefix cannot
    // smuggle it in: the manifest's own sweep field is checked too.
    for ext in ["jsonl", "manifest.json"] {
        std::fs::rename(
            dir.join(format!("tiny2-shard-0of1.{ext}")),
            dir.join(format!("evil-shard-0of1.{ext}")),
        )
        .unwrap();
    }
    match read_shard_dir(&dir, "evil") {
        Err(ShardError::Mismatch { field: "sweep", .. }) => {}
        res => panic!("expected sweep mismatch on renamed shard, got {res:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
