//! Deterministic snapshot/resume guarantees (PR 5):
//!
//! 1. A run paused at ANY point, serialized to JSON, parsed back, and
//!    resumed produces output byte-identical to the uninterrupted run —
//!    property-tested over random workloads and random checkpoint
//!    instants, plus targeted adversarial instants: mid-transform,
//!    during a backlog retry cooldown, between a segment boundary and
//!    its first arrival, and just before an event-cap cut.
//! 2. The checkpointed sweep runner (`gyges snapshot` / `resume`)
//!    survives a deliberate mid-job kill and reassembles the exact
//!    serial-driver bytes; tampered state files are rejected loudly.
//! 3. The branch explorer forks one snapshot into policy variants whose
//!    divergence report is deterministic across repeated runs, and
//!    whose parent branch equals the uninterrupted timeline.

use gyges::config::{ClusterConfig, ModelConfig, Policy, PolicyId};
use gyges::coordinator::{ClusterSim, RunStatus, SimOutcome, SystemKind};
use gyges::experiments::branch::{default_branches, explore};
use gyges::experiments::sweep::{build_job_sim, outcome_to_result, results_to_jsonl};
use gyges::experiments::sweep::run_sweep_serial;
use gyges::experiments::named_sweep_jobs;
use gyges::sim::SimTime;
use gyges::snapshot::runner::{resume_run, run_checkpointed, RunOutcome, RunPlan};
use gyges::snapshot::state::{RunContext, SimSnapshot};
use gyges::util::proptest;
use gyges::util::Prng;
use gyges::workload::{ChunkedTrace, LongBursts, ProductionStream, StreamSource, Trace};
use gyges::workload::{SloClass, SloMix, TraceRequest, TraceSegment, TraceSource};
use std::path::PathBuf;

fn cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gyges-snapshot-{name}-{}", std::process::id()))
}

/// Full observable state of one run (everything a sweep row serializes).
fn sig(out: &SimOutcome) -> String {
    format!(
        "{}|{:?}|{:?}|{:?}",
        out.report.to_json(),
        out.counters,
        out.recorder.tps_series(),
        out.error
    )
}

/// Pause `sim` at `at`, roundtrip its state through the JSON envelope,
/// and return the restored simulator — or `None` if the run finished
/// before the checkpoint instant.
fn checkpoint_roundtrip(
    sim: &mut ClusterSim,
    at: SimTime,
    cfg: &ClusterConfig,
) -> Option<ClusterSim> {
    match sim.run_until(Some(at)) {
        RunStatus::Done => None,
        RunStatus::Paused => {
            let snap = sim.snapshot().expect("paused run must snapshot");
            let text = snap.to_string_pretty();
            let parsed = SimSnapshot::parse(&text).expect("snapshot must parse");
            assert_eq!(parsed, snap, "JSON roundtrip must be lossless");
            Some(ClusterSim::from_snapshot(cfg.clone(), &parsed).expect("restore must succeed"))
        }
    }
}

#[test]
fn prop_resume_is_byte_identical_at_random_checkpoint_times() {
    proptest::forall(
        "resume == uninterrupted",
        proptest::Config { cases: 8, seed: 0x5AAB_5 },
        |rng: &mut Prng| {
            let seed = rng.next();
            let horizon = 30.0 + rng.f64() * 40.0;
            let t1 = 1.0 + rng.f64() * horizon;
            let t2 = t1 + rng.f64() * horizon;
            let streamed = rng.chance(0.5);
            (seed, horizon, t1, t2, streamed)
        },
        |&(seed, horizon, t1, t2, streamed)| {
            let build = || -> ClusterSim {
                if streamed {
                    let trace = Trace::hybrid_paper(seed, horizon);
                    let source = ChunkedTrace::with_horizon(trace, 7.5, horizon);
                    ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(source))
                } else {
                    ClusterSim::new(cfg(), SystemKind::Gyges, Trace::hybrid_paper(seed, horizon))
                }
            };
            let reference = sig(&build().run());
            let mut sim = build();
            // Two checkpoints at random instants; each roundtrips the
            // full state through JSON.
            for t in [t1, t2] {
                match checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(t), &cfg()) {
                    Some(restored) => sim = restored,
                    None => break,
                }
            }
            let _ = sim.run_until(None);
            let resumed = sig(&sim.finish());
            gyges::prop_assert!(
                resumed == reference,
                "resumed run diverged (seed {seed:#x}, horizon {horizon:.1}, t1 {t1:.2}, \
                 t2 {t2:.2}):\n  ref: {reference}\n  got: {resumed}"
            );
            Ok(())
        },
    );
}

/// A trace that forces a scale-up (one 50K long amid shorts).
fn transforming_trace() -> Trace {
    let mut trace = Trace::default();
    for i in 0..30u64 {
        trace.requests.push(TraceRequest {
            id: 0,
            arrival: SimTime::from_secs_f64(i as f64 * 0.5),
            input_len: 1000,
            output_len: 60,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    trace.requests.push(TraceRequest {
        id: 0,
        arrival: SimTime::from_secs_f64(1.0),
        input_len: 50_000,
        output_len: 64,
        class: SloClass::Interactive,
        prefix: Vec::new(),
    });
    trace.sort_and_renumber();
    trace
}

#[test]
fn resume_mid_transform_is_byte_identical() {
    let reference = sig(&ClusterSim::new(cfg(), SystemKind::Gyges, transforming_trace()).run());
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, transforming_trace());
    let mut restored = None;
    let mut t = 0.25;
    while t < 120.0 {
        if sim.run_until(Some(SimTime::from_secs_f64(t))) == RunStatus::Done {
            break;
        }
        if sim.in_flight_transforms() > 0 {
            restored = checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(t), &cfg());
            break;
        }
        t += 0.25;
    }
    let mut sim = restored.expect("must capture an in-flight transformation");
    assert!(sim.in_flight_transforms() > 0, "restored state must still be mid-transform");
    let _ = sim.run_until(None);
    assert_eq!(sig(&sim.finish()), reference, "mid-transform resume diverged");
}

/// Steady shorts plus one request beyond even TP4's max sequence: the
/// long can never be placed (`needed_tp` = None → Defer), so EVERY
/// backlog drain pass is a no-progress pass — each finish arms the
/// retry cooldown and schedules a wakeup, guaranteeing armed-cooldown
/// intervals for the adversarial checkpoint to land in. (Liveness
/// still holds: once the shorts drain, the final no-progress pass has
/// no other pending events and stops re-arming.)
fn overload_trace() -> Trace {
    let mut trace = Trace::default();
    for i in 0..200u64 {
        trace.requests.push(TraceRequest {
            id: 0,
            arrival: SimTime::from_secs_f64(i as f64 * 0.5),
            input_len: 1000,
            output_len: 60,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    trace.requests.push(TraceRequest {
        id: 0,
        arrival: SimTime::from_secs_f64(0.2),
        input_len: 200_000, // beyond max_seq(4): unserveable, defers forever
        output_len: 64,
        class: SloClass::Interactive,
        prefix: Vec::new(),
    });
    trace.sort_and_renumber();
    trace
}

#[test]
fn resume_during_backlog_cooldown_is_byte_identical() {
    let reference_out = ClusterSim::new(cfg(), SystemKind::Gyges, overload_trace()).run();
    assert!(
        reference_out.counters.backlog_wakeup_events > 0,
        "scenario must actually arm the retry cooldown (got {:?})",
        reference_out.counters
    );
    let reference = sig(&reference_out);
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, overload_trace());
    let mut restored = None;
    let mut t = 0.02;
    while t < 400.0 {
        if sim.run_until(Some(SimTime::from_secs_f64(t))) == RunStatus::Done {
            break;
        }
        if sim.backlog_len() > 0 && sim.backlog_cooldown_deadline() > sim.sim_now() {
            restored = checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(t), &cfg());
            break;
        }
        t += 0.02;
    }
    let mut sim = restored.expect("must capture an armed backlog cooldown");
    assert!(sim.backlog_len() > 0, "restored state must still hold the backlog");
    let _ = sim.run_until(None);
    assert_eq!(sig(&sim.finish()), reference, "backlog-cooldown resume diverged");
}

#[test]
fn resume_between_segment_boundary_and_first_arrival() {
    // Arrivals at 1 s and 11 s, 5 s windows: the 10.5 s checkpoint sits
    // after the [10, 15) boundary but before its first arrival.
    let mut trace = Trace::default();
    for (id, at) in [(0u64, 1.0), (1, 11.0)] {
        trace.requests.push(TraceRequest {
            id,
            arrival: SimTime::from_secs_f64(at),
            input_len: 2000,
            output_len: 150,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    let build = || {
        let source = ChunkedTrace::with_horizon(
            Trace { requests: trace.requests.clone() },
            5.0,
            15.0,
        );
        ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(source))
    };
    let reference = sig(&build().run());
    let mut sim = build();
    let restored = checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(10.5), &cfg())
        .expect("run must still be live at 10.5 s (arrival at 11 s pending)");
    let mut sim = restored;
    let _ = sim.run_until(None);
    assert_eq!(sig(&sim.finish()), reference, "segment-boundary resume diverged");
}

#[test]
fn resume_through_event_cap_cut_is_byte_identical() {
    let mut capped = cfg();
    capped.max_events = 400; // cuts the overload trace long before drain
    let reference =
        sig(&ClusterSim::new(capped.clone(), SystemKind::Gyges, overload_trace()).run());
    assert!(reference.contains("EventCapExceeded"), "reference must actually hit the cap");
    let mut sim = ClusterSim::new(capped.clone(), SystemKind::Gyges, overload_trace());
    let restored = checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(0.1), &capped)
        .expect("cap must not be reached by 0.1 s");
    let mut sim = restored;
    let _ = sim.run_until(None);
    assert_eq!(
        sig(&sim.finish()),
        reference,
        "resume must reproduce the event-cap cut exactly (cap and pending count included)"
    );
}

#[test]
fn resume_of_bursty_production_stream_is_byte_identical() {
    let spec = ProductionStream {
        seed: 0xF1627B,
        qps: 2.0,
        segment_s: 15.0,
        horizon_s: 90.0,
        longs: Some(LongBursts::paper()),
        slo: None,
        prefix: None,
    };
    let build = || {
        let source = StreamSource::new(spec.clone());
        ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(source))
    };
    let reference = sig(&build().run());
    let mut sim = build();
    // Checkpoint mid-stream: the cursor is (spec, next, next_id) — the
    // bursty phase state reconstructs from the seed alone.
    let restored = checkpoint_roundtrip(&mut sim, SimTime::from_secs_f64(40.0), &cfg())
        .expect("90 s bursty stream must still be live at 40 s");
    let mut sim = restored;
    let _ = sim.run_until(None);
    assert_eq!(sig(&sim.finish()), reference, "bursty-stream resume diverged");
}

#[test]
fn resume_of_composed_slo_policy_is_byte_identical_and_serializes_pipeline_state() {
    // PR 8: a composed (-slo-admit) pipeline policy on an overloaded,
    // SLO-classed stream. The snapshot must carry the recursive
    // `pipeline` PolicyState kind (schema v4) and the class tags of
    // queued batch work — the state preemption-by-requeue shuffles —
    // and kill/resume at arbitrary instants must reproduce the
    // uninterrupted run's bytes, admission drops and preemptions
    // included.
    let cfg = gyges::experiments::slo::slo_cfg();
    let id = PolicyId { base: Policy::Gyges, cache: false, slo: true, admit: true };
    let spec = ProductionStream {
        seed: 0x510_C1A5,
        qps: 10.0,
        segment_s: 15.0,
        horizon_s: 30.0,
        longs: None,
        slo: Some(SloMix { interactive_frac: 0.9 }),
        prefix: None,
    };
    let build = || {
        let source = StreamSource::new(spec.clone());
        ClusterSim::with_source(cfg.clone(), SystemKind::Gyges, Box::new(source)).with_policy(id)
    };
    let reference = sig(&build().run());
    let mut sim = build();
    let (mut saw_pipeline, mut saw_batch) = (false, false);
    let mut t = 1.0;
    while t < 300.0 {
        match sim.run_until(Some(SimTime::from_secs_f64(t))) {
            RunStatus::Done => break,
            RunStatus::Paused => {
                let snap = sim.snapshot().expect("paused run must snapshot");
                let text = snap.to_string_pretty();
                saw_pipeline |= text.contains("\"pipeline\"");
                saw_batch |= text.contains("\"batch\"");
                let parsed = SimSnapshot::parse(&text).expect("snapshot must parse");
                assert_eq!(parsed, snap, "JSON roundtrip must be lossless");
                sim = ClusterSim::from_snapshot(cfg.clone(), &parsed).expect("restore");
            }
        }
        t += 1.0;
    }
    let _ = sim.run_until(None);
    let resumed = sig(&sim.finish());
    assert!(saw_pipeline, "composed policy must serialize as the pipeline PolicyState kind");
    assert!(saw_batch, "walk must checkpoint with batch-class work captured in some snapshot");
    assert_eq!(resumed, reference, "composed-policy resume diverged from the uninterrupted run");
}

#[test]
fn snapshot_refuses_unsnapshottable_sources_and_config_drift() {
    // A custom test-double source has no cursor: snapshot must refuse,
    // not guess.
    struct Opaque(bool);
    impl TraceSource for Opaque {
        fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
            if self.0 {
                return None;
            }
            self.0 = true;
            Some(Ok(TraceSegment {
                index: 0,
                start: SimTime::ZERO,
                end: SimTime::from_secs_f64(5.0),
                requests: vec![TraceRequest {
                    id: 0,
                    arrival: SimTime::from_secs_f64(1.0),
                    input_len: 1000,
                    output_len: 500,
                    class: SloClass::Interactive,
                    prefix: Vec::new(),
                }],
            }))
        }
    }
    let mut sim = ClusterSim::with_source(cfg(), SystemKind::Gyges, Box::new(Opaque(false)));
    assert_eq!(sim.run_until(Some(SimTime::from_secs_f64(2.0))), RunStatus::Paused);
    let err = sim.snapshot().unwrap_err();
    assert!(err.contains("does not support snapshotting"), "{err}");

    // Restoring under a different config is refused by the fingerprint.
    let mut sim = ClusterSim::new(cfg(), SystemKind::Gyges, transforming_trace());
    assert_eq!(sim.run_until(Some(SimTime::from_secs_f64(2.0))), RunStatus::Paused);
    let snap = sim.snapshot().unwrap();
    let mut other = cfg();
    other.min_dwell_s += 1.0;
    let err = ClusterSim::from_snapshot(other, &snap).unwrap_err();
    assert!(err.contains("fingerprint"), "{err}");
}

#[test]
fn checkpointed_runner_survives_kill_and_reassembles_serial_bytes() {
    let dir = tmp("runner");
    let out = dir.join("merged.jsonl");
    let _ = std::fs::remove_dir_all(&dir);
    let plan = RunPlan {
        sweep: "fig12-qwen".into(),
        horizon_s: 30.0,
        every_s: 5.0,
        dir: dir.clone(),
        out: out.clone(),
        stream_dir: None,
        stop_after: Some(2),
    };
    // Stage 1: "dies" (deliberately) after two checkpoints, mid job 0.
    match run_checkpointed(&plan).unwrap() {
        RunOutcome::Paused { checkpoints, next_job, at } => {
            assert_eq!(checkpoints, 2);
            assert_eq!(next_job, 0);
            assert!(at > SimTime::ZERO);
        }
        other => panic!("expected a pause, got {other:?}"),
    }
    assert!(!out.exists(), "no merged output before completion");
    // Stage 2: resume to completion.
    match resume_run(&dir, None).unwrap() {
        RunOutcome::Completed { rows, .. } => assert_eq!(rows, 3),
        other => panic!("expected completion, got {other:?}"),
    }
    let merged = std::fs::read_to_string(&out).unwrap();
    let canonical = named_sweep_jobs("fig12-qwen", 30.0).unwrap();
    let serial = results_to_jsonl(&run_sweep_serial(&canonical));
    assert_eq!(merged, serial, "checkpoint/kill/resume must reproduce the serial bytes");
    // Resuming a completed run is an idempotent re-seal.
    match resume_run(&dir, None).unwrap() {
        RunOutcome::Completed { rows, .. } => assert_eq!(rows, 3),
        other => panic!("expected idempotent completion, got {other:?}"),
    }
    assert_eq!(std::fs::read_to_string(&out).unwrap(), serial);
    // A tampered completed-row file is rejected loudly.
    let victim = dir.join("rows-00000.jsonl");
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[0] ^= 1;
    std::fs::write(&victim, &bytes).unwrap();
    let err = resume_run(&dir, None).unwrap_err();
    assert!(err.contains("payload hash"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn branch_explorer_is_deterministic_and_parent_matches_uninterrupted_run() {
    let jobs = named_sweep_jobs("fig12-qwen", 30.0).unwrap();
    let job_index = 2; // qwen2.5-32b under the Gyges policy
    let job = &jobs[job_index];
    assert_eq!(job.key, "qwen2.5-32b/gyges");
    let mut sim = build_job_sim(job);
    assert_eq!(sim.run_until(Some(SimTime::from_secs_f64(15.0))), RunStatus::Paused);
    let snap = sim
        .snapshot_with_context(Some(RunContext {
            sweep: "fig12-qwen".into(),
            horizon_s: 30.0,
            job_index,
            key: job.key.clone(),
            stream_dir: None,
        }))
        .unwrap();
    let branches = default_branches();
    assert!(branches.len() >= 3, "acceptance: at least 3 policy variants");
    let a = explore(&job.cfg, &snap, &branches, 4).unwrap().to_string();
    let b = explore(&job.cfg, &snap, &branches, 2).unwrap().to_string();
    assert_eq!(a, b, "divergence report must be deterministic across runs and thread counts");
    // The parent branch IS the uninterrupted timeline.
    let report = gyges::util::Json::parse(&a).unwrap();
    let parent = report.get("parent").unwrap().to_string();
    let uninterrupted = outcome_to_result("parent", build_job_sim(job).run()).to_json().to_string();
    assert_eq!(parent, uninterrupted, "parent continuation must equal the never-paused run");
    // Branches diverge from the parent in at least one variant (the
    // whole point of a warm-state ablation).
    let rows = report.get("branches").unwrap().as_arr().unwrap();
    assert!(
        rows.iter().any(|b| b.get("row").map(|r| r.to_string()) != Some(parent.clone())),
        "at least one branch must diverge from the parent timeline"
    );
}
