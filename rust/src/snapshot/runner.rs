//! Checkpointed sweep runner: `gyges snapshot` / `gyges resume`.
//!
//! Runs a named sweep's canonical job list serially (the serial order is
//! the byte-identity reference), checkpointing the in-progress job's
//! complete simulator state every `every_s` simulated seconds. Killing
//! the process at ANY point loses at most the work since the last
//! checkpoint; `gyges resume` restores the newest checkpoint and
//! finishes the run, producing output byte-identical to an
//! uninterrupted `run_sweep_serial` + `results_to_jsonl` (the same
//! bytes `gyges sweep-shard <sweep> --shard 0/1` writes — CI `cmp`s the
//! two).
//!
//! On-disk layout under the state directory:
//!
//!   `snapshot-run.json`        run manifest (schema v1): sweep,
//!                              horizon, cadence, job-list fingerprint,
//!                              completed-job row hashes
//!   `rows-XXXXX.jsonl`         one finished job's result row
//!   `job-XXXXX.snapshot.json`  newest checkpoint of the in-progress
//!                              job (tmp+rename, so a kill mid-write
//!                              leaves the previous checkpoint valid)
//!
//! `--stop-after K` exits with a distinct status after writing K
//! checkpoints — the deliberate-kill hook the CI `snapshot-verify` job
//! uses, and the stage budget that splits an hour-horizon run across
//! chained CI jobs.

use crate::coordinator::{ClusterSim, RunStatus};
use crate::experiments::launch::streamed_named_jobs;
use crate::experiments::shard::job_list_hash;
use crate::experiments::sweep::{build_job_sim, outcome_to_result, SweepJob};
use crate::experiments::{named_sweep_default_horizon, named_sweep_jobs, NAMED_SWEEPS};
use crate::sim::clock::{SimDuration, SimTime};
use crate::snapshot::state::{RunContext, SimSnapshot};
use crate::util::hash::{fnv1a, hex64};
use crate::util::json::Json;
use crate::util::Args;
use std::path::{Path, PathBuf};

/// Run-manifest schema version.
pub const RUN_SCHEMA_VERSION: u64 = 1;

/// Everything `gyges snapshot` needs to drive one checkpointed sweep.
#[derive(Clone, Debug)]
pub struct RunPlan {
    pub sweep: String,
    pub horizon_s: f64,
    /// Checkpoint cadence in simulated seconds.
    pub every_s: f64,
    /// State directory (manifest + rows + checkpoints).
    pub dir: PathBuf,
    /// Final merged JSONL path.
    pub out: PathBuf,
    /// Replay traces from a `gyges trace-gen` segment root instead of
    /// materializing them (O(segment) trace memory, as `sweep-shard
    /// --stream-dir`).
    pub stream_dir: Option<PathBuf>,
    /// Exit (status 3) after writing this many checkpoints — the
    /// deliberate-kill / stage-budget hook.
    pub stop_after: Option<usize>,
}

/// What a runner invocation did.
#[derive(Clone, Debug, PartialEq)]
pub enum RunOutcome {
    Completed { rows: usize, bytes: usize },
    /// Paused after `checkpoints` checkpoint writes; job `next_job` is
    /// parked at simulated time `at`. Resume with `gyges resume`.
    Paused { checkpoints: usize, next_job: usize, at: SimTime },
}

// ---------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct DoneJob {
    index: usize,
    payload_hash: String,
}

#[derive(Clone, Debug)]
struct RunManifest {
    sweep: String,
    horizon_s: f64,
    every_s: f64,
    stream_dir: Option<String>,
    jobs_hash: String,
    total_jobs: usize,
    out: String,
    done: Vec<DoneJob>,
}

impl RunManifest {
    fn path(dir: &Path) -> PathBuf {
        dir.join("snapshot-run.json")
    }

    fn rows_name(index: usize) -> String {
        format!("rows-{index:05}.jsonl")
    }

    fn snapshot_name(index: usize) -> String {
        format!("job-{index:05}.snapshot.json")
    }

    fn to_json(&self) -> Json {
        let done: Vec<Json> = self
            .done
            .iter()
            .map(|d| {
                let mut o = Json::obj();
                o.set("index", d.index).set("payload_hash", d.payload_hash.as_str());
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("schema_version", RUN_SCHEMA_VERSION)
            .set("kind", "snapshot-run")
            .set("sweep", self.sweep.as_str())
            .set("horizon_s", self.horizon_s)
            .set("every_s", self.every_s)
            .set(
                "stream_dir",
                self.stream_dir.as_deref().map(Json::from).unwrap_or(Json::Null),
            )
            .set("jobs_hash", self.jobs_hash.as_str())
            .set("total_jobs", self.total_jobs)
            .set("out", self.out.as_str())
            .set("done", Json::Arr(done));
        o
    }

    fn from_json(j: &Json) -> Result<RunManifest, String> {
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_u64())
            .ok_or("run manifest: missing schema_version")?;
        if version != RUN_SCHEMA_VERSION {
            return Err(format!(
                "run manifest: schema_version {version} unsupported (this reads \
                 v{RUN_SCHEMA_VERSION})"
            ));
        }
        if j.get("kind").and_then(|v| v.as_str()) != Some("snapshot-run") {
            return Err("run manifest: not a snapshot-run document".into());
        }
        let s = |k: &str| j.req_str(k, "run manifest").map(str::to_string);
        let f = |k: &str| j.req_f64(k, "run manifest");
        let mut done = Vec::new();
        for (k, d) in j
            .get("done")
            .and_then(|v| v.as_arr())
            .ok_or("run manifest: missing done array")?
            .iter()
            .enumerate()
        {
            let index = d
                .get("index")
                .and_then(|v| v.as_u64())
                .ok_or("run manifest: bad done index")? as usize;
            if index != k {
                return Err(format!(
                    "run manifest: done jobs are not a prefix (entry {k} has index {index})"
                ));
            }
            done.push(DoneJob {
                index,
                payload_hash: d
                    .get("payload_hash")
                    .and_then(|v| v.as_str())
                    .ok_or("run manifest: bad done payload_hash")?
                    .to_string(),
            });
        }
        Ok(RunManifest {
            sweep: s("sweep")?,
            horizon_s: f("horizon_s")?,
            every_s: f("every_s")?,
            stream_dir: match j.get("stream_dir") {
                None | Some(Json::Null) => None,
                Some(v) => {
                    Some(v.as_str().ok_or("run manifest: bad stream_dir")?.to_string())
                }
            },
            jobs_hash: s("jobs_hash")?,
            total_jobs: j
                .get("total_jobs")
                .and_then(|v| v.as_u64())
                .ok_or("run manifest: bad total_jobs")? as usize,
            out: s("out")?,
            done,
        })
    }
}

/// Write `text` kill-safely: a tmp file in the same directory, then an
/// atomic rename. A process killed mid-write leaves the previous
/// version (or nothing) — never a truncated document.
fn write_atomic(path: &Path, text: &str) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

fn build_jobs(
    sweep: &str,
    horizon_s: f64,
    stream_dir: Option<&Path>,
) -> Result<Vec<SweepJob>, String> {
    match stream_dir {
        Some(root) => streamed_named_jobs(sweep, horizon_s, root),
        None => named_sweep_jobs(sweep, horizon_s)
            .ok_or_else(|| format!("unknown sweep {sweep:?} (known: {})", NAMED_SWEEPS.join(", "))),
    }
}

// ---------------------------------------------------------------------
// The drive loop
// ---------------------------------------------------------------------

/// Run jobs `manifest.done.len()..` to completion, checkpointing every
/// `manifest.every_s` simulated seconds. `current` carries a restored
/// mid-job simulator when resuming.
fn drive(
    dir: &Path,
    jobs: &[SweepJob],
    manifest: &mut RunManifest,
    mut current: Option<ClusterSim>,
    stop_after: Option<usize>,
) -> Result<RunOutcome, String> {
    let every = {
        let d = SimDuration::from_secs_f64(manifest.every_s);
        SimDuration(d.0.max(1))
    };
    let mut written = 0usize;
    let start = manifest.done.len();
    for (idx, job) in jobs.iter().enumerate().skip(start) {
        let mut sim = match current.take() {
            Some(s) => s,
            None => build_job_sim(job),
        };
        // First boundary strictly ahead of the restored clock; after a
        // pause the boundary advances by `every`. A window that
        // processed NO events writes no checkpoint and burns no
        // `--stop-after` credit: the state is identical to the last
        // one written, and a resumed run re-derives its first boundary
        // from the restored clock — which sits below the boundary it
        // paused at — so counting empty windows would re-checkpoint
        // the same state forever (zero forward progress per resume).
        let mut next_stop = SimTime((sim.sim_now().0 / every.0 + 1) * every.0);
        loop {
            let events_before = sim.counters.events;
            match sim.run_until(Some(next_stop)) {
                RunStatus::Done => break,
                RunStatus::Paused => {
                    if sim.counters.events == events_before {
                        next_stop = next_stop + every;
                        continue;
                    }
                    let ctx = RunContext {
                        sweep: manifest.sweep.clone(),
                        horizon_s: manifest.horizon_s,
                        job_index: idx,
                        key: job.key.clone(),
                        stream_dir: manifest.stream_dir.clone(),
                    };
                    let snap = sim.snapshot_with_context(Some(ctx))?;
                    let at = snap.sim_time;
                    write_atomic(
                        &dir.join(RunManifest::snapshot_name(idx)),
                        &snap.to_string_pretty(),
                    )?;
                    written += 1;
                    if let Some(budget) = stop_after {
                        if written >= budget {
                            return Ok(RunOutcome::Paused {
                                checkpoints: written,
                                next_job: idx,
                                at,
                            });
                        }
                    }
                    next_stop = next_stop + every;
                }
            }
        }
        let row = format!("{}\n", outcome_to_result(&job.key, sim.finish()).to_json());
        write_atomic(&dir.join(RunManifest::rows_name(idx)), &row)?;
        manifest.done.push(DoneJob { index: idx, payload_hash: hex64(fnv1a(row.as_bytes())) });
        write_atomic(&RunManifest::path(dir), &format!("{}\n", manifest.to_json()))?;
        // The row supersedes any checkpoint of this job.
        let _ = std::fs::remove_file(dir.join(RunManifest::snapshot_name(idx)));
    }
    seal(dir, manifest)
}

/// Concatenate the verified per-job rows into the final JSONL.
fn seal(dir: &Path, manifest: &RunManifest) -> Result<RunOutcome, String> {
    let mut merged = String::new();
    for d in &manifest.done {
        let path = dir.join(RunManifest::rows_name(d.index));
        let row =
            std::fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let actual = hex64(fnv1a(row.as_bytes()));
        if actual != d.payload_hash {
            return Err(format!(
                "{}: payload hash {actual} does not match manifest {} (row file corrupted or \
                 edited after the run)",
                path.display(),
                d.payload_hash
            ));
        }
        merged.push_str(&row);
    }
    let out = PathBuf::from(&manifest.out);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("create {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(&out, &merged).map_err(|e| format!("write {}: {e}", out.display()))?;
    Ok(RunOutcome::Completed { rows: manifest.done.len(), bytes: merged.len() })
}

/// Start a checkpointed run from scratch (any previous state under
/// `plan.dir` is cleared — it belonged to a different invocation).
pub fn run_checkpointed(plan: &RunPlan) -> Result<RunOutcome, String> {
    if !plan.every_s.is_finite() || plan.every_s <= 0.0 {
        return Err("snapshot: --every must be a positive number of simulated seconds".into());
    }
    let jobs = build_jobs(&plan.sweep, plan.horizon_s, plan.stream_dir.as_deref())?;
    std::fs::create_dir_all(&plan.dir)
        .map_err(|e| format!("create {}: {e}", plan.dir.display()))?;
    // Clear stale state files so resume can never mix two runs.
    if let Ok(entries) = std::fs::read_dir(&plan.dir) {
        for entry in entries.filter_map(|e| e.ok()) {
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with("rows-")
                || name.starts_with("job-")
                || name == "snapshot-run.json"
            {
                std::fs::remove_file(entry.path())
                    .map_err(|e| format!("remove stale {}: {e}", entry.path().display()))?;
            }
        }
    }
    let mut manifest = RunManifest {
        sweep: plan.sweep.clone(),
        horizon_s: plan.horizon_s,
        every_s: plan.every_s,
        stream_dir: plan.stream_dir.as_ref().map(|p| p.to_string_lossy().into_owned()),
        jobs_hash: job_list_hash(&jobs),
        total_jobs: jobs.len(),
        out: plan.out.to_string_lossy().into_owned(),
        done: Vec::new(),
    };
    write_atomic(&RunManifest::path(&plan.dir), &format!("{}\n", manifest.to_json()))?;
    drive(&plan.dir, &jobs, &mut manifest, None, plan.stop_after)
}

/// Resume an interrupted checkpointed run from its state directory.
/// Verifies the manifest, re-derives the canonical job list and proves
/// it matches the one the run started from (`jobs_hash`), re-verifies
/// every completed row's payload hash, restores the newest checkpoint
/// of the in-progress job (if one exists — otherwise that job restarts
/// from its trace, which is equivalent work, not wrong results), and
/// drives the rest of the sweep to the exact uninterrupted bytes.
pub fn resume_run(dir: &Path, stop_after: Option<usize>) -> Result<RunOutcome, String> {
    let path = RunManifest::path(dir);
    let text =
        std::fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
    let mut manifest = RunManifest::from_json(&doc)?;
    let jobs = build_jobs(
        &manifest.sweep,
        manifest.horizon_s,
        manifest.stream_dir.as_deref().map(Path::new),
    )?;
    if jobs.len() != manifest.total_jobs {
        return Err(format!(
            "resume: rebuilt job list has {} jobs, manifest says {}",
            jobs.len(),
            manifest.total_jobs
        ));
    }
    let hash = job_list_hash(&jobs);
    if hash != manifest.jobs_hash {
        return Err(format!(
            "resume: rebuilt job list hashes to {hash}, manifest says {} — the sweep registry \
             or trace inputs changed since the run started",
            manifest.jobs_hash
        ));
    }
    if manifest.done.len() >= jobs.len() {
        // Every job already finished; (re)seal idempotently.
        return seal(dir, &manifest);
    }
    let idx = manifest.done.len();
    let snap_path = dir.join(RunManifest::snapshot_name(idx));
    let current = match std::fs::read_to_string(&snap_path) {
        Err(_) => None, // no checkpoint yet: restart this job from its trace
        Ok(text) => {
            let snap = SimSnapshot::parse(&text)
                .map_err(|e| format!("{}: {e}", snap_path.display()))?;
            let ctx = snap
                .context
                .as_ref()
                .ok_or_else(|| format!("{}: checkpoint lacks a run context", snap_path.display()))?;
            if ctx.sweep != manifest.sweep || ctx.job_index != idx || ctx.key != jobs[idx].key {
                return Err(format!(
                    "{}: checkpoint describes {}[{}] {:?}, expected {}[{idx}] {:?}",
                    snap_path.display(),
                    ctx.sweep,
                    ctx.job_index,
                    ctx.key,
                    manifest.sweep,
                    jobs[idx].key
                ));
            }
            if snap.system != jobs[idx].system.name() {
                return Err(format!(
                    "{}: checkpoint system {:?} does not match the job's {:?}",
                    snap_path.display(),
                    snap.system,
                    jobs[idx].system.name()
                ));
            }
            Some(ClusterSim::from_snapshot(jobs[idx].cfg.clone(), &snap)
                .map_err(|e| format!("{}: {e}", snap_path.display()))?)
        }
    };
    drive(dir, &jobs, &mut manifest, current, stop_after)
}

// ---------------------------------------------------------------------
// CLI glue
// ---------------------------------------------------------------------

/// Exit status for a deliberate `--stop-after` pause (distinct from 0 =
/// completed and 1 = error, so CI stages can assert "paused as asked").
pub const PAUSED_EXIT_CODE: i32 = 3;

/// `gyges snapshot <sweep> ...` — checkpointed serial sweep run.
pub fn snapshot_cli(args: &Args) -> i32 {
    let Some(sweep) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!(
            "usage: gyges snapshot <{}> [--horizon S] [--every SIM_S] [--dir DIR] [--out FILE] \
             [--stream-dir DIR] [--stop-after K]",
            NAMED_SWEEPS.join("|")
        );
        return 2;
    };
    let parsed = (|| -> Result<(f64, f64, Option<usize>), String> {
        Ok((
            args.parsed_strict("horizon", named_sweep_default_horizon(sweep))?,
            args.parsed_strict("every", 30.0f64)?,
            match args.get("stop-after") {
                None => None,
                Some(raw) => Some(
                    raw.parse::<usize>()
                        .map_err(|_| format!("--stop-after {raw:?} is not a count"))?,
                ),
            },
        ))
    })();
    let (horizon_s, every_s, stop_after) = match parsed {
        Ok(v) => v,
        Err(e) => {
            eprintln!("snapshot: {e}");
            return 2;
        }
    };
    let plan = RunPlan {
        sweep: sweep.to_string(),
        horizon_s,
        every_s,
        dir: PathBuf::from(args.get_or("dir", &format!("target/snapshots/{sweep}"))),
        out: PathBuf::from(args.get_or("out", &format!("target/{sweep}-snapshot-run.jsonl"))),
        stream_dir: args.get("stream-dir").map(PathBuf::from),
        stop_after,
    };
    match run_checkpointed(&plan) {
        Ok(RunOutcome::Completed { rows, bytes }) => {
            println!(
                "{sweep}: completed with checkpoints every {every_s} sim-s → {rows} rows \
                 ({bytes} bytes) → {}",
                plan.out.display()
            );
            0
        }
        Ok(RunOutcome::Paused { checkpoints, next_job, at }) => {
            println!(
                "{sweep}: paused after {checkpoints} checkpoint(s); job {next_job} parked at \
                 sim-time {at} — `gyges resume --dir {}` continues",
                plan.dir.display()
            );
            PAUSED_EXIT_CODE
        }
        Err(e) => {
            eprintln!("snapshot: {e}");
            1
        }
    }
}

/// `gyges resume --dir DIR ...` — continue an interrupted run.
pub fn resume_cli(args: &Args) -> i32 {
    let Some(dir) = args.get("dir") else {
        eprintln!("usage: gyges resume --dir DIR [--stop-after K]");
        return 2;
    };
    let stop_after = match args.get("stop-after") {
        None => None,
        Some(raw) => match raw.parse::<usize>() {
            Ok(k) => Some(k),
            Err(_) => {
                eprintln!("resume: --stop-after {raw:?} is not a count");
                return 2;
            }
        },
    };
    match resume_run(Path::new(dir), stop_after) {
        Ok(RunOutcome::Completed { rows, bytes }) => {
            println!("resumed run completed: {rows} rows ({bytes} bytes)");
            0
        }
        Ok(RunOutcome::Paused { checkpoints, next_job, at }) => {
            println!(
                "paused again after {checkpoints} checkpoint(s); job {next_job} parked at \
                 sim-time {at}"
            );
            PAUSED_EXIT_CODE
        }
        Err(e) => {
            eprintln!("resume: {e}");
            1
        }
    }
}
