//! Snapshot schema v4: a versioned, self-describing serialization of
//! complete [`ClusterSim`](crate::coordinator::ClusterSim) state.
//!
//! Everything the event loop's next decision can observe is captured:
//! the event queue (entries with their original FIFO sequence numbers
//! plus the counter), the simulated clock, every instance with its
//! request queues and in-flight transformation, the deferred backlog
//! with its cooldown deadline, routing-policy state, the recorder's
//! rows and TPS buckets, and the arrival feed's replay cursor
//! ([`crate::workload::SourceCursor`] — a few integers for seeded/
//! file-backed streams, the remaining requests for in-memory traces).
//!
//! Schema v2 adds the fault-injection state introduced alongside
//! `rust/src/faults/`: the armed [`FaultPlan`] with its cursor, the
//! per-host degraded/link-down deadlines, the per-instance stall
//! deadlines, per-backlog-entry retry bookkeeping (`attempts`,
//! `next_retry`), and four new event kinds (`fault`, `host_restore`,
//! `stall_end`, `link_restore`) — so a kill/resume stays byte-identical
//! even mid-fault-storm. v1 documents are rejected (no migration: they
//! predate the fault subsystem and every v1 producer can re-run).
//!
//! Schema v3 adds the per-request TPS-credit ledger
//! (`RequestRecord::tok_buckets`, serialized as each recorder row's
//! `buckets` array, omitted when empty) so a resumed run can unwind
//! per-second throughput credits when a later host crash requeues a
//! request it had already generated tokens for. v2 documents are
//! rejected for the same reason v1 ones were: a v2 snapshot cannot
//! say which seconds a live request credited, so resume-then-crash
//! would diverge from the uninterrupted run.
//!
//! Schema v4 accompanies the filter/score scheduler pipeline: each
//! serialized request carries its SLO class (`class`, omitted for the
//! interactive default), composed policies snapshot as a recursive
//! `pipeline` policy kind wrapping their base state, and the counters
//! gain `preemptions` / `admission_dropped`. v3 documents are rejected:
//! a v3 snapshot cannot say which queued prefills are batch-class, so a
//! resumed `-slo` policy could preempt the wrong victims and diverge.
//!
//! What is deliberately NOT serialized, and why that is sound:
//!
//! * **Derived routing indices** (`LoadIndex` / `HostIndex`) — rebuilt
//!   from the restored instance table on load; the rebuild *is* the
//!   from-scratch construction the end-of-run debug check compares
//!   against, and `ClusterSim::from_snapshot` debug-asserts it again.
//! * **Incremental aggregates** (instance committed/context tokens,
//!   recorder totals) — recomputed from the serialized queues/rows they
//!   are defined over.
//! * **Wall-clock profiling** (`SimProfile`) — not simulation state; a
//!   profiling run refuses to snapshot.
//! * **The `ClusterConfig`/`EngineModel`** — the resuming process
//!   reconstructs them from the same run descriptor (sweep registry or
//!   CLI flags) and the envelope's `config_fingerprint` proves the
//!   reconstruction matches the snapshotting process's config exactly.
//!
//! The envelope carries `schema_version`, the config fingerprint, and
//! an FNV-1a `payload_hash` over the canonical state encoding (object
//! keys sort deterministically), so truncated or edited snapshot files
//! are rejected loudly at load — same integrity discipline as the PR 3
//! shard manifests and PR 4 segment files.

use crate::cache::ClusterCache;
use crate::config::ClusterConfig;
use crate::coordinator::PolicyState;
use crate::coordinator::SimCounters;
use crate::faults::FaultPlan;
use crate::metrics::RequestRecord;
use crate::sim::clock::{SimDuration, SimTime};
use crate::util::hash::{fnv1a, hex64};
use crate::util::json::Json;
use crate::workload::{FeedState, SloClass};

/// Snapshot schema version this module reads and writes. v5 added the
/// prefix-cache state (request prefix paths + cached-token credits, the
/// per-instance radix trees, the policy `cache` flag); older documents
/// are rejected rather than half-restored.
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 5;

/// One queued runtime event (arrivals are never queue events — they
/// live in the feed cursor).
#[derive(Clone, Debug, PartialEq)]
pub struct EventSnap {
    pub at: SimTime,
    /// Original FIFO sequence number inside the event queue.
    pub seq: u64,
    pub kind: EventKindSnap,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EventKindSnap {
    Step { iid: usize, epoch: u64 },
    TransformDone { iid: usize, epoch: u64 },
    BacklogWakeup,
    /// Index into the armed [`FaultPlan`]'s fault list.
    Fault { idx: usize },
    HostRestore { host: usize },
    StallEnd { iid: usize, epoch: u64 },
    LinkRestore { host: usize },
}

/// What an instance's in-flight step will do when it completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PendingSnap {
    None,
    Prefill { req_id: u64 },
    Decode,
    Maintenance,
}

/// An active request (running, queued for prefill, or backlogged).
#[derive(Clone, Debug, PartialEq)]
pub struct ReqSnap {
    pub id: u64,
    pub arrival: SimTime,
    pub input_len: u64,
    pub output_len: u64,
    pub generated: u64,
    /// [`crate::coordinator::Phase`] name.
    pub phase: String,
    /// SLO class — what `-slo` preemption and `-admit` deadlines key on.
    pub class: SloClass,
    /// Shared-prefix block path (empty for prefix-free traces).
    pub prefix: Vec<u64>,
    /// Prefill tokens credited by the prefix cache at placement.
    pub cached_tokens: u64,
}

/// A backlogged request with its first-deferral stamp and retry
/// bookkeeping (zero / epoch for requests that never failed a route
/// under a bounded [`crate::faults::RetryPolicy`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DeferredSnap {
    pub req: ReqSnap,
    pub since: SimTime,
    pub attempts: u32,
    pub next_retry: SimTime,
}

/// An in-flight transformation: enough to rebuild the executor exactly
/// (the plan regenerates from the model + endpoints + stagger; the
/// derived per-op overhead is carried verbatim).
#[derive(Clone, Debug, PartialEq)]
pub struct TransformSnap {
    pub from_tp: u64,
    pub to_tp: u64,
    /// `TransformPlan::ops_per_step` (2 × layers per step).
    pub ops_per_step: usize,
    /// [`crate::transform::Mechanism`] name.
    pub mech: String,
    pub per_op_visible: SimDuration,
    pub step: usize,
    pub blocked_until: Option<SimTime>,
}

/// One serving instance.
#[derive(Clone, Debug, PartialEq)]
pub struct InstanceSnap {
    pub id: usize,
    pub host: usize,
    pub workers: Vec<usize>,
    pub degree: u64,
    /// [`crate::coordinator::ParallelKind`] name.
    pub kind: String,
    pub running: Vec<ReqSnap>,
    pub prefill: Vec<ReqSnap>,
    pub kv_tokens: u64,
    pub transforming: Option<TransformSnap>,
    pub last_transform: SimTime,
    pub stepping: bool,
    pub retired: bool,
}

/// The recorder's state: occupied rows (dense-id slab holes omitted),
/// raw per-second token buckets, and the horizon watermark.
#[derive(Clone, Debug, PartialEq)]
pub struct RecorderSnap {
    pub rows: Vec<(u64, RequestRecord)>,
    pub tps_buckets: Vec<u64>,
    pub horizon: SimTime,
}

/// Complete simulator state between two events.
#[derive(Clone, Debug, PartialEq)]
pub struct SimState {
    pub queue_seq: u64,
    /// Sorted ascending by `(at, seq)`.
    pub events: Vec<EventSnap>,
    pub instances: Vec<InstanceSnap>,
    pub epochs: Vec<u64>,
    pub pending: Vec<PendingSnap>,
    pub dwell_check_scheduled: Vec<bool>,
    pub backlog: Vec<DeferredSnap>,
    pub counters: SimCounters,
    pub policy: PolicyState,
    pub transformation_disabled: bool,
    pub use_routing_index: bool,
    pub backlog_cooldown_until: SimTime,
    pub backlog_wakeup_scheduled: bool,
    /// The armed fault plan (empty when no faults were injected) and
    /// how many of its faults have already fired.
    pub fault_plan: FaultPlan,
    pub fault_cursor: usize,
    /// Per-host crash-recovery deadlines (`ZERO` = healthy).
    pub degraded_until: Vec<SimTime>,
    /// Per-host KV-migration-link outage deadlines (`ZERO` = up).
    pub link_down_until: Vec<SimTime>,
    /// Per-instance stall deadlines, parallel to `instances`.
    pub stall_until: Vec<SimTime>,
    pub recorder: RecorderSnap,
    pub feed: FeedState,
    /// The prefix-cache model, `None` when the run never armed it.
    pub cache: Option<ClusterCache>,
}

/// Where this snapshot came from, for the resume/branch CLIs: which
/// named sweep, at which horizon, which job of its canonical list, and
/// (for streamed jobs) the segment-directory root. `None` for snapshots
/// taken through the library API directly.
#[derive(Clone, Debug, PartialEq)]
pub struct RunContext {
    pub sweep: String,
    pub horizon_s: f64,
    pub job_index: usize,
    pub key: String,
    pub stream_dir: Option<String>,
}

/// The full snapshot: envelope + state.
#[derive(Clone, Debug, PartialEq)]
pub struct SimSnapshot {
    /// [`crate::coordinator::SystemKind`] name.
    pub system: String,
    /// [`config_fingerprint`] of the `ClusterConfig` the simulation ran
    /// under — resume reconstructs the config and must match it.
    pub config_fingerprint: String,
    /// The simulated clock at capture (`EventQueue::now`).
    pub sim_time: SimTime,
    pub context: Option<RunContext>,
    pub state: SimState,
}

/// Fingerprint of every config field the simulation's behaviour depends
/// on. Strings are 0xFF-delimited (never valid UTF-8) so adjacent
/// fields cannot alias; f64 knobs hash their exact bit patterns.
pub fn config_fingerprint(cfg: &ClusterConfig) -> String {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(cfg.model.name.as_bytes());
    bytes.push(0xFF);
    bytes.extend_from_slice(cfg.gpu.name.as_bytes());
    bytes.push(0xFF);
    bytes.extend_from_slice(cfg.policy.name().as_bytes());
    bytes.push(0xFF);
    bytes.extend_from_slice(&(cfg.tp_choices.len() as u64).to_le_bytes());
    for &tp in &cfg.tp_choices {
        bytes.extend_from_slice(&tp.to_le_bytes());
    }
    for v in [
        cfg.hosts as u64,
        cfg.gpus_per_host as u64,
        cfg.scale_down_threshold.to_bits(),
        cfg.slo_interactive_deadline_s.to_bits(),
        cfg.slo_batch_deadline_s.to_bits(),
        cfg.min_dwell_s.to_bits(),
        cfg.backlog_retry_cooldown_s.to_bits(),
        cfg.retry_max_attempts as u64,
        cfg.retry_backoff_base_s.to_bits(),
        cfg.max_batch_tokens,
        cfg.max_batch_size as u64,
        cfg.max_events,
        cfg.seed,
    ] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    hex64(fnv1a(&bytes))
}

// ---------------------------------------------------------------------
// JSON encoding
// ---------------------------------------------------------------------

fn time_opt(t: Option<SimTime>) -> Json {
    match t {
        Some(t) => Json::from(t.0),
        None => Json::Null,
    }
}

fn req_to_json(r: &ReqSnap) -> Json {
    let mut o = Json::obj();
    o.set("id", r.id)
        .set("arrival_ns", r.arrival.0)
        .set("input", r.input_len)
        .set("output", r.output_len)
        .set("generated", r.generated)
        .set("phase", r.phase.as_str());
    // Interactive (the default) encodes as absence — classless runs
    // serialize exactly as they would have without the field.
    if r.class == SloClass::Batch {
        o.set("class", r.class.name());
    }
    // Prefix-free requests encode as absence, as does a zero cache
    // credit — cache-off snapshots carry no trace of the feature.
    if !r.prefix.is_empty() {
        o.set("prefix", Json::Arr(r.prefix.iter().map(|&b| Json::from(b)).collect()));
    }
    if r.cached_tokens > 0 {
        o.set("cached_tokens", r.cached_tokens);
    }
    o
}

fn req_from_json(j: &Json) -> Result<ReqSnap, String> {
    let num = |k: &str| j.req_u64(k, "request");
    let class = match j.get("class") {
        None | Some(Json::Null) => SloClass::Interactive,
        Some(v) => {
            let s = v.as_str().ok_or("request: bad class")?;
            SloClass::by_name(s).ok_or_else(|| format!("request: unknown class {s:?}"))?
        }
    };
    let prefix = match j.get("prefix") {
        None | Some(Json::Null) => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or("request: bad prefix")?
            .iter()
            .map(|b| b.as_u64().ok_or("request: bad prefix block"))
            .collect::<Result<Vec<_>, _>>()?,
    };
    let cached_tokens = match j.get("cached_tokens") {
        None | Some(Json::Null) => 0,
        Some(v) => v.as_u64().ok_or("request: bad cached_tokens")?,
    };
    Ok(ReqSnap {
        id: num("id")?,
        arrival: SimTime(num("arrival_ns")?),
        input_len: num("input")?,
        output_len: num("output")?,
        generated: num("generated")?,
        phase: j.req_str("phase", "request")?.to_string(),
        class,
        prefix,
        cached_tokens,
    })
}

fn counters_to_json(c: &SimCounters) -> Json {
    let mut o = Json::obj();
    o.set("scale_ups", c.scale_ups)
        .set("scale_downs", c.scale_downs)
        .set("deferred", c.deferred)
        .set("steps", c.steps)
        .set("events", c.events)
        .set("arrival_events", c.arrival_events)
        .set("step_events", c.step_events)
        .set("transform_done_events", c.transform_done_events)
        .set("stale_events", c.stale_events)
        .set("backlog_wakeup_events", c.backlog_wakeup_events)
        .set("routes", c.routes)
        .set("kicks", c.kicks)
        .set("backlog_retries", c.backlog_retries)
        .set("backlog_requeues", c.backlog_requeues)
        .set("backlog_suppressed", c.backlog_suppressed)
        // Exact ticks, not the float seconds the report rows print.
        .set("backlog_wait_ns", c.backlog_wait.0)
        .set("fault_events", c.fault_events)
        .set("recovery_events", c.recovery_events)
        .set("crashed_instances", c.crashed_instances)
        .set("crash_requeued", c.crash_requeued)
        .set("dropped", c.dropped)
        .set("transform_rollbacks", c.transform_rollbacks)
        .set("stalled_instances", c.stalled_instances)
        .set("scale_up_blocked", c.scale_up_blocked)
        .set("preemptions", c.preemptions)
        .set("admission_dropped", c.admission_dropped);
    o
}

fn counters_from_json(j: &Json) -> Result<SimCounters, String> {
    let num = |k: &str| j.req_u64(k, "counters");
    Ok(SimCounters {
        scale_ups: num("scale_ups")?,
        scale_downs: num("scale_downs")?,
        deferred: num("deferred")?,
        steps: num("steps")?,
        events: num("events")?,
        arrival_events: num("arrival_events")?,
        step_events: num("step_events")?,
        transform_done_events: num("transform_done_events")?,
        stale_events: num("stale_events")?,
        backlog_wakeup_events: num("backlog_wakeup_events")?,
        routes: num("routes")?,
        kicks: num("kicks")?,
        backlog_retries: num("backlog_retries")?,
        backlog_requeues: num("backlog_requeues")?,
        backlog_suppressed: num("backlog_suppressed")?,
        backlog_wait: SimDuration(num("backlog_wait_ns")?),
        fault_events: num("fault_events")?,
        recovery_events: num("recovery_events")?,
        crashed_instances: num("crashed_instances")?,
        crash_requeued: num("crash_requeued")?,
        dropped: num("dropped")?,
        transform_rollbacks: num("transform_rollbacks")?,
        stalled_instances: num("stalled_instances")?,
        scale_up_blocked: num("scale_up_blocked")?,
        preemptions: num("preemptions")?,
        admission_dropped: num("admission_dropped")?,
    })
}

fn policy_to_json(p: &PolicyState) -> Json {
    let mut o = Json::obj();
    match p {
        PolicyState::Gyges { reserved, reserve_cap, last_long_seen, long_hold_s } => {
            o.set("kind", "gyges")
                .set("reserved", Json::Arr(reserved.iter().map(|&i| Json::from(i)).collect()))
                .set("reserve_cap", *reserve_cap)
                .set("last_long_seen_ns", time_opt(*last_long_seen))
                .set("long_hold_s", *long_hold_s);
        }
        PolicyState::RoundRobin { cursor } => {
            o.set("kind", "rr").set("cursor", *cursor);
        }
        PolicyState::LeastLoad => {
            o.set("kind", "llf");
        }
        PolicyState::Pipeline { cache, slo, admit, base } => {
            o.set("kind", "pipeline")
                .set("slo", *slo)
                .set("admit", *admit)
                .set("base", policy_to_json(base));
            // Absence-encoded: cache-free pipelines serialize exactly
            // as they did before the flag existed.
            if *cache {
                o.set("cache", true);
            }
        }
    }
    o
}

fn policy_from_json(j: &Json) -> Result<PolicyState, String> {
    match j.get("kind").and_then(|v| v.as_str()) {
        Some("gyges") => Ok(PolicyState::Gyges {
            reserved: j
                .get("reserved")
                .and_then(|v| v.as_arr())
                .ok_or("policy: bad reserved")?
                .iter()
                .map(|v| v.as_u64().map(|x| x as usize).ok_or("policy: bad reserved entry"))
                .collect::<Result<Vec<_>, _>>()?,
            reserve_cap: j
                .get("reserve_cap")
                .and_then(|v| v.as_f64())
                .ok_or("policy: bad reserve_cap")?,
            last_long_seen: match j.get("last_long_seen_ns") {
                None | Some(Json::Null) => None,
                Some(v) => Some(SimTime(v.as_u64().ok_or("policy: bad last_long_seen_ns")?)),
            },
            long_hold_s: j
                .get("long_hold_s")
                .and_then(|v| v.as_f64())
                .ok_or("policy: bad long_hold_s")?,
        }),
        Some("rr") => Ok(PolicyState::RoundRobin {
            cursor: j
                .get("cursor")
                .and_then(|v| v.as_u64())
                .ok_or("policy: bad cursor")? as usize,
        }),
        Some("llf") => Ok(PolicyState::LeastLoad),
        Some("pipeline") => Ok(PolicyState::Pipeline {
            cache: j.get("cache").and_then(|v| v.as_bool()).unwrap_or(false),
            slo: j.req_bool("slo", "policy")?,
            admit: j.req_bool("admit", "policy")?,
            base: Box::new(policy_from_json(j.get("base").ok_or("policy: missing base")?)?),
        }),
        other => Err(format!("policy: unknown kind {other:?}")),
    }
}

fn event_to_json(e: &EventSnap) -> Json {
    let mut o = Json::obj();
    o.set("at_ns", e.at.0).set("seq", e.seq);
    match &e.kind {
        EventKindSnap::Step { iid, epoch } => {
            o.set("kind", "step").set("iid", *iid).set("epoch", *epoch);
        }
        EventKindSnap::TransformDone { iid, epoch } => {
            o.set("kind", "transform_done").set("iid", *iid).set("epoch", *epoch);
        }
        EventKindSnap::BacklogWakeup => {
            o.set("kind", "backlog_wakeup");
        }
        EventKindSnap::Fault { idx } => {
            o.set("kind", "fault").set("idx", *idx);
        }
        EventKindSnap::HostRestore { host } => {
            o.set("kind", "host_restore").set("host", *host);
        }
        EventKindSnap::StallEnd { iid, epoch } => {
            o.set("kind", "stall_end").set("iid", *iid).set("epoch", *epoch);
        }
        EventKindSnap::LinkRestore { host } => {
            o.set("kind", "link_restore").set("host", *host);
        }
    }
    o
}

fn event_from_json(j: &Json) -> Result<EventSnap, String> {
    let num = |k: &str| j.req_u64(k, "event");
    let kind = match j.get("kind").and_then(|v| v.as_str()) {
        Some("step") => EventKindSnap::Step { iid: num("iid")? as usize, epoch: num("epoch")? },
        Some("transform_done") => {
            EventKindSnap::TransformDone { iid: num("iid")? as usize, epoch: num("epoch")? }
        }
        Some("backlog_wakeup") => EventKindSnap::BacklogWakeup,
        Some("fault") => EventKindSnap::Fault { idx: num("idx")? as usize },
        Some("host_restore") => EventKindSnap::HostRestore { host: num("host")? as usize },
        Some("stall_end") => {
            EventKindSnap::StallEnd { iid: num("iid")? as usize, epoch: num("epoch")? }
        }
        Some("link_restore") => EventKindSnap::LinkRestore { host: num("host")? as usize },
        other => return Err(format!("event: unknown kind {other:?}")),
    };
    Ok(EventSnap { at: SimTime(num("at_ns")?), seq: num("seq")?, kind })
}

fn transform_to_json(t: &TransformSnap) -> Json {
    let mut o = Json::obj();
    o.set("from_tp", t.from_tp)
        .set("to_tp", t.to_tp)
        .set("ops_per_step", t.ops_per_step)
        .set("mech", t.mech.as_str())
        .set("per_op_visible_ns", t.per_op_visible.0)
        .set("step", t.step)
        .set("blocked_until_ns", time_opt(t.blocked_until));
    o
}

fn transform_from_json(j: &Json) -> Result<TransformSnap, String> {
    let num = |k: &str| j.req_u64(k, "transform");
    Ok(TransformSnap {
        from_tp: num("from_tp")?,
        to_tp: num("to_tp")?,
        ops_per_step: num("ops_per_step")? as usize,
        mech: j.req_str("mech", "transform")?.to_string(),
        per_op_visible: SimDuration(num("per_op_visible_ns")?),
        step: num("step")? as usize,
        blocked_until: match j.get("blocked_until_ns") {
            None | Some(Json::Null) => None,
            Some(v) => Some(SimTime(v.as_u64().ok_or("transform: bad blocked_until_ns")?)),
        },
    })
}

fn instance_to_json(i: &InstanceSnap) -> Json {
    let reqs = |rs: &[ReqSnap]| Json::Arr(rs.iter().map(req_to_json).collect());
    let mut o = Json::obj();
    o.set("id", i.id)
        .set("host", i.host)
        .set("workers", Json::Arr(i.workers.iter().map(|&w| Json::from(w)).collect()))
        .set("degree", i.degree)
        .set("parallel", i.kind.as_str())
        .set("running", reqs(&i.running))
        .set("prefill", reqs(&i.prefill))
        .set("kv_tokens", i.kv_tokens)
        .set(
            "transforming",
            i.transforming.as_ref().map(transform_to_json).unwrap_or(Json::Null),
        )
        .set("last_transform_ns", i.last_transform.0)
        .set("stepping", i.stepping)
        .set("retired", i.retired);
    o
}

fn instance_from_json(j: &Json) -> Result<InstanceSnap, String> {
    let num = |k: &str| j.req_u64(k, "instance");
    let flag = |k: &str| j.req_bool(k, "instance");
    let reqs = |k: &str| -> Result<Vec<ReqSnap>, String> {
        j.req_arr(k, "instance")?.iter().map(req_from_json).collect()
    };
    Ok(InstanceSnap {
        id: num("id")? as usize,
        host: num("host")? as usize,
        workers: j
            .req_arr("workers", "instance")?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize).ok_or("instance: bad worker"))
            .collect::<Result<Vec<_>, _>>()?,
        degree: num("degree")?,
        kind: j.req_str("parallel", "instance")?.to_string(),
        running: reqs("running")?,
        prefill: reqs("prefill")?,
        kv_tokens: num("kv_tokens")?,
        transforming: match j.get("transforming") {
            None | Some(Json::Null) => None,
            Some(t) => Some(transform_from_json(t)?),
        },
        last_transform: SimTime(num("last_transform_ns")?),
        stepping: flag("stepping")?,
        retired: flag("retired")?,
    })
}

fn recorder_to_json(r: &RecorderSnap) -> Json {
    let rows: Vec<Json> = r
        .rows
        .iter()
        .map(|(id, rec)| {
            let mut o = Json::obj();
            o.set("id", *id)
                .set("arrival_ns", rec.arrival.0)
                .set("first_token_ns", time_opt(rec.first_token))
                .set("finished_ns", time_opt(rec.finished))
                .set("input", rec.input_len)
                .set("output", rec.output_len)
                .set("generated", rec.generated);
            // Interactive encodes as absence, like ReqSnap's class.
            if rec.class == SloClass::Batch {
                o.set("class", rec.class.name());
            }
            // Per-second TPS credits as [second, count] pairs (schema
            // v3); omitted when the request never generated a token.
            if !rec.tok_buckets.is_empty() {
                let pairs = rec
                    .tok_buckets
                    .iter()
                    .map(|&(s, c)| {
                        Json::Arr(vec![Json::from(u64::from(s)), Json::from(u64::from(c))])
                    })
                    .collect();
                o.set("buckets", Json::Arr(pairs));
            }
            o
        })
        .collect();
    let mut o = Json::obj();
    o.set("rows", Json::Arr(rows))
        .set("tps_buckets", Json::Arr(r.tps_buckets.iter().map(|&c| Json::from(c)).collect()))
        .set("horizon_ns", r.horizon.0);
    o
}

fn recorder_from_json(j: &Json) -> Result<RecorderSnap, String> {
    let mut rows = Vec::new();
    for row in j.req_arr("rows", "recorder")? {
        let num = |k: &str| row.req_u64(k, "recorder row");
        let opt = |k: &str| -> Result<Option<SimTime>, String> {
            match row.get(k) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => Ok(Some(SimTime(
                    v.as_u64().ok_or_else(|| format!("recorder row: bad {k:?}"))?,
                ))),
            }
        };
        let mut tok_buckets = Vec::new();
        if let Some(pairs) = row.get("buckets") {
            for p in pairs.as_arr().ok_or("recorder row: bad buckets")? {
                let pair = p.as_arr().filter(|a| a.len() == 2).ok_or("recorder row: bad pair")?;
                let sec = pair[0].as_u64().ok_or("recorder row: bad bucket second")?;
                let c = pair[1].as_u64().ok_or("recorder row: bad bucket count")?;
                tok_buckets.push((sec as u32, c as u32));
            }
        }
        let class = match row.get("class") {
            None | Some(Json::Null) => SloClass::Interactive,
            Some(v) => {
                let s = v.as_str().ok_or("recorder row: bad class")?;
                SloClass::by_name(s).ok_or_else(|| format!("recorder row: unknown class {s:?}"))?
            }
        };
        rows.push((
            num("id")?,
            RequestRecord {
                arrival: SimTime(num("arrival_ns")?),
                first_token: opt("first_token_ns")?,
                finished: opt("finished_ns")?,
                input_len: num("input")?,
                output_len: num("output")?,
                generated: num("generated")?,
                tok_buckets,
                class,
            },
        ));
    }
    Ok(RecorderSnap {
        rows,
        tps_buckets: j
            .req_arr("tps_buckets", "recorder")?
            .iter()
            .map(|v| v.as_u64().ok_or("recorder: bad tps bucket"))
            .collect::<Result<Vec<_>, _>>()?,
        horizon: SimTime(
            j.get("horizon_ns").and_then(|v| v.as_u64()).ok_or("recorder: bad horizon_ns")?,
        ),
    })
}

fn pending_to_json(p: &PendingSnap) -> Json {
    match p {
        PendingSnap::None => Json::Str("none".into()),
        PendingSnap::Decode => Json::Str("decode".into()),
        PendingSnap::Maintenance => Json::Str("maintenance".into()),
        PendingSnap::Prefill { req_id } => {
            let mut o = Json::obj();
            o.set("prefill", *req_id);
            o
        }
    }
}

fn pending_from_json(j: &Json) -> Result<PendingSnap, String> {
    match j {
        Json::Str(s) => match s.as_str() {
            "none" => Ok(PendingSnap::None),
            "decode" => Ok(PendingSnap::Decode),
            "maintenance" => Ok(PendingSnap::Maintenance),
            other => Err(format!("pending: unknown {other:?}")),
        },
        Json::Obj(_) => Ok(PendingSnap::Prefill {
            req_id: j.get("prefill").and_then(|v| v.as_u64()).ok_or("pending: bad prefill")?,
        }),
        _ => Err("pending: expected string or object".into()),
    }
}

fn state_to_json(s: &SimState) -> Json {
    let backlog: Vec<Json> = s
        .backlog
        .iter()
        .map(|d| {
            let mut o = Json::obj();
            o.set("req", req_to_json(&d.req))
                .set("since_ns", d.since.0)
                .set("attempts", u64::from(d.attempts))
                .set("next_retry_ns", d.next_retry.0);
            o
        })
        .collect();
    let times = |ts: &[SimTime]| Json::Arr(ts.iter().map(|t| Json::from(t.0)).collect());
    let mut o = Json::obj();
    o.set("queue_seq", s.queue_seq)
        .set("events", Json::Arr(s.events.iter().map(event_to_json).collect()))
        .set("instances", Json::Arr(s.instances.iter().map(instance_to_json).collect()))
        .set("epochs", Json::Arr(s.epochs.iter().map(|&e| Json::from(e)).collect()))
        .set("pending", Json::Arr(s.pending.iter().map(pending_to_json).collect()))
        .set(
            "dwell_check_scheduled",
            Json::Arr(s.dwell_check_scheduled.iter().map(|&b| Json::from(b)).collect()),
        )
        .set("backlog", Json::Arr(backlog))
        .set("counters", counters_to_json(&s.counters))
        .set("policy", policy_to_json(&s.policy))
        .set("transformation_disabled", s.transformation_disabled)
        .set("use_routing_index", s.use_routing_index)
        .set("backlog_cooldown_until_ns", s.backlog_cooldown_until.0)
        .set("backlog_wakeup_scheduled", s.backlog_wakeup_scheduled)
        .set("fault_plan", s.fault_plan.to_json())
        .set("fault_cursor", s.fault_cursor)
        .set("degraded_until_ns", times(&s.degraded_until))
        .set("link_down_until_ns", times(&s.link_down_until))
        .set("stall_until_ns", times(&s.stall_until))
        .set("recorder", recorder_to_json(&s.recorder))
        .set("feed", s.feed.to_json());
    // Unarmed caches encode as absence — a cache-off snapshot is
    // byte-for-byte what it would have been without the subsystem.
    if let Some(c) = &s.cache {
        o.set("cache", c.to_json());
    }
    o
}

fn state_from_json(j: &Json) -> Result<SimState, String> {
    let arr = |k: &str| j.req_arr(k, "state");
    let flag = |k: &str| j.req_bool(k, "state");
    let num = |k: &str| j.req_u64(k, "state");
    let times = |k: &str| -> Result<Vec<SimTime>, String> {
        arr(k)?
            .iter()
            .map(|v| v.as_u64().map(SimTime).ok_or_else(|| format!("state: bad {k:?} entry")))
            .collect()
    };
    let mut backlog = Vec::new();
    for d in arr("backlog")? {
        backlog.push(DeferredSnap {
            req: req_from_json(d.get("req").ok_or("state: backlog entry missing req")?)?,
            since: SimTime(d.req_u64("since_ns", "state")?),
            attempts: d.req_u64("attempts", "state")? as u32,
            next_retry: SimTime(d.req_u64("next_retry_ns", "state")?),
        });
    }
    Ok(SimState {
        queue_seq: num("queue_seq")?,
        events: arr("events")?.iter().map(event_from_json).collect::<Result<Vec<_>, _>>()?,
        instances: arr("instances")?
            .iter()
            .map(instance_from_json)
            .collect::<Result<Vec<_>, _>>()?,
        epochs: arr("epochs")?
            .iter()
            .map(|v| v.as_u64().ok_or("state: bad epoch"))
            .collect::<Result<Vec<_>, _>>()?,
        pending: arr("pending")?
            .iter()
            .map(pending_from_json)
            .collect::<Result<Vec<_>, _>>()?,
        dwell_check_scheduled: arr("dwell_check_scheduled")?
            .iter()
            .map(|v| v.as_bool().ok_or("state: bad dwell flag"))
            .collect::<Result<Vec<_>, _>>()?,
        backlog,
        counters: counters_from_json(j.get("counters").ok_or("state: missing counters")?)?,
        policy: policy_from_json(j.get("policy").ok_or("state: missing policy")?)?,
        transformation_disabled: flag("transformation_disabled")?,
        use_routing_index: flag("use_routing_index")?,
        backlog_cooldown_until: SimTime(num("backlog_cooldown_until_ns")?),
        backlog_wakeup_scheduled: flag("backlog_wakeup_scheduled")?,
        fault_plan: FaultPlan::from_json(j.get("fault_plan").ok_or("state: missing fault_plan")?)?,
        fault_cursor: num("fault_cursor")? as usize,
        degraded_until: times("degraded_until_ns")?,
        link_down_until: times("link_down_until_ns")?,
        stall_until: times("stall_until_ns")?,
        recorder: recorder_from_json(j.get("recorder").ok_or("state: missing recorder")?)?,
        feed: FeedState::from_json(j.get("feed").ok_or("state: missing feed")?)?,
        cache: match j.get("cache") {
            None | Some(Json::Null) => None,
            Some(v) => Some(ClusterCache::from_json(v)?),
        },
    })
}

impl SimSnapshot {
    /// The full snapshot document: envelope + hashed state payload.
    pub fn to_json(&self) -> Json {
        let state = state_to_json(&self.state);
        let payload_hash = hex64(fnv1a(state.to_string().as_bytes()));
        let context = match &self.context {
            None => Json::Null,
            Some(c) => {
                let mut o = Json::obj();
                o.set("sweep", c.sweep.as_str())
                    .set("horizon_s", c.horizon_s)
                    .set("job_index", c.job_index)
                    .set("key", c.key.as_str())
                    .set(
                        "stream_dir",
                        c.stream_dir.as_deref().map(Json::from).unwrap_or(Json::Null),
                    );
                o
            }
        };
        let mut o = Json::obj();
        o.set("schema_version", SNAPSHOT_SCHEMA_VERSION)
            .set("kind", "sim-snapshot")
            .set("system", self.system.as_str())
            .set("config_fingerprint", self.config_fingerprint.as_str())
            .set("sim_time_ns", self.sim_time.0)
            .set("context", context)
            .set("payload_hash", payload_hash.as_str())
            .set("state", state);
        o
    }

    /// Parse and integrity-check a snapshot document: schema version,
    /// kind, and the FNV-1a payload hash over the canonical state
    /// encoding must all match.
    pub fn from_json(j: &Json) -> Result<SimSnapshot, String> {
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_u64())
            .ok_or("snapshot: missing schema_version")?;
        if version != SNAPSHOT_SCHEMA_VERSION {
            return Err(format!(
                "snapshot: schema_version {version} unsupported (this reads \
                 v{SNAPSHOT_SCHEMA_VERSION})"
            ));
        }
        if j.get("kind").and_then(|v| v.as_str()) != Some("sim-snapshot") {
            return Err("snapshot: not a sim-snapshot document".into());
        }
        let state_json = j.get("state").ok_or("snapshot: missing state")?;
        let want = j
            .get("payload_hash")
            .and_then(|v| v.as_str())
            .ok_or("snapshot: missing payload_hash")?;
        let got = hex64(fnv1a(state_json.to_string().as_bytes()));
        if got != want {
            return Err(format!(
                "snapshot: state payload hash {got} does not match envelope {want} (file \
                 corrupted or edited after capture)"
            ));
        }
        let context = match j.get("context") {
            None | Some(Json::Null) => None,
            Some(c) => Some(RunContext {
                sweep: c
                    .get("sweep")
                    .and_then(|v| v.as_str())
                    .ok_or("snapshot context: bad sweep")?
                    .to_string(),
                horizon_s: c
                    .get("horizon_s")
                    .and_then(|v| v.as_f64())
                    .ok_or("snapshot context: bad horizon_s")?,
                job_index: c
                    .get("job_index")
                    .and_then(|v| v.as_u64())
                    .ok_or("snapshot context: bad job_index")?
                    as usize,
                key: c
                    .get("key")
                    .and_then(|v| v.as_str())
                    .ok_or("snapshot context: bad key")?
                    .to_string(),
                stream_dir: match c.get("stream_dir") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_str().ok_or("snapshot context: bad stream_dir")?.to_string(),
                    ),
                },
            }),
        };
        Ok(SimSnapshot {
            system: j
                .get("system")
                .and_then(|v| v.as_str())
                .ok_or("snapshot: missing system")?
                .to_string(),
            config_fingerprint: j
                .get("config_fingerprint")
                .and_then(|v| v.as_str())
                .ok_or("snapshot: missing config_fingerprint")?
                .to_string(),
            sim_time: SimTime(
                j.get("sim_time_ns").and_then(|v| v.as_u64()).ok_or("snapshot: bad sim_time")?,
            ),
            context,
            state: state_from_json(state_json)?,
        })
    }

    /// Serialize to the canonical single-document string (with trailing
    /// newline, the on-disk form).
    pub fn to_string_pretty(&self) -> String {
        format!("{}\n", self.to_json())
    }

    /// Parse [`SimSnapshot::to_string_pretty`] output.
    pub fn parse(text: &str) -> Result<SimSnapshot, String> {
        let doc = Json::parse(text.trim_end())?;
        Self::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn config_fingerprint_is_sensitive_to_knobs() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let a = config_fingerprint(&cfg);
        assert_eq!(a, config_fingerprint(&cfg.clone()), "deterministic");
        let mut b = cfg.clone();
        b.min_dwell_s += 1.0;
        assert_ne!(a, config_fingerprint(&b), "dwell change must show");
        let mut c = cfg.clone();
        c.seed ^= 1;
        assert_ne!(a, config_fingerprint(&c), "seed change must show");
        let mut d = cfg.clone();
        d.model = ModelConfig::llama3_8b();
        assert_ne!(a, config_fingerprint(&d), "model change must show");
        let mut e = cfg;
        e.slo_interactive_deadline_s += 1.0;
        assert_ne!(a, config_fingerprint(&e), "SLO deadline change must show");
    }

    #[test]
    fn pipeline_policy_state_roundtrips_through_json() {
        let composed = PolicyState::Pipeline {
            cache: true,
            slo: true,
            admit: true,
            base: Box::new(PolicyState::Gyges {
                reserved: vec![2, 5],
                reserve_cap: 0.55,
                last_long_seen: Some(SimTime(123_456)),
                long_hold_s: 45.0,
            }),
        };
        let back = policy_from_json(&policy_to_json(&composed)).unwrap();
        assert_eq!(back, composed);
        // Plain compositions still serialize as the legacy kinds.
        let rr = PolicyState::RoundRobin { cursor: 3 };
        let j = policy_to_json(&rr);
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("rr"));
        assert_eq!(policy_from_json(&j).unwrap(), rr);
    }

    #[test]
    fn tampered_payload_is_rejected() {
        // Build a tiny synthetic snapshot through a real simulation in
        // the integration tests; here, check the envelope mechanics on a
        // hand-rolled doc: flipping one state byte must break the hash.
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let sim = crate::coordinator::ClusterSim::new(
            cfg.clone(),
            crate::coordinator::SystemKind::Gyges,
            crate::workload::Trace::default(),
        );
        let snap = sim.snapshot().unwrap();
        let text = snap.to_string_pretty();
        assert_eq!(SimSnapshot::parse(&text).unwrap(), snap, "roundtrip");
        // Tamper inside the state object (retain valid JSON).
        let tampered = text.replace("\"queue_seq\":0", "\"queue_seq\":7");
        assert_ne!(tampered, text, "tamper target must exist");
        let err = SimSnapshot::parse(&tampered).unwrap_err();
        assert!(err.contains("payload hash"), "{err}");
    }
}
