//! Deterministic simulation snapshot/resume.
//!
//! Three layers:
//!
//! * [`state`] — schema-v1 serialization of complete `ClusterSim` state
//!   (FNV-1a payload hash, config fingerprint, self-describing run
//!   context). `ClusterSim::snapshot` / `ClusterSim::from_snapshot`
//!   produce/consume it; a resumed run is byte-identical to an
//!   uninterrupted one because every value the event loop's next
//!   decision can observe is restored exactly (and everything derived —
//!   routing indices, incremental aggregates — is rebuilt from the
//!   restored primaries and debug-checked against a full rescan).
//! * [`runner`] — the checkpointed sweep runner behind `gyges snapshot`
//!   / `gyges resume`: runs a named sweep's canonical job list serially,
//!   checkpointing every N simulated seconds (kill-safe tmp+rename
//!   writes, per-job row files with payload hashes, a run manifest that
//!   pins the job-list fingerprint), and resumes an interrupted run
//!   from its latest checkpoint to the exact bytes
//!   `run_sweep_serial` + `results_to_jsonl` would have produced.
//! * the branch explorer (`experiments::branch`) — forks one snapshot
//!   under K policy variants from the same warm cluster state and
//!   reports per-branch divergence against the parent timeline.

pub mod runner;
pub mod state;

pub use runner::{resume_run, run_checkpointed, RunOutcome, RunPlan};
pub use state::{RunContext, SimSnapshot, SNAPSHOT_SCHEMA_VERSION};
