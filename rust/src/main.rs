//! `gyges` — the leader binary: cluster-simulation serving, real-model
//! PJRT serving, and experiment reproduction.
//!
//! Usage:
//!   gyges info
//!   gyges serve       [--model M] [--policy gyges|rr|llf] [--system S]
//!                     [--qps Q | --hybrid] [--horizon SECS] [--seed N]
//!                     [--config FILE]
//!   gyges serve-real  [--artifacts DIR] [--shorts N] [--longs N]
//!   gyges repro       <table1|table2|table3|fig2|fig9|fig10|fig11|fig12|
//!                      fig13|fig14|static|all> [--horizon SECS]

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, SystemKind};
use gyges::util::Args;
use gyges::workload::Trace;

fn main() {
    gyges::util::logging::init(gyges::util::logging::Level::Info);
    let args = Args::from_env();
    let code = match args.command() {
        Some("info") => cmd_info(),
        Some("serve") => cmd_serve(&args),
        Some("serve-real") => cmd_serve_real(&args),
        Some("repro") => cmd_repro(&args),
        _ => {
            eprintln!("usage: gyges <info|serve|serve-real|repro> [options]  (see rust/src/main.rs)");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_info() -> i32 {
    let mut t =
        gyges::util::Table::new(["model", "weights", "layers", "heads/kv", "MLP frac", "GPU"]);
    for m in ModelConfig::all() {
        let gpu = gyges::config::GpuSpec::for_model(&m);
        t.row([
            m.name.to_string(),
            gyges::util::fmt_bytes(m.total_weight_bytes()),
            format!("{}", m.num_layers),
            format!("{}/{}", m.num_heads, m.num_kv_heads),
            format!("{:.1}%", m.mlp_weight_fraction() * 100.0),
            gpu.name.to_string(),
        ]);
    }
    t.print();
    0
}

fn build_cluster(args: &Args) -> Result<ClusterConfig, String> {
    if let Some(path) = args.get("config") {
        return ClusterConfig::from_file(path);
    }
    let model_name = args.get_or("model", "qwen2.5-32b");
    let model = ModelConfig::by_name(&model_name)
        .ok_or_else(|| format!("unknown model {model_name:?}"))?;
    let mut cfg = ClusterConfig::paper_default(model);
    if let Some(p) = args.get("policy") {
        cfg.policy = Policy::by_name(p).ok_or_else(|| format!("unknown policy {p:?}"))?;
    }
    cfg.hosts = args.parsed_or("hosts", cfg.hosts);
    cfg.seed = args.parsed_or("seed", cfg.seed);
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = match build_cluster(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let system = match args.get_or("system", "gyges").as_str() {
        "gyges" => SystemKind::Gyges,
        "gyges-" => SystemKind::GygesNoOverlap,
        "basic" => SystemKind::Basic,
        "seesaw" => SystemKind::Seesaw,
        "kunserve" => SystemKind::KunServe,
        "loongserve" => SystemKind::LoongServe,
        other => {
            eprintln!("unknown system {other:?}");
            return 2;
        }
    };
    let horizon = args.parsed_or("horizon", 600.0);
    let trace = if args.flag("hybrid") || args.get("qps").is_none() {
        Trace::hybrid_paper(cfg.seed, horizon)
    } else {
        Trace::production(cfg.seed, args.parsed_or("qps", 1.0), horizon)
    };
    println!(
        "serving {} requests over {horizon}s on {} ({} GPUs, policy {}, system {})",
        trace.len(),
        cfg.model.name,
        cfg.total_gpus(),
        cfg.policy.name(),
        system.name()
    );
    let out = run_system(cfg, system, None, trace);
    println!("{}", out.report.line());
    println!(
        "scale-ups {}  scale-downs {}  deferred {}  steps {}",
        out.counters.scale_ups, out.counters.scale_downs, out.counters.deferred, out.counters.steps
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_real(_args: &Args) -> i32 {
    eprintln!("serve-real needs the PJRT runtime: rebuild with `--features pjrt`");
    2
}

#[cfg(feature = "pjrt")]
fn cmd_serve_real(args: &Args) -> i32 {
    use gyges::serve::{synthetic_workload, RealServer, ServerConfig};
    let artifacts = args.get_or("artifacts", "artifacts");
    let mut server = match RealServer::new(&artifacts, ServerConfig::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to load artifacts from {artifacts:?}: {e:#}");
            return 1;
        }
    };
    println!(
        "loaded gyges-tiny from {} (tp={})",
        server.rt.man.dir.display(),
        server.rt.tp
    );
    if let Err(e) = server.rt.verify_oracle() {
        eprintln!("oracle verification FAILED: {e:#}");
        return 1;
    }
    println!("oracle verified: rust serving path matches the python reference exactly");
    let shorts = args.parsed_or("shorts", 6usize);
    let longs = args.parsed_or("longs", 2usize);
    let reqs = synthetic_workload(args.parsed_or("seed", 42), shorts, longs, server.rt.man.vocab);
    match server.serve(&reqs) {
        Ok(rep) => {
            println!(
                "served {} requests in {:.2}s  throughput {:.1} tok/s  transforms {} ({} moved)",
                rep.results.len(),
                rep.wall_s,
                rep.throughput_tps,
                rep.transforms,
                gyges::util::fmt_bytes(rep.transform_bytes as u64)
            );
            println!(
                "TTFT p50 {:.1} ms p99 {:.1} ms   TPOT p50 {:.1} ms p99 {:.1} ms",
                rep.ttft.p50 * 1e3,
                rep.ttft.p99 * 1e3,
                rep.tpot.p50 * 1e3,
                rep.tpot.p99 * 1e3
            );
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}

fn cmd_repro(args: &Args) -> i32 {
    use gyges::experiments as exp;
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let horizon = args.parsed_or("horizon", 300.0);
    let run = |name: &str| match name {
        "table1" => drop(exp::table1()),
        "table2" => drop(exp::table2()),
        "table3" => drop(exp::table3()),
        "fig2" => drop(exp::fig2()),
        "fig9" => drop(exp::fig9()),
        "fig10" => drop(exp::fig10()),
        "fig11" => drop(exp::fig11()),
        "fig12" => drop(exp::fig12(horizon, &ModelConfig::eval_set())),
        "fig13" => drop(exp::fig13()),
        "fig14" => drop(exp::fig14(horizon, &[2.0, 6.0, 10.0])),
        "static" => drop(exp::static_hybrid_compare(horizon)),
        other => eprintln!("unknown experiment {other:?}"),
    };
    if what == "all" {
        for name in [
            "table1", "table2", "table3", "fig2", "fig9", "fig10", "fig11", "fig12", "fig13",
            "fig14", "static",
        ] {
            println!();
            run(name);
        }
    } else {
        run(what);
    }
    println!("\nJSON rows written under target/repro/");
    0
}
