//! `gyges` — the leader binary: cluster-simulation serving, real-model
//! PJRT serving, and experiment reproduction.
//!
//! Usage:
//!   gyges info
//!   gyges serve       [--model M] [--policy gyges|rr|llf (+ -slo/-admit
//!                     suffixes, e.g. gyges-slo-admit)] [--system S]
//!                     [--qps Q | --hybrid | --trace-dir DIR]
//!                     [--horizon SECS] [--seed N] [--config FILE]
//!   gyges serve-real  [--artifacts DIR] [--shorts N] [--longs N]
//!   gyges repro       <table1|table2|table3|fig2|fig9|fig10|fig11|fig12|
//!                      fig13|fig14|fig-faults|fig-slo|fig-cache|static|all>
//!                     [--horizon SECS]
//!   gyges chaos       [--horizon SECS]   (fig-faults: goodput/SLO/drops
//!                     for gyges|rr|llf|static under a seeded fault storm)
//!   gyges slo         [--horizon SECS]   (fig-slo: SLO lanes + admission
//!                     control vs plain policies on a classed stream)
//!   gyges cache       [--horizon SECS]   (fig-cache: prefix-cache-aware
//!                     routing vs plain policies on a shared-prefix stream)
//!   gyges sweep-shard <fig12|fig12-qwen|fig13|fig14|ablation-hold|
//!                      fig-faults|fig-slo|fig-cache> [--shard K/N] [--horizon SECS]
//!                     [--out-dir DIR] [--stream-dir DIR]
//!   gyges sweep-merge <sweep> [--dir DIR] [--out FILE]
//!                     [--expect-horizon SECS]
//!   gyges trace-gen   <sweep|production> [--horizon SECS] [--segment-s S]
//!                     [--out-dir DIR] [--resume-from K] [--qps Q] [--seed N]
//!                     [--bursty]
//!   gyges sweep-launch <sweep> [--horizon SECS] [--segment-s S]
//!                     [--shards N] [--trace-dir DIR] [--out-dir DIR]
//!                     [--out FILE] [--procs J] [--in-process]
//!   gyges snapshot    <sweep> [--horizon SECS] [--every SIM_SECS]
//!                     [--dir DIR] [--out FILE] [--stream-dir DIR]
//!                     [--stop-after K]   (exit 3 = paused deliberately)
//!   gyges resume      --dir DIR [--stop-after K]
//!   gyges branch      --snapshot FILE [--holds CSV] [--policies CSV]
//!                     [--no-static] [--out FILE] [--threads N]
//!   gyges bench-gate  [--baseline FILE] [--fresh FILE] [--max-regress F]
//!   gyges lint        [--strict] [--json] [--root DIR]   (determinism-
//!                     contract linter, rules D01-D07; exit 1 on findings;
//!                     --strict escalates suppression-hygiene warnings)
//!
//! Global options (every subcommand):
//!   --queue <calendar|heap>   event-queue backend (default calendar;
//!                             outputs are byte-identical across both)
//!   --legacy-routing          route plain policies through the legacy
//!                             (pre-pipeline) reference implementations
//!                             (needs a `--features legacy-policies`
//!                             build; the CI byte-comparison uses it)

#![forbid(unsafe_code)]

use gyges::config::{ClusterConfig, ModelConfig, PolicyId};
use gyges::coordinator::{run_system, SystemKind};
use gyges::util::Args;
use gyges::workload::Trace;

fn main() {
    gyges::util::logging::init(gyges::util::logging::Level::Info);
    let args = Args::from_env();
    // Global knob, parsed before dispatch so every subcommand (serve,
    // repro, sweeps, snapshot/resume, ...) honours it. The backend is
    // deliberately NOT part of ClusterConfig or the snapshot format:
    // both backends pop the exact same (time, seq) stream, so outputs
    // are byte-identical and snapshots resume across backends.
    if let Some(q) = args.get("queue") {
        match gyges::sim::QueueBackend::by_name(q) {
            Some(b) => gyges::sim::set_queue_backend(b),
            None => {
                eprintln!("unknown --queue backend {q:?} (expected calendar|heap)");
                std::process::exit(2);
            }
        }
    }
    if args.flag("legacy-routing") {
        #[cfg(feature = "legacy-policies")]
        gyges::coordinator::set_legacy_routing(true);
        #[cfg(not(feature = "legacy-policies"))]
        {
            eprintln!(
                "--legacy-routing needs the legacy reference policies: rebuild with \
                 `--features legacy-policies`"
            );
            std::process::exit(2);
        }
    }
    let code = match args.command() {
        Some("info") => cmd_info(),
        Some("serve") => cmd_serve(&args),
        Some("serve-real") => cmd_serve_real(&args),
        Some("repro") => cmd_repro(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("slo") => cmd_slo(&args),
        Some("cache") => cmd_cache(&args),
        Some("sweep-shard") => cmd_sweep_shard(&args),
        Some("sweep-merge") => cmd_sweep_merge(&args),
        Some("trace-gen") => gyges::experiments::launch::trace_gen_cli(&args),
        Some("sweep-launch") => gyges::experiments::launch::sweep_launch_cli(&args),
        Some("snapshot") => gyges::snapshot::runner::snapshot_cli(&args),
        Some("resume") => gyges::snapshot::runner::resume_cli(&args),
        Some("branch") => gyges::experiments::branch::branch_cli(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        Some("lint") => gyges::analysis::lint_cli(&args),
        _ => {
            eprintln!(
                "usage: gyges <info|serve|serve-real|repro|chaos|slo|cache|sweep-shard|\
                 sweep-merge|trace-gen|sweep-launch|snapshot|resume|branch|bench-gate|lint> \
                 [options]  (see rust/src/main.rs)"
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_info() -> i32 {
    let mut t =
        gyges::util::Table::new(["model", "weights", "layers", "heads/kv", "MLP frac", "GPU"]);
    for m in ModelConfig::all() {
        let gpu = gyges::config::GpuSpec::for_model(&m);
        t.row([
            m.name.to_string(),
            gyges::util::fmt_bytes(m.total_weight_bytes()),
            format!("{}", m.num_layers),
            format!("{}/{}", m.num_heads, m.num_kv_heads),
            format!("{:.1}%", m.mlp_weight_fraction() * 100.0),
            gpu.name.to_string(),
        ]);
    }
    t.print();
    0
}

fn build_cluster(args: &Args) -> Result<ClusterConfig, String> {
    if let Some(path) = args.get("config") {
        return ClusterConfig::from_file(path);
    }
    let model_name = args.get_or("model", "qwen2.5-32b");
    let model = ModelConfig::by_name(&model_name)
        .ok_or_else(|| format!("unknown model {model_name:?}"))?;
    let mut cfg = ClusterConfig::paper_default(model);
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyId::parse(p).ok_or_else(|| format!("unknown policy {p:?}"))?;
    }
    cfg.hosts = args.parsed_or("hosts", cfg.hosts);
    cfg.seed = args.parsed_or("seed", cfg.seed);
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = match build_cluster(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let system = match args.get_or("system", "gyges").as_str() {
        "gyges" => SystemKind::Gyges,
        "gyges-" => SystemKind::GygesNoOverlap,
        "basic" => SystemKind::Basic,
        "seesaw" => SystemKind::Seesaw,
        "kunserve" => SystemKind::KunServe,
        "loongserve" => SystemKind::LoongServe,
        other => {
            eprintln!("unknown system {other:?}");
            return 2;
        }
    };
    // --trace-dir replays a `gyges trace-gen` segment directory (any
    // label, including `production` streams) one segment at a time —
    // peak trace memory stays O(segment) however long the horizon is.
    if let Some(dir) = args.get("trace-dir") {
        let path = std::path::Path::new(dir);
        let sd = match gyges::workload::SegmentDir::open(path) {
            Ok(sd) => sd,
            Err(e) => {
                eprintln!("serve: {e}");
                return 1;
            }
        };
        println!(
            "serving {} streamed requests ({} segments) from {dir} on {} ({} GPUs, policy {}, \
             system {})",
            sd.requests,
            sd.files.len(),
            cfg.model.name,
            cfg.total_gpus(),
            cfg.policy.name(),
            system.name()
        );
        let source = gyges::workload::SegmentFileSource::new(sd);
        let out = gyges::coordinator::ClusterSim::with_source(cfg, system, Box::new(source)).run();
        println!("{}", out.report.line());
        println!(
            "scale-ups {}  scale-downs {}  deferred {}  steps {}  peak buffered {}",
            out.counters.scale_ups,
            out.counters.scale_downs,
            out.counters.deferred,
            out.counters.steps,
            out.trace_peak_buffered
        );
        return match out.error {
            None => 0,
            Some(e) => {
                eprintln!("serve: run terminated early: {e}");
                1
            }
        };
    }
    let horizon = args.parsed_or("horizon", 600.0);
    let trace = if args.flag("hybrid") || args.get("qps").is_none() {
        Trace::hybrid_paper(cfg.seed, horizon)
    } else {
        Trace::production(cfg.seed, args.parsed_or("qps", 1.0), horizon)
    };
    println!(
        "serving {} requests over {horizon}s on {} ({} GPUs, policy {}, system {})",
        trace.len(),
        cfg.model.name,
        cfg.total_gpus(),
        cfg.policy.name(),
        system.name()
    );
    let out = run_system(cfg, system, None, trace);
    println!("{}", out.report.line());
    println!(
        "scale-ups {}  scale-downs {}  deferred {}  steps {}",
        out.counters.scale_ups, out.counters.scale_downs, out.counters.deferred, out.counters.steps
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_real(_args: &Args) -> i32 {
    eprintln!("serve-real needs the PJRT runtime: rebuild with `--features pjrt`");
    2
}

#[cfg(feature = "pjrt")]
fn cmd_serve_real(args: &Args) -> i32 {
    use gyges::serve::{synthetic_workload, RealServer, ServerConfig};
    let artifacts = args.get_or("artifacts", "artifacts");
    let mut server = match RealServer::new(&artifacts, ServerConfig::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to load artifacts from {artifacts:?}: {e:#}");
            return 1;
        }
    };
    println!(
        "loaded gyges-tiny from {} (tp={})",
        server.rt.man.dir.display(),
        server.rt.tp
    );
    if let Err(e) = server.rt.verify_oracle() {
        eprintln!("oracle verification FAILED: {e:#}");
        return 1;
    }
    println!("oracle verified: rust serving path matches the python reference exactly");
    let shorts = args.parsed_or("shorts", 6usize);
    let longs = args.parsed_or("longs", 2usize);
    let reqs = synthetic_workload(args.parsed_or("seed", 42), shorts, longs, server.rt.man.vocab);
    match server.serve(&reqs) {
        Ok(rep) => {
            println!(
                "served {} requests in {:.2}s  throughput {:.1} tok/s  transforms {} ({} moved)",
                rep.results.len(),
                rep.wall_s,
                rep.throughput_tps,
                rep.transforms,
                gyges::util::fmt_bytes(rep.transform_bytes as u64)
            );
            println!(
                "TTFT p50 {:.1} ms p99 {:.1} ms   TPOT p50 {:.1} ms p99 {:.1} ms",
                rep.ttft.p50 * 1e3,
                rep.ttft.p99 * 1e3,
                rep.tpot.p50 * 1e3,
                rep.tpot.p99 * 1e3
            );
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}

/// Run one stripe of a named figure sweep and write its JSONL + manifest
/// (the per-process / per-CI-matrix-job entry point; see PERF.md).
fn cmd_sweep_shard(args: &Args) -> i32 {
    use gyges::experiments::{shard, NAMED_SWEEPS};
    let Some(sweep) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!("usage: gyges sweep-shard <{}> [--shard K/N] ...", NAMED_SWEEPS.join("|"));
        return 2;
    };
    shard::shard_cli_named(args, sweep)
}

/// Merge the shard files of one sweep back into the serial driver's
/// exact bytes, rejecting incomplete or inconsistent shard sets.
/// `--expect-horizon S` additionally proves the shards were built from
/// the CANONICAL registry job list at horizon S (the manifests'
/// `jobs_hash` alone proves mutual consistency, not canonicality — a
/// full shard set run at the wrong horizon merges cleanly otherwise).
fn cmd_sweep_merge(args: &Args) -> i32 {
    use gyges::experiments::shard::{job_list_hash, merge_shards, read_shard_dir};
    let Some(sweep) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!(
            "usage: gyges sweep-merge <sweep> [--dir DIR] [--out FILE] [--expect-horizon S]"
        );
        return 2;
    };
    let dir = args.get_or("dir", "target/shards");
    let inputs = match read_shard_dir(std::path::Path::new(&dir), sweep) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("sweep-merge: {e}");
            return 1;
        }
    };
    let merged = match merge_shards(&inputs) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("sweep-merge REJECTED ({} shard files under {dir}): {e}", inputs.len());
            return 1;
        }
    };
    if let Some(raw) = args.get("expect-horizon") {
        // A typo'd value must not silently skip the canonicality check.
        let Ok(expect_h) = raw.parse::<f64>() else {
            eprintln!("sweep-merge: --expect-horizon {raw:?} is not a number");
            return 2;
        };
        let Some(canonical) = gyges::experiments::named_sweep_jobs(sweep, expect_h) else {
            eprintln!("sweep-merge: --expect-horizon given but {sweep:?} is not a registry sweep");
            return 1;
        };
        let want = job_list_hash(&canonical);
        let got = &inputs[0].manifest.jobs_hash;
        if *got != want {
            eprintln!(
                "sweep-merge REJECTED: shards are mutually consistent but do NOT match the \
                 canonical {sweep} job list at horizon {expect_h} (jobs_hash {got} != {want})"
            );
            return 1;
        }
    }
    let out = args.get_or("out", &format!("{dir}/{sweep}-merged.jsonl"));
    if let Err(e) = std::fs::write(&out, &merged) {
        eprintln!("sweep-merge: write {out}: {e}");
        return 1;
    }
    println!(
        "merged {} shards of {sweep}: {} rows, {} bytes → {out}",
        inputs.len(),
        merged.lines().count(),
        merged.len()
    );
    0
}

/// Gate CI on the fresh bench snapshot vs the committed baseline.
fn cmd_bench_gate(args: &Args) -> i32 {
    use gyges::util::Json;
    let baseline_path = args.get_or("baseline", "BENCH_sim.json");
    let fresh_path = args.get_or("fresh", "target/BENCH_sim.json");
    // No silent fallback: the gate guards CI, so a typo'd tolerance
    // must be loud, not replaced by the default.
    let max_regress = match args.get("max-regress") {
        None => 0.25,
        Some(v) => match v.parse::<f64>() {
            Ok(x) => x,
            Err(_) => {
                eprintln!("bench-gate: --max-regress {v:?} is not a number (e.g. 0.25 = 25%)");
                return 2;
            }
        },
    };
    let load = |path: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))
    };
    let (baseline, fresh) = match (load(&baseline_path), load(&fresh_path)) {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-gate: {e}");
            return 1;
        }
    };
    let report = gyges::metrics::gate::evaluate(&baseline, &fresh, max_regress);
    println!(
        "bench-gate: {baseline_path} (baseline) vs {fresh_path} (fresh), tolerance {:.0}%",
        max_regress * 100.0
    );
    for line in &report.lines {
        println!("  {line}");
    }
    report.exit_code()
}

fn cmd_repro(args: &Args) -> i32 {
    use gyges::experiments as exp;
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let horizon = args.parsed_or("horizon", 300.0);
    let run = |name: &str| match name {
        "table1" => drop(exp::table1()),
        "table2" => drop(exp::table2()),
        "table3" => drop(exp::table3()),
        "fig2" => drop(exp::fig2()),
        "fig9" => drop(exp::fig9()),
        "fig10" => drop(exp::fig10()),
        "fig11" => drop(exp::fig11()),
        "fig12" => drop(exp::fig12(horizon, &ModelConfig::eval_set())),
        "fig13" => drop(exp::fig13()),
        "fig14" => drop(exp::fig14(horizon, &[2.0, 6.0, 10.0])),
        "fig-faults" => drop(exp::chaos::fig_faults(horizon)),
        "fig-slo" => drop(exp::slo::fig_slo(horizon)),
        "fig-cache" => drop(exp::cache::fig_cache(horizon)),
        "static" => drop(exp::static_hybrid_compare(horizon)),
        other => eprintln!("unknown experiment {other:?}"),
    };
    if what == "all" {
        for name in [
            "table1", "table2", "table3", "fig2", "fig9", "fig10", "fig11", "fig12", "fig13",
            "fig14", "static",
        ] {
            println!();
            run(name);
        }
    } else {
        run(what);
    }
    println!("\nJSON rows written under target/repro/");
    0
}

/// The chaos experiment: the Figure-12 workload under a seeded fault
/// storm, Gyges vs RR/LLF/static (`fig-faults` in the sweep registry).
fn cmd_chaos(args: &Args) -> i32 {
    let horizon =
        args.parsed_or("horizon", gyges::experiments::named_sweep_default_horizon("fig-faults"));
    gyges::experiments::chaos::fig_faults(horizon);
    println!("\nJSON rows written under target/repro/");
    0
}

/// The SLO-composition experiment: lanes + admission control vs plain
/// policies on an overloaded classed stream (`fig-slo` in the registry).
fn cmd_slo(args: &Args) -> i32 {
    let horizon =
        args.parsed_or("horizon", gyges::experiments::named_sweep_default_horizon("fig-slo"));
    gyges::experiments::slo::fig_slo(horizon);
    println!("\nJSON rows written under target/repro/");
    0
}

/// The cache-awareness experiment: prefix-cache-affinity scoring vs
/// plain policies on a shared-prefix stream (`fig-cache` in the
/// registry).
fn cmd_cache(args: &Args) -> i32 {
    let horizon =
        args.parsed_or("horizon", gyges::experiments::named_sweep_default_horizon("fig-cache"));
    gyges::experiments::cache::fig_cache(horizon);
    println!("\nJSON rows written under target/repro/");
    0
}
