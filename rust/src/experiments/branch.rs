//! Counterfactual branch explorer: `gyges branch`.
//!
//! Forks ONE simulation snapshot — a warm cluster mid-trace, with its
//! in-flight transforms, backlog, and queue state intact — under K
//! policy variants, runs every branch to completion through the PR 1
//! parallel driver pattern (work-stealing threads, fixed-order merge),
//! and reports per-branch divergence (throughput / p99 TTFT / transform
//! count deltas) against the *parent timeline* (the unmodified
//! continuation of the snapshot). This is the head-to-head framing the
//! paper's transform-vs-queue claims need: every policy decides from
//! the SAME warm state, which no cold-start comparison can produce —
//! a cold start lets each policy shape its own cluster long before the
//! interesting decision point.
//!
//! Determinism: each branch is a pure function of (snapshot, variant),
//! so repeated explorations produce byte-identical reports (enforced by
//! `rust/tests/snapshot.rs`).

use super::sweep::{outcome_to_result, sweep_threads, SweepResult};
use super::{named_sweep_jobs, NAMED_SWEEPS};
use crate::config::{Policy, PolicyId};
use crate::coordinator::{ClusterSim, PolicyState};
use crate::experiments::launch::streamed_named_jobs;
use crate::snapshot::state::SimSnapshot;
use crate::util::json::Json;
use crate::util::Args;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Branch-report schema version.
pub const BRANCH_SCHEMA_VERSION: u64 = 1;

/// One counterfactual to fork from the snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum BranchKind {
    /// The unmodified continuation — the reference timeline.
    Parent,
    /// Swap in a fresh routing policy — any [`PolicyId`], composed or
    /// plain (its internal state — RR cursor, hysteresis stamp — starts
    /// cold; the cluster does not).
    Policy(PolicyId),
    /// Keep the Gyges policy but override its anti-oscillation hold
    /// (the A3 grid, now from warm state).
    GygesHold(f64),
    /// Freeze the current topology: no further transformations (the
    /// static-deployment baseline, §3.3, continued from warm state).
    Static,
}

impl BranchKind {
    pub fn name(&self) -> String {
        match self {
            BranchKind::Parent => "parent".into(),
            BranchKind::Policy(p) => p.name().into(),
            BranchKind::GygesHold(h) => format!("gyges-hold{h}"),
            BranchKind::Static => "static".into(),
        }
    }
}

/// The variant list `gyges branch` runs by default (parent excluded —
/// it is always added as the reference).
pub fn default_branches() -> Vec<BranchKind> {
    vec![
        BranchKind::GygesHold(0.0),
        BranchKind::GygesHold(120.0),
        BranchKind::Policy(Policy::RoundRobin.into()),
        BranchKind::Policy(Policy::LeastLoadFirst.into()),
        BranchKind::Static,
    ]
}

fn fork(
    cfg: &crate::config::ClusterConfig,
    snap: &SimSnapshot,
    kind: &BranchKind,
) -> Result<ClusterSim, String> {
    match kind {
        BranchKind::Parent => ClusterSim::from_snapshot(cfg.clone(), snap),
        BranchKind::Policy(p) => {
            Ok(ClusterSim::from_snapshot(cfg.clone(), snap)?.with_policy(*p))
        }
        BranchKind::GygesHold(h) => {
            // Override ONLY the hold knob inside the restored policy
            // state: the warm reserve list and hysteresis stamp carry
            // over, so the branch measures the knob, not a
            // policy-state reset. (`set_gyges_hold` would rebuild the
            // policy cold — the A3 cold-start path, wrong here.) On a
            // non-Gyges snapshot the knob has no meaning and the
            // branch degenerates to the parent timeline.
            let mut warm = snap.clone();
            if let PolicyState::Gyges { long_hold_s, .. } = &mut warm.state.policy {
                *long_hold_s = *h;
            }
            ClusterSim::from_snapshot(cfg.clone(), &warm)
        }
        BranchKind::Static => {
            let mut sim = ClusterSim::from_snapshot(cfg.clone(), snap)?;
            sim.disable_transformation();
            Ok(sim)
        }
    }
}

fn transforms(r: &SweepResult) -> u64 {
    r.counters.scale_ups + r.counters.scale_downs
}

/// Fork `snap` under `[parent] + branches`, run all to completion in
/// parallel, and build the divergence report. The returned JSON is
/// canonical (sorted object keys, fixed branch order), so identical
/// inputs produce identical bytes.
pub fn explore(
    cfg: &crate::config::ClusterConfig,
    snap: &SimSnapshot,
    branches: &[BranchKind],
    threads: usize,
) -> Result<Json, String> {
    if branches.is_empty() {
        return Err("branch: no variants to explore".into());
    }
    let mut kinds: Vec<BranchKind> = Vec::with_capacity(branches.len() + 1);
    kinds.push(BranchKind::Parent);
    kinds.extend_from_slice(branches);
    // Fork first (serially — from_snapshot is cheap), then run the
    // branches with the PR 1 work-stealing pattern and merge results in
    // fixed branch order, so the report is deterministic regardless of
    // which branch finishes first.
    let mut sims = Vec::with_capacity(kinds.len());
    for kind in &kinds {
        sims.push(Some(fork(cfg, snap, kind)?));
    }
    let sims: Vec<Mutex<Option<ClusterSim>>> = sims.into_iter().map(Mutex::new).collect();
    let slots: Vec<Mutex<Option<SweepResult>>> = kinds.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.clamp(1, kinds.len());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= kinds.len() {
                    break;
                }
                let sim = sims[i].lock().unwrap().take().expect("each branch forks once");
                let result = outcome_to_result(&kinds[i].name(), sim.run());
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    let results: Vec<SweepResult> = slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every claimed branch stores a result"))
        .collect();
    let parent = &results[0];

    let mut branch_rows = Vec::new();
    for (kind, r) in kinds.iter().zip(&results).skip(1) {
        let mut delta = Json::obj();
        delta
            .set("throughput_tps", r.report.throughput_tps - parent.report.throughput_tps)
            .set("ttft_p99_s", r.report.ttft_p99_s - parent.report.ttft_p99_s)
            .set("tpot_p50_s", r.report.tpot_p50_s - parent.report.tpot_p50_s)
            .set(
                "transforms",
                transforms(r) as i64 - transforms(parent) as i64,
            )
            .set("completed", r.report.completed as i64 - parent.report.completed as i64);
        let mut row = Json::obj();
        row.set("name", kind.name().as_str())
            .set("row", r.to_json())
            .set("delta_vs_parent", delta);
        branch_rows.push(row);
    }
    let context = match &snap.context {
        None => Json::Null,
        Some(c) => {
            let mut o = Json::obj();
            o.set("sweep", c.sweep.as_str())
                .set("horizon_s", c.horizon_s)
                .set("job_index", c.job_index)
                .set("key", c.key.as_str());
            o
        }
    };
    let mut report = Json::obj();
    report
        .set("schema_version", BRANCH_SCHEMA_VERSION)
        .set("kind", "branch-report")
        .set("forked_at_s", snap.sim_time.as_secs_f64())
        .set("context", context)
        .set("parent", parent.to_json())
        .set("branches", Json::Arr(branch_rows));
    Ok(report)
}

/// Render the report as the human table `gyges branch` prints.
pub fn print_report(report: &Json) {
    let mut t = crate::util::Table::new([
        "branch", "tput (tps)", "Δ tput", "ttft p99", "Δ p99", "transforms", "Δ",
    ]);
    let row_of = |r: &Json| -> (f64, f64, u64) {
        let rep = r.get("report");
        let get = |k: &str| rep.and_then(|x| x.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let counters = r.get("counters");
        let cnt = |k: &str| {
            counters.and_then(|x| x.get(k)).and_then(|v| v.as_u64()).unwrap_or(0)
        };
        (get("throughput_tps"), get("ttft_p99_s"), cnt("scale_ups") + cnt("scale_downs"))
    };
    if let Some(parent) = report.get("parent") {
        let (tput, p99, tr) = row_of(parent);
        t.row([
            "parent".to_string(),
            format!("{tput:.1}"),
            "-".into(),
            format!("{p99:.2}s"),
            "-".into(),
            format!("{tr}"),
            "-".into(),
        ]);
    }
    for b in report.get("branches").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let name = b.get("name").and_then(|v| v.as_str()).unwrap_or("?");
        let Some(row) = b.get("row") else { continue };
        let (tput, p99, tr) = row_of(row);
        let delta = b.get("delta_vs_parent");
        let d = |k: &str| delta.and_then(|x| x.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0);
        t.row([
            name.to_string(),
            format!("{tput:.1}"),
            format!("{:+.1}", d("throughput_tps")),
            format!("{p99:.2}s"),
            format!("{:+.2}s", d("ttft_p99_s")),
            format!("{tr}"),
            format!("{:+.0}", d("transforms")),
        ]);
    }
    t.print();
}

/// `gyges branch --snapshot FILE ...` — fork one checkpoint under
/// policy variants and write/print the divergence report. The snapshot
/// must carry a run context (the CLI runner always attaches one): the
/// job's configuration is rebuilt from the sweep registry and proven
/// against the embedded fingerprint.
pub fn branch_cli(args: &Args) -> i32 {
    let Some(path) = args.get("snapshot") else {
        eprintln!(
            "usage: gyges branch --snapshot FILE [--holds CSV] [--policies CSV] [--no-static] \
             [--out FILE] [--threads N]"
        );
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("branch: read {path}: {e}");
            return 1;
        }
    };
    let snap = match SimSnapshot::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("branch: {path}: {e}");
            return 1;
        }
    };
    let Some(ctx) = snap.context.clone() else {
        eprintln!("branch: {path}: snapshot lacks a run context (captured outside the runner)");
        return 1;
    };
    let jobs = match &ctx.stream_dir {
        Some(root) => match streamed_named_jobs(&ctx.sweep, ctx.horizon_s, Path::new(root)) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("branch: {e}");
                return 1;
            }
        },
        None => match named_sweep_jobs(&ctx.sweep, ctx.horizon_s) {
            Some(j) => j,
            None => {
                eprintln!(
                    "branch: unknown sweep {:?} (known: {})",
                    ctx.sweep,
                    NAMED_SWEEPS.join(", ")
                );
                return 1;
            }
        },
    };
    let Some(job) = jobs.get(ctx.job_index) else {
        eprintln!(
            "branch: snapshot says job {} but {} has only {} jobs",
            ctx.job_index,
            ctx.sweep,
            jobs.len()
        );
        return 1;
    };
    // Build the variant list.
    let mut branches = Vec::new();
    match (args.get("holds"), args.get("policies"), args.flag("no-static")) {
        (None, None, false) => branches = default_branches(),
        (holds, policies, no_static) => {
            if let Some(csv) = holds {
                for part in csv.split(',').filter(|s| !s.trim().is_empty()) {
                    match part.trim().parse::<f64>() {
                        Ok(h) if h.is_finite() && h >= 0.0 => {
                            branches.push(BranchKind::GygesHold(h))
                        }
                        _ => {
                            eprintln!("branch: --holds entry {part:?} is not a valid hold");
                            return 2;
                        }
                    }
                }
            }
            if let Some(csv) = policies {
                for part in csv.split(',').filter(|s| !s.trim().is_empty()) {
                    match PolicyId::parse(part.trim()) {
                        Some(p) => branches.push(BranchKind::Policy(p)),
                        None => {
                            eprintln!("branch: unknown policy {part:?}");
                            return 2;
                        }
                    }
                }
            }
            if !no_static {
                branches.push(BranchKind::Static);
            }
        }
    }
    // Strict parse: a typo'd count must not silently become the default
    // (the PR 4 `parsed_strict` rule for every numeric CLI flag).
    let threads = match args.parsed_strict("threads", sweep_threads()) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("branch: {e}");
            return 2;
        }
    };
    let report = match explore(&job.cfg, &snap, &branches, threads) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("branch: {e}");
            return 1;
        }
    };
    println!(
        "forked {}[{}] ({}) at sim-time {:.3}s into {} branches + parent:",
        ctx.sweep,
        ctx.job_index,
        ctx.key,
        snap.sim_time.as_secs_f64(),
        branches.len()
    );
    print_report(&report);
    let out = args.get_or("out", "target/branch-report.json");
    if let Some(parent) = Path::new(&out).parent() {
        if !parent.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(parent);
        }
    }
    if let Err(e) = std::fs::write(&out, format!("{report}\n")) {
        eprintln!("branch: write {out}: {e}");
        return 1;
    }
    println!("report → {out}");
    0
}
