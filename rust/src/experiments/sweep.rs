//! Parallel, deterministic sweep driver.
//!
//! An experiment sweep (policy × model × QPS, Figures 12–14) is a list of
//! independent [`ClusterSim`](crate::coordinator::ClusterSim) runs. Each
//! run is a pure function of its [`SweepJob`], so the driver fans jobs out
//! across OS threads with a work-stealing shared counter (rayon is not in
//! the offline registry snapshot; `std::thread::scope` + an atomic next-job
//! index gives the same dynamic load balancing for coarse-grained jobs)
//! and merges results **by job index** — the merged output is byte-
//! identical to the serial driver's, which the `determinism` integration
//! test and [`tests::parallel_matches_serial_bytes`] both enforce.
//!
//! Thread count: `GYGES_SWEEP_THREADS` env var, else the machine's
//! available parallelism. Set it to 1 to force the serial path.

use crate::config::{ClusterConfig, PolicyId};
use crate::coordinator::{ClusterSim, SimCounters, SystemKind};
use crate::faults::FaultPlan;
use crate::metrics::RunReport;
use crate::util::json::Json;
use crate::workload::{ChunkedTrace, ProductionStream, SegmentDir, SegmentFileSource};
use crate::workload::{StreamSource, Trace};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// How a job's request stream reaches the simulator. The first three
/// variants replay the *same* trace (and therefore produce byte-identical
/// rows — the streamed-replay guarantee `rust/tests/streaming.rs`
/// enforces); [`JobTrace::Stream`] is its own seeded workload whose
/// segmentation is part of its identity.
#[derive(Clone)]
pub enum JobTrace {
    /// Materialized trace fed as one segment (the classic path).
    Full(Arc<Trace>),
    /// Materialized trace fed in `segment_s` windows — same rows, feed
    /// buffer bounded by one window (the generator still materializes).
    Chunked { trace: Arc<Trace>, segment_s: f64 },
    /// JSONL segment files streamed lazily from a `gyges trace-gen`
    /// directory: O(segment) trace memory end to end.
    Dir(Arc<SegmentDir>),
    /// Per-segment seeded generation (multi-hour production stream):
    /// O(segment) memory with no files at all.
    Stream(ProductionStream),
}

impl JobTrace {
    /// Append this workload's identity to a manifest fingerprint. The
    /// three same-trace variants hash identically (request count, total
    /// tokens, last arrival) — a streamed shard set is provably the same
    /// sweep as a whole-trace one; a [`JobTrace::Stream`] hashes its
    /// generating spec instead (including `segment_s`, which shapes its
    /// draws).
    pub fn fingerprint_into(&self, bytes: &mut Vec<u8>) {
        let shape = |bytes: &mut Vec<u8>, len: u64, tokens: u64, last_bits: u64| {
            bytes.push(0x01);
            for v in [len, tokens, last_bits] {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        };
        match self {
            JobTrace::Full(t) | JobTrace::Chunked { trace: t, .. } => {
                let last = t.requests.last().map(|r| r.arrival.as_secs_f64().to_bits());
                shape(bytes, t.len() as u64, t.total_tokens(), last.unwrap_or(0));
            }
            JobTrace::Dir(d) => {
                let last = if d.requests == 0 {
                    0
                } else {
                    d.last_arrival.as_secs_f64().to_bits()
                };
                shape(bytes, d.requests, d.total_tokens, last);
            }
            JobTrace::Stream(s) => {
                bytes.push(0x02);
                for v in
                    [s.seed, s.qps.to_bits(), s.segment_s.to_bits(), s.horizon_s.to_bits()]
                {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                // The Figure-2b bursty overlay is part of the workload
                // identity; plain streams keep their historical hash
                // (no trailing discriminant byte was ever emitted).
                if let Some(l) = &s.longs {
                    bytes.push(0x03);
                    for v in [
                        l.quiet_rate.to_bits(),
                        l.burst_rate.to_bits(),
                        l.quiet_mean_s.to_bits(),
                        l.burst_mean_s.to_bits(),
                        l.input_len,
                    ] {
                        bytes.extend_from_slice(&v.to_le_bytes());
                    }
                }
                // Same discipline for the SLO-class mix: classless
                // streams hash exactly as before it existed.
                if let Some(m) = &s.slo {
                    bytes.push(0x04);
                    bytes.extend_from_slice(&m.interactive_frac.to_bits().to_le_bytes());
                }
                // And for the shared-prefix overlay: prefix-free
                // streams keep their historical hash.
                if let Some(p) = &s.prefix {
                    bytes.push(0x05);
                    for v in [
                        p.prompts,
                        p.prompt_blocks,
                        p.sessions,
                        p.session_blocks,
                        p.session_frac.to_bits(),
                    ] {
                        bytes.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
    }
}

/// One independent simulation in a sweep. Traces are shared via `Arc` so
/// a policy sweep over one workload does not deep-copy it per job at
/// submission time (each run still clones its own working copy).
#[derive(Clone)]
pub struct SweepJob {
    /// Caller-chosen identifier, carried through to the result.
    pub key: String,
    pub cfg: ClusterConfig,
    pub system: SystemKind,
    /// Routing policy override — a full [`PolicyId`], so composed
    /// policies (`gyges-slo`, `rr-admit`, …) sweep like base ones;
    /// `None` keeps the config's policy.
    pub policy: Option<PolicyId>,
    pub trace: JobTrace,
    /// Override for the Gyges policy's anti-oscillation hold (ablation
    /// A3); `None` keeps the policy default.
    pub gyges_hold: Option<f64>,
    /// Seeded fault storm armed before the run (`fig-faults` / `gyges
    /// chaos`); `None` (and an empty plan) leave the simulation byte-
    /// identical to a fault-free job.
    pub faults: Option<FaultPlan>,
    /// Pin the deployment static (no scale-up/down) — the "static"
    /// comparator in the chaos experiment.
    pub disable_transformation: bool,
    /// Arm the prefix-cache model even for cache-blind policies —
    /// `fig-cache` measures every policy under the same cache physics
    /// and only varies routing awareness. `-cache` policies arm it
    /// implicitly; `false` on a plain policy is the historical
    /// cache-free simulation, byte for byte.
    pub arm_cache: bool,
}

impl SweepJob {
    pub fn new(
        key: impl Into<String>,
        cfg: ClusterConfig,
        system: SystemKind,
        policy: Option<PolicyId>,
        trace: Arc<Trace>,
    ) -> SweepJob {
        Self::with_job_trace(key, cfg, system, policy, JobTrace::Full(trace))
    }

    /// Build a job over any [`JobTrace`] delivery mode.
    pub fn with_job_trace(
        key: impl Into<String>,
        cfg: ClusterConfig,
        system: SystemKind,
        policy: Option<PolicyId>,
        trace: JobTrace,
    ) -> SweepJob {
        SweepJob {
            key: key.into(),
            cfg,
            system,
            policy,
            trace,
            gyges_hold: None,
            faults: None,
            disable_transformation: false,
            arm_cache: false,
        }
    }

    /// Run this job with a custom Gyges long-request hold.
    pub fn with_gyges_hold(mut self, hold_s: f64) -> SweepJob {
        self.gyges_hold = Some(hold_s);
        self
    }

    /// Arm a fault plan for this job (validated against the job's
    /// cluster shape when the simulator is built).
    pub fn with_faults(mut self, plan: FaultPlan) -> SweepJob {
        self.faults = Some(plan);
        self
    }

    /// Pin the deployment static: routing still runs, transformation
    /// never fires.
    pub fn with_transformation_disabled(mut self) -> SweepJob {
        self.disable_transformation = true;
        self
    }

    /// Arm the prefix-cache model regardless of the policy's `-cache`
    /// flag (track-only for cache-blind baselines).
    pub fn with_cache(mut self) -> SweepJob {
        self.arm_cache = true;
        self
    }

    /// Switch a materialized job to chunked (streamed) replay of the
    /// same trace — rows stay byte-identical; no-op for jobs already
    /// streaming from files or a generator.
    pub fn replay_chunked(mut self, segment_s: f64) -> SweepJob {
        self.trace = match self.trace {
            JobTrace::Full(t) | JobTrace::Chunked { trace: t, .. } => {
                JobTrace::Chunked { trace: t, segment_s }
            }
            other => other,
        };
        self
    }
}

/// The portable outcome of one job: everything the figure renderers need,
/// without the full per-request recorder.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub key: String,
    pub report: RunReport,
    pub counters: SimCounters,
    /// Per-second output-token series (Figure 13).
    pub tps_series: Vec<(u64, u64)>,
    /// Stringified [`crate::coordinator::SimError`], if the run was cut.
    pub error: Option<String>,
    /// Prefix-cache tallies, `None` when the job never armed the cache.
    pub cache: Option<crate::cache::CacheCounters>,
}

impl SweepResult {
    /// Canonical JSON form (object keys sort deterministically), used by
    /// the byte-identity tests and `BENCH_sim.json`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        counters
            .set("scale_ups", self.counters.scale_ups)
            .set("scale_downs", self.counters.scale_downs)
            .set("deferred", self.counters.deferred)
            .set("steps", self.counters.steps)
            .set("events", self.counters.events)
            .set("arrival_events", self.counters.arrival_events)
            .set("step_events", self.counters.step_events)
            .set("transform_done_events", self.counters.transform_done_events)
            .set("stale_events", self.counters.stale_events)
            .set("backlog_wakeup_events", self.counters.backlog_wakeup_events)
            .set("routes", self.counters.routes)
            .set("kicks", self.counters.kicks)
            .set("backlog_retries", self.counters.backlog_retries)
            .set("backlog_requeues", self.counters.backlog_requeues)
            .set("backlog_suppressed", self.counters.backlog_suppressed)
            .set("backlog_wait_s", self.counters.backlog_wait.as_secs_f64())
            .set("fault_events", self.counters.fault_events)
            .set("recovery_events", self.counters.recovery_events)
            .set("crashed_instances", self.counters.crashed_instances)
            .set("crash_requeued", self.counters.crash_requeued)
            .set("dropped", self.counters.dropped)
            .set("transform_rollbacks", self.counters.transform_rollbacks)
            .set("stalled_instances", self.counters.stalled_instances)
            .set("scale_up_blocked", self.counters.scale_up_blocked)
            .set("preemptions", self.counters.preemptions)
            .set("admission_dropped", self.counters.admission_dropped);
        let series: Vec<Json> = self
            .tps_series
            .iter()
            .map(|&(s, c)| Json::Arr(vec![Json::from(s), Json::from(c)]))
            .collect();
        let mut o = Json::obj();
        o.set("key", self.key.as_str())
            .set("report", self.report.to_json())
            .set("counters", counters)
            .set("tps_series", Json::Arr(series))
            .set(
                "error",
                self.error.as_deref().map(Json::from).unwrap_or(Json::Null),
            );
        // Absence-encoded: rows from cache-free jobs (every pre-cache
        // figure) serialize byte-identically to before the field.
        if let Some(c) = &self.cache {
            let mut cj = Json::obj();
            cj.set("lookups", c.lookups)
                .set("hit_blocks", c.hit_blocks)
                .set("miss_blocks", c.miss_blocks)
                .set("inserted_blocks", c.inserted_blocks)
                .set("evicted_blocks", c.evicted_blocks)
                .set("invalidations", c.invalidations)
                .set("hit_rate", c.hit_rate());
            o.set("cache", cj);
        }
        o
    }
}

/// Build the simulator one job describes, policy/hold applied — shared
/// by the driver below, the checkpointed snapshot runner, and the
/// branch explorer (all three must construct the byte-identical sim).
pub fn build_job_sim(job: &SweepJob) -> ClusterSim {
    let mut sim = match &job.trace {
        JobTrace::Full(t) => ClusterSim::new(job.cfg.clone(), job.system, (**t).clone()),
        JobTrace::Chunked { trace, segment_s } => ClusterSim::with_source(
            job.cfg.clone(),
            job.system,
            Box::new(ChunkedTrace::new((**trace).clone(), *segment_s)),
        ),
        JobTrace::Dir(d) => ClusterSim::with_source(
            job.cfg.clone(),
            job.system,
            Box::new(SegmentFileSource::new((**d).clone())),
        ),
        JobTrace::Stream(spec) => ClusterSim::with_source(
            job.cfg.clone(),
            job.system,
            Box::new(StreamSource::new(spec.clone())),
        ),
    };
    if let Some(p) = job.policy {
        sim = sim.with_policy(p);
    }
    if job.arm_cache {
        sim.arm_cache();
    }
    if let Some(hold) = job.gyges_hold {
        sim.set_gyges_hold(hold);
    }
    if job.disable_transformation {
        sim.disable_transformation();
    }
    if let Some(plan) = &job.faults {
        if !plan.is_empty() {
            sim.set_fault_plan(plan.clone()).expect("sweep job fault plan must fit its cluster");
        }
    }
    sim
}

/// Fold a finished simulation into the portable per-job row.
pub fn outcome_to_result(key: &str, out: crate::coordinator::SimOutcome) -> SweepResult {
    SweepResult {
        key: key.to_string(),
        tps_series: out.recorder.tps_series(),
        report: out.report,
        counters: out.counters,
        error: out.error.map(|e| e.to_string()),
        cache: out.cache,
    }
}

fn run_job(job: &SweepJob) -> SweepResult {
    outcome_to_result(&job.key, build_job_sim(job).run())
}

/// Worker count: `GYGES_SWEEP_THREADS` override, else hardware threads.
pub fn sweep_threads() -> usize {
    if let Some(n) = std::env::var("GYGES_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run every job on the calling thread, in order.
pub fn run_sweep_serial(jobs: &[SweepJob]) -> Vec<SweepResult> {
    jobs.iter().map(run_job).collect()
}

/// Run jobs across `threads` workers. Workers steal the next unclaimed job
/// index; results land in per-job slots and are merged in job order, so
/// the output is byte-identical to [`run_sweep_serial`] regardless of
/// completion order.
pub fn run_sweep_parallel(jobs: &[SweepJob], threads: usize) -> Vec<SweepResult> {
    if jobs.is_empty() {
        return Vec::new();
    }
    let workers = threads.clamp(1, jobs.len());
    if workers == 1 {
        return run_sweep_serial(jobs);
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<SweepResult>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let result = run_job(&jobs[i]);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every claimed job stores a result"))
        .collect()
}

/// The default driver: parallel across [`sweep_threads`] workers.
pub fn run_sweep(jobs: &[SweepJob]) -> Vec<SweepResult> {
    run_sweep_parallel(jobs, sweep_threads())
}

/// Surface cut runs loudly (stderr) and report whether any job errored.
/// Figure renderers call this so an event-capped run can never silently
/// contribute partial numbers to a table.
pub fn warn_on_errors(results: &[SweepResult]) -> bool {
    let mut any = false;
    for r in results {
        if let Some(e) = &r.error {
            eprintln!("WARNING: sweep job {:?} terminated early: {e} — its rows are partial", r.key);
            any = true;
        }
    }
    any
}

/// Serialize a merged result list to one canonical string (one JSON object
/// per line, in job order).
pub fn results_to_jsonl(results: &[SweepResult]) -> String {
    let mut s = String::new();
    for r in results {
        s.push_str(&r.to_json().to_string());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Policy};

    fn small_jobs() -> Vec<SweepJob> {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let trace = Arc::new(Trace::hybrid_paper(3, 60.0));
        [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges]
            .into_iter()
            .map(|p| {
                SweepJob::new(
                    format!("hybrid/{}", p.name()),
                    cfg.clone(),
                    SystemKind::Gyges,
                    Some(p.into()),
                    Arc::clone(&trace),
                )
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_bytes() {
        let jobs = small_jobs();
        let serial = run_sweep_serial(&jobs);
        let parallel = run_sweep_parallel(&jobs, 4);
        assert_eq!(
            results_to_jsonl(&serial),
            results_to_jsonl(&parallel),
            "parallel merge must be byte-identical to the serial driver"
        );
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        let jobs = small_jobs();
        let out = run_sweep_parallel(&jobs, 64);
        assert_eq!(out.len(), jobs.len());
        for (job, res) in jobs.iter().zip(&out) {
            assert_eq!(job.key, res.key, "results stay in job order");
            assert!(res.report.completed > 0);
            assert!(res.error.is_none());
        }
    }

    #[test]
    fn chunked_replay_jobs_match_full_replay_bytes() {
        let jobs = small_jobs();
        let chunked: Vec<SweepJob> =
            jobs.iter().cloned().map(|j| j.replay_chunked(9.0)).collect();
        assert_eq!(
            results_to_jsonl(&run_sweep_serial(&jobs)),
            results_to_jsonl(&run_sweep_serial(&chunked)),
            "streamed (chunked) replay must produce byte-identical sweep rows"
        );
    }

    #[test]
    fn empty_sweep_is_empty() {
        assert!(run_sweep_parallel(&[], 8).is_empty());
        assert!(run_sweep_serial(&[]).is_empty());
    }

    #[test]
    fn event_cap_surfaces_per_job() {
        let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        cfg.max_events = 10;
        let trace = Arc::new(Trace::hybrid_paper(4, 30.0));
        let jobs = vec![SweepJob::new(
            "capped",
            cfg,
            SystemKind::Gyges,
            Some(Policy::Gyges.into()),
            trace,
        )];
        let out = run_sweep(&jobs);
        assert!(out[0].error.as_deref().unwrap_or("").contains("event cap"));
    }
}
