//! Sharded sweep orchestration: fan a figure sweep out across processes
//! (or CI matrix jobs) and merge the pieces back to the exact bytes the
//! serial driver would have produced.
//!
//! A sweep is a canonical, deterministically ordered job list (see the
//! `fig12_jobs`/`fig13_jobs`/`fig14_jobs` builders). Shard `k` of `N`
//! runs the stripe `{k, k+N, k+2N, ...}` of that list through the
//! in-process work-stealing driver and emits two files:
//!
//!   `<sweep>-shard-<k>of<N>.jsonl`          one result row per job
//!   `<sweep>-shard-<k>of<N>.manifest.json`  completeness proof (v1)
//!
//! The manifest pins everything a merge needs to *prove* it reassembled
//! the whole sweep: schema version, shard index/count, the canonical
//! job-list length and a fingerprint over its keys + trace/config shape
//! (so shards from different sweeps, horizons, or workloads can never
//! be mixed), the global indices and keys this shard covered, and a
//! hash of the payload bytes. The
//! merge validates all of it, rejects missing / duplicated / foreign /
//! tampered shards loudly, and reorders rows by global job index — the
//! output is byte-identical to
//! [`run_sweep_serial`](super::sweep::run_sweep_serial) +
//! [`results_to_jsonl`](super::sweep::results_to_jsonl) on the same job
//! list, which `rust/tests/sharding.rs` enforces for every shard count.

use super::sweep::{results_to_jsonl, run_sweep, SweepJob};
use crate::util::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};

/// Manifest schema version this module reads and writes.
pub const SHARD_SCHEMA_VERSION: u64 = 1;

/// Upper bound on a manifest's claimed job count. Real sweeps are tens
/// of jobs; the cap exists so a corrupted/hand-edited manifest claiming
/// e.g. 1e15 jobs is rejected as a [`ShardError::BadManifest`] instead
/// of driving an unbounded allocation (OOM with no diagnostic) in
/// validation and merge.
pub const MAX_TOTAL_JOBS: usize = 1_000_000;

// ---------------------------------------------------------------------
// Shard spec
// ---------------------------------------------------------------------

/// Which stripe of the canonical job list a process runs: shard `index`
/// of `count` owns global job indices `index, index+count, ...`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    pub fn new(index: usize, count: usize) -> Result<ShardSpec, ShardError> {
        if count == 0 {
            return Err(ShardError::BadSpec("shard count must be >= 1".into()));
        }
        if index >= count {
            return Err(ShardError::BadSpec(format!(
                "shard index {index} out of range for {count} shards (want 0..{count})"
            )));
        }
        Ok(ShardSpec { index, count })
    }

    /// Parse the CLI form `k/N` (e.g. `--shard 2/8`).
    pub fn parse(s: &str) -> Result<ShardSpec, ShardError> {
        let (k, n) = s
            .split_once('/')
            .ok_or_else(|| ShardError::BadSpec(format!("expected k/N, got {s:?}")))?;
        let index = k
            .trim()
            .parse::<usize>()
            .map_err(|_| ShardError::BadSpec(format!("bad shard index {k:?} in {s:?}")))?;
        let count = n
            .trim()
            .parse::<usize>()
            .map_err(|_| ShardError::BadSpec(format!("bad shard count {n:?} in {s:?}")))?;
        ShardSpec::new(index, count)
    }

    /// The whole sweep as one shard (the unsharded reference run).
    pub fn full() -> ShardSpec {
        ShardSpec { index: 0, count: 1 }
    }

    /// Global job indices this shard owns, ascending.
    pub fn job_indices(&self, total_jobs: usize) -> Vec<usize> {
        (self.index..total_jobs).step_by(self.count).collect()
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Everything the shard/merge layer can reject. Merge failures are meant
/// to be loud: a missing or doctored shard must fail the pipeline, never
/// produce a silently partial figure.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardError {
    BadSpec(String),
    Io(String),
    BadManifest(String),
    /// Two shards disagree on a field every shard of one sweep must share.
    Mismatch { field: &'static str, detail: String },
    MissingShard(usize),
    DuplicateShard(usize),
    /// Payload bytes do not hash to what the manifest promised.
    PayloadHash { shard: usize, expected: String, actual: String },
    /// Payload rows disagree with the manifest's job list.
    RowMismatch { shard: usize, detail: String },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::BadSpec(m) => write!(f, "bad shard spec: {m}"),
            ShardError::Io(m) => write!(f, "shard I/O error: {m}"),
            ShardError::BadManifest(m) => write!(f, "bad shard manifest: {m}"),
            ShardError::Mismatch { field, detail } => {
                write!(f, "shard manifests disagree on {field}: {detail}")
            }
            ShardError::MissingShard(k) => write!(f, "shard {k} is missing"),
            ShardError::DuplicateShard(k) => write!(f, "shard {k} appears more than once"),
            ShardError::PayloadHash { shard, expected, actual } => write!(
                f,
                "shard {shard} payload hash {actual} does not match manifest {expected} \
                 (file corrupted or edited after the run)"
            ),
            ShardError::RowMismatch { shard, detail } => {
                write!(f, "shard {shard} rows disagree with manifest: {detail}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

// ---------------------------------------------------------------------
// Hashing (FNV-1a; no external crates offline)
// ---------------------------------------------------------------------

// Shared with the trace-segment files since PR 4; re-exported so shard
// tooling keeps its historical import path.
pub use crate::util::hash::fnv1a;
use crate::util::hash::hex64;

/// Fingerprint of the canonical job list (all jobs of the sweep, in
/// order): each job's key plus the trace/config facts that shape its
/// rows — workload shape (request count, total tokens, last arrival for
/// materialized/segment-dir traces; the generating spec for seeded
/// streams — see `JobTrace::fingerprint_into`), system, policy, seed,
/// fleet shape, event cap, hold override. Keys alone are not enough:
/// fig12/fig14 keys do not encode the horizon, so two runs of "the same
/// sweep" at different horizons would otherwise merge into a silently
/// mixed figure. The same-trace delivery modes (whole, chunked, segment
/// files) hash identically — streamed shards are provably the same
/// sweep as whole-trace shards. Strings are 0xFF-delimited (never valid
/// UTF-8), so adjacent fields cannot alias.
pub fn job_list_hash(jobs: &[SweepJob]) -> String {
    let mut bytes = Vec::new();
    for job in jobs {
        bytes.extend_from_slice(job.key.as_bytes());
        bytes.push(0xFF);
        bytes.extend_from_slice(job.system.name().as_bytes());
        bytes.push(0xFF);
        if let Some(p) = job.policy {
            bytes.extend_from_slice(p.name().as_bytes());
        }
        bytes.push(0xFF);
        job.trace.fingerprint_into(&mut bytes);
        for v in [
            job.cfg.seed,
            job.cfg.hosts as u64,
            job.cfg.gpus_per_host as u64,
            job.cfg.max_events,
            job.cfg.retry_max_attempts as u64,
            job.cfg.retry_backoff_base_s.to_bits(),
        ] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        // Presence discriminant first: Some(0.0) must not collide with
        // None (0.0f64.to_bits() == 0), and hold 0 vs the 45 s policy
        // default is exactly the pair A3 compares.
        match job.gyges_hold {
            Some(h) => {
                bytes.push(1);
                bytes.extend_from_slice(&h.to_bits().to_le_bytes());
            }
            None => bytes.push(0),
        }
        // Fault storm and static-deployment pin are part of the job's
        // identity: a faulted job must never merge with its unfaulted
        // twin (same key, same trace, very different rows).
        match &job.faults {
            Some(plan) => {
                bytes.push(1);
                plan.fingerprint_into(&mut bytes);
            }
            None => bytes.push(0),
        }
        bytes.push(job.disable_transformation as u8);
    }
    hex64(fnv1a(&bytes))
}

// ---------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------

/// The completeness proof written next to every shard's JSONL.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub schema_version: u64,
    /// Sweep name (e.g. `fig12`) — informational plus a first-line guard.
    pub sweep: String,
    pub shard_index: usize,
    pub shard_count: usize,
    /// Length of the canonical job list (all shards combined).
    pub total_jobs: usize,
    /// [`job_list_hash`] fingerprint of the canonical job list. All
    /// shards of one sweep share it; a shard built from a different job
    /// list (other horizon, model set, workload, or sweep) cannot slip
    /// into a merge.
    pub jobs_hash: String,
    /// Global job indices this shard ran, ascending (the `k, k+N, ...`
    /// stripe — recorded explicitly so the merge can verify rather than
    /// assume the striping rule).
    pub job_indices: Vec<usize>,
    /// Job keys aligned with `job_indices`.
    pub job_keys: Vec<String>,
    /// Row count of the payload JSONL (== `job_indices.len()`).
    pub rows: usize,
    /// Hex FNV-1a of the payload file's exact bytes.
    pub payload_hash: String,
}

impl ShardManifest {
    pub fn to_json(&self) -> Json {
        let indices: Vec<Json> = self.job_indices.iter().map(|&i| Json::from(i)).collect();
        let keys: Vec<Json> = self.job_keys.iter().map(|k| Json::from(k.as_str())).collect();
        let mut o = Json::obj();
        o.set("schema_version", self.schema_version)
            .set("sweep", self.sweep.as_str())
            .set("shard_index", self.shard_index)
            .set("shard_count", self.shard_count)
            .set("total_jobs", self.total_jobs)
            .set("jobs_hash", self.jobs_hash.as_str())
            .set("job_indices", Json::Arr(indices))
            .set("job_keys", Json::Arr(keys))
            .set("rows", self.rows)
            .set("payload_hash", self.payload_hash.as_str());
        o
    }

    /// Parse + structurally validate one manifest document.
    pub fn from_json(j: &Json) -> Result<ShardManifest, ShardError> {
        let bad = ShardError::BadManifest;
        let str_field = |k: &str| -> Result<String, ShardError> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| bad(format!("missing or non-string field {k:?}")))
        };
        let num_field = |k: &str| -> Result<u64, ShardError> {
            j.get(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| bad(format!("missing or non-integer field {k:?}")))
        };
        let schema_version = num_field("schema_version")?;
        if schema_version != SHARD_SCHEMA_VERSION {
            return Err(bad(format!(
                "schema_version {schema_version} unsupported (this reads v{SHARD_SCHEMA_VERSION})"
            )));
        }
        let m = ShardManifest {
            schema_version,
            sweep: str_field("sweep")?,
            shard_index: num_field("shard_index")? as usize,
            shard_count: num_field("shard_count")? as usize,
            total_jobs: num_field("total_jobs")? as usize,
            jobs_hash: str_field("jobs_hash")?,
            job_indices: j
                .get("job_indices")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| bad("missing or non-array field \"job_indices\"".into()))?
                .iter()
                .map(|v| {
                    v.as_u64()
                        .map(|x| x as usize)
                        .ok_or_else(|| bad("non-integer entry in job_indices".into()))
                })
                .collect::<Result<Vec<usize>, ShardError>>()?,
            job_keys: j
                .get("job_keys")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| bad("missing or non-array field \"job_keys\"".into()))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| bad("non-string entry in job_keys".into()))
                })
                .collect::<Result<Vec<String>, ShardError>>()?,
            rows: num_field("rows")? as usize,
            payload_hash: str_field("payload_hash")?,
        };
        m.validate()?;
        Ok(m)
    }

    /// Internal consistency (one manifest in isolation).
    pub fn validate(&self) -> Result<(), ShardError> {
        let bad = ShardError::BadManifest;
        // Bound-check BEFORE anything sized by total_jobs is allocated
        // (the expected stripe below, merge_shards' line table).
        if self.total_jobs > MAX_TOTAL_JOBS || self.shard_count > MAX_TOTAL_JOBS {
            return Err(bad(format!(
                "total_jobs {} / shard_count {} exceed the sanity cap {MAX_TOTAL_JOBS} \
                 (corrupted manifest?)",
                self.total_jobs, self.shard_count
            )));
        }
        if self.shard_count == 0 || self.shard_index >= self.shard_count {
            return Err(bad(format!(
                "shard index {} out of range for {} shards",
                self.shard_index, self.shard_count
            )));
        }
        if self.rows != self.job_indices.len() || self.rows != self.job_keys.len() {
            return Err(bad(format!(
                "rows={} but {} job_indices / {} job_keys",
                self.rows,
                self.job_indices.len(),
                self.job_keys.len()
            )));
        }
        let expected = ShardSpec { index: self.shard_index, count: self.shard_count }
            .job_indices(self.total_jobs);
        if self.job_indices != expected {
            return Err(bad(format!(
                "job_indices {:?} are not the {}/{} stripe of {} jobs (expected {:?})",
                self.job_indices, self.shard_index, self.shard_count, self.total_jobs, expected
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Running a shard
// ---------------------------------------------------------------------

/// Run shard `spec` of the canonical `jobs` list through the parallel
/// driver and return `(payload, manifest)`: the shard's JSONL bytes (one
/// row per owned job, in global-index order) plus its completeness proof.
pub fn run_sweep_shard(sweep: &str, jobs: &[SweepJob], spec: ShardSpec) -> (String, ShardManifest) {
    let indices = spec.job_indices(jobs.len());
    let subset: Vec<SweepJob> = indices.iter().map(|&i| jobs[i].clone()).collect();
    let results = run_sweep(&subset);
    let payload = results_to_jsonl(&results);
    let manifest = ShardManifest {
        schema_version: SHARD_SCHEMA_VERSION,
        sweep: sweep.to_string(),
        shard_index: spec.index,
        shard_count: spec.count,
        total_jobs: jobs.len(),
        jobs_hash: job_list_hash(jobs),
        job_keys: indices.iter().map(|&i| jobs[i].key.clone()).collect(),
        job_indices: indices,
        rows: subset.len(),
        payload_hash: hex64(fnv1a(payload.as_bytes())),
    };
    (payload, manifest)
}

/// File names a shard writes under its output directory.
pub fn shard_file_names(sweep: &str, spec: ShardSpec) -> (String, String) {
    let stem = format!("{sweep}-shard-{}of{}", spec.index, spec.count);
    (format!("{stem}.jsonl"), format!("{stem}.manifest.json"))
}

/// Paths + row count reported by [`write_shard`].
#[derive(Clone, Debug)]
pub struct WrittenShard {
    pub data_path: PathBuf,
    pub manifest_path: PathBuf,
    pub rows: usize,
}

/// Run shard `spec` of `jobs` and write its JSONL + manifest into `dir`
/// (created if absent).
pub fn write_shard(
    dir: &Path,
    sweep: &str,
    jobs: &[SweepJob],
    spec: ShardSpec,
) -> Result<WrittenShard, ShardError> {
    let io = |what: &str, e: std::io::Error| ShardError::Io(format!("{what}: {e}"));
    let (payload, manifest) = run_sweep_shard(sweep, jobs, spec);
    std::fs::create_dir_all(dir).map_err(|e| io(&format!("create {}", dir.display()), e))?;
    let (data_name, manifest_name) = shard_file_names(sweep, spec);
    let data_path = dir.join(data_name);
    let manifest_path = dir.join(manifest_name);
    std::fs::write(&data_path, &payload)
        .map_err(|e| io(&format!("write {}", data_path.display()), e))?;
    std::fs::write(&manifest_path, format!("{}\n", manifest.to_json()))
        .map_err(|e| io(&format!("write {}", manifest_path.display()), e))?;
    Ok(WrittenShard { data_path, manifest_path, rows: manifest.rows })
}

// ---------------------------------------------------------------------
// Merging
// ---------------------------------------------------------------------

/// One shard handed to the merge: its parsed manifest + raw payload.
#[derive(Clone, Debug)]
pub struct ShardInput {
    pub manifest: ShardManifest,
    pub payload: String,
}

/// Load every `<sweep>-shard-*.manifest.json` (+ sibling `.jsonl`) under
/// `dir`, in file-name order.
pub fn read_shard_dir(dir: &Path, sweep: &str) -> Result<Vec<ShardInput>, ShardError> {
    let io = |what: &str, e: std::io::Error| ShardError::Io(format!("{what}: {e}"));
    let prefix = format!("{sweep}-shard-");
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map_err(|e| io(&format!("read {}", dir.display()), e))?
        .filter_map(|entry| entry.ok())
        .filter_map(|entry| entry.file_name().into_string().ok())
        .filter(|n| n.starts_with(&prefix) && n.ends_with(".manifest.json"))
        .collect();
    names.sort();
    let mut inputs = Vec::with_capacity(names.len());
    for name in names {
        let manifest_path = dir.join(&name);
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| io(&format!("read {}", manifest_path.display()), e))?;
        let doc = Json::parse(&text)
            .map_err(|e| ShardError::BadManifest(format!("{}: {e}", manifest_path.display())))?;
        let manifest = ShardManifest::from_json(&doc)?;
        // The filename prefix selected this file; the manifest's own
        // sweep field must agree, or a renamed foreign shard could
        // smuggle another sweep's rows into the merge.
        if manifest.sweep != sweep {
            return Err(ShardError::Mismatch {
                field: "sweep",
                detail: format!(
                    "{} declares sweep {:?}, expected {sweep:?}",
                    manifest_path.display(),
                    manifest.sweep
                ),
            });
        }
        let data_name = name.replace(".manifest.json", ".jsonl");
        let data_path = dir.join(&data_name);
        let payload = std::fs::read_to_string(&data_path)
            .map_err(|e| io(&format!("read {}", data_path.display()), e))?;
        inputs.push(ShardInput { manifest, payload });
    }
    Ok(inputs)
}

/// Validate a complete shard set and reassemble the sweep's JSONL.
///
/// Guarantees on `Ok`: every shard 0..count was present exactly once, all
/// manifests agreed on (sweep, count, total, keys hash), every payload
/// hashed to its manifest's promise, every row's `key` matched the
/// manifest's job key, and the returned string is the rows of all shards
/// reordered by global job index — byte-identical to the serial driver's
/// output for the same canonical job list.
pub fn merge_shards(shards: &[ShardInput]) -> Result<String, ShardError> {
    let first = shards
        .first()
        .ok_or_else(|| ShardError::BadManifest("no shards to merge".into()))?;
    let count = first.manifest.shard_count;
    let total = first.manifest.total_jobs;
    for s in shards {
        let m = &s.manifest;
        m.validate()?;
        if m.sweep != first.manifest.sweep {
            return Err(ShardError::Mismatch {
                field: "sweep",
                detail: format!("{:?} vs {:?}", m.sweep, first.manifest.sweep),
            });
        }
        if m.shard_count != count {
            return Err(ShardError::Mismatch {
                field: "shard_count",
                detail: format!(
                    "shard {} says {} shards, shard {} says {count}",
                    m.shard_index, m.shard_count, first.manifest.shard_index
                ),
            });
        }
        if m.total_jobs != total {
            return Err(ShardError::Mismatch {
                field: "total_jobs",
                detail: format!("{} vs {total}", m.total_jobs),
            });
        }
        if m.jobs_hash != first.manifest.jobs_hash {
            return Err(ShardError::Mismatch {
                field: "jobs_hash",
                detail: format!(
                    "shard {} was built from a different job list ({} vs {})",
                    m.shard_index, m.jobs_hash, first.manifest.jobs_hash
                ),
            });
        }
    }

    let mut seen = vec![false; count];
    let mut lines: Vec<Option<&str>> = vec![None; total];
    for s in shards {
        let m = &s.manifest;
        if seen[m.shard_index] {
            return Err(ShardError::DuplicateShard(m.shard_index));
        }
        seen[m.shard_index] = true;
        let actual = hex64(fnv1a(s.payload.as_bytes()));
        if actual != m.payload_hash {
            return Err(ShardError::PayloadHash {
                shard: m.shard_index,
                expected: m.payload_hash.clone(),
                actual,
            });
        }
        let payload_lines: Vec<&str> = s.payload.lines().collect();
        if payload_lines.len() != m.rows {
            return Err(ShardError::RowMismatch {
                shard: m.shard_index,
                detail: format!("{} payload rows, manifest says {}", payload_lines.len(), m.rows),
            });
        }
        for ((&global, key), &line) in
            m.job_indices.iter().zip(&m.job_keys).zip(&payload_lines)
        {
            let row = Json::parse(line).map_err(|e| ShardError::RowMismatch {
                shard: m.shard_index,
                detail: format!("row for job {global} is not valid JSON: {e}"),
            })?;
            let row_key = row.get("key").and_then(|k| k.as_str()).unwrap_or("");
            if row_key != key.as_str() {
                return Err(ShardError::RowMismatch {
                    shard: m.shard_index,
                    detail: format!("row for job {global} has key {row_key:?}, expected {key:?}"),
                });
            }
            lines[global] = Some(line);
        }
    }
    if let Some(k) = seen.iter().position(|&s| !s) {
        return Err(ShardError::MissingShard(k));
    }

    let mut out = String::new();
    for (i, line) in lines.iter().enumerate() {
        match line {
            Some(l) => {
                out.push_str(l);
                out.push('\n');
            }
            // Unreachable once every stripe validated, but never emit a
            // silently partial merge if the invariant is ever broken.
            None => {
                return Err(ShardError::RowMismatch {
                    shard: i % count,
                    detail: format!("no shard produced a row for job {i}"),
                })
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// CLI glue (shared by `gyges sweep-shard` and the figure benches)
// ---------------------------------------------------------------------

/// Dispatch one shard of a named sweep: resolve the registry's job
/// list — with the sweep's own default horizon unless `--horizon` is
/// given — and run [`shard_cli`]. The single entry point behind every
/// figure bench's `--shard` mode and `gyges sweep-shard`, so job list
/// and horizon defaults can never drift between them. `--stream-dir D`
/// replays the sweep's traces from `gyges trace-gen` segment files
/// under `D` instead of materializing them (O(segment) trace memory;
/// rows stay byte-identical). Unknown sweep names exit 2.
pub fn shard_cli_named(args: &crate::util::Args, sweep: &str) -> i32 {
    // A typo'd horizon must not silently become the default: every
    // shard of one sweep would "agree" on the wrong job list and merge
    // cleanly into a figure the operator never asked for.
    let horizon =
        match args.parsed_strict::<f64>("horizon", super::named_sweep_default_horizon(sweep)) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("sweep-shard: {e}");
                return 2;
            }
        };
    let jobs = match args.get("stream-dir") {
        Some(dir) => match super::launch::streamed_named_jobs(sweep, horizon, Path::new(dir)) {
            Ok(jobs) => jobs,
            Err(e) => {
                eprintln!("sweep-shard: {e}");
                return 2;
            }
        },
        None => match super::named_sweep_jobs(sweep, horizon) {
            Some(jobs) => jobs,
            None => {
                eprintln!(
                    "unknown sweep {sweep:?} (known: {})",
                    super::NAMED_SWEEPS.join(", ")
                );
                return 2;
            }
        },
    };
    shard_cli(args, sweep, &jobs)
}

/// Drive one shard from parsed CLI args: `--shard k/N` (default `0/1`,
/// i.e. the unsharded reference run) and `--out-dir DIR` (default
/// `target/shards`). Returns a process exit code and prints what it
/// wrote, so benches and the `gyges` binary share one behaviour.
pub fn shard_cli(args: &crate::util::Args, sweep: &str, jobs: &[SweepJob]) -> i32 {
    let spec = match ShardSpec::parse(&args.get_or("shard", "0/1")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let dir = args.get_or("out-dir", "target/shards");
    match write_shard(Path::new(&dir), sweep, jobs, spec) {
        Ok(w) => {
            println!(
                "{sweep} shard {spec}: {} of {} jobs → {} (+ manifest)",
                w.rows,
                jobs.len(),
                w.data_path.display()
            );
            0
        }
        Err(e) => {
            eprintln!("{sweep} shard {spec} failed: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_validates() {
        assert_eq!(ShardSpec::parse("0/1").unwrap(), ShardSpec::full());
        assert_eq!(ShardSpec::parse("2/8").unwrap(), ShardSpec { index: 2, count: 8 });
        assert!(matches!(ShardSpec::parse("3/3"), Err(ShardError::BadSpec(_))));
        assert!(matches!(ShardSpec::parse("1/0"), Err(ShardError::BadSpec(_))));
        assert!(matches!(ShardSpec::parse("x/4"), Err(ShardError::BadSpec(_))));
        assert!(matches!(ShardSpec::parse("nonsense"), Err(ShardError::BadSpec(_))));
    }

    #[test]
    fn striping_partitions_every_job_exactly_once() {
        for total in [0usize, 1, 5, 12, 13] {
            for count in 1..=total + 2 {
                let mut owned = vec![0u32; total];
                for index in 0..count {
                    for i in ShardSpec::new(index, count).unwrap().job_indices(total) {
                        owned[i] += 1;
                    }
                }
                assert!(owned.iter().all(|&c| c == 1), "total={total} count={count}: {owned:?}");
            }
        }
    }

    fn manifest_fixture() -> ShardManifest {
        ShardManifest {
            schema_version: SHARD_SCHEMA_VERSION,
            sweep: "figX".into(),
            shard_index: 1,
            shard_count: 2,
            total_jobs: 5,
            jobs_hash: "00000000deadbeef".into(),
            job_indices: vec![1, 3],
            job_keys: vec!["b".into(), "d".into()],
            rows: 2,
            payload_hash: hex64(fnv1a(b"")),
        }
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let m = manifest_fixture();
        let text = m.to_json().to_string();
        let back = ShardManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn manifest_rejects_structural_lies() {
        let mut m = manifest_fixture();
        m.rows = 3; // rows != job_indices.len()
        assert!(matches!(m.validate(), Err(ShardError::BadManifest(_))));

        let mut m = manifest_fixture();
        m.job_indices = vec![0, 3]; // not the 1/2 stripe
        assert!(matches!(m.validate(), Err(ShardError::BadManifest(_))));

        let mut m = manifest_fixture();
        m.shard_index = 2; // out of range
        assert!(matches!(m.validate(), Err(ShardError::BadManifest(_))));

        let mut m = manifest_fixture();
        m.total_jobs = MAX_TOTAL_JOBS + 1; // must reject, not allocate
        assert!(matches!(m.validate(), Err(ShardError::BadManifest(_))));

        let mut doc = manifest_fixture().to_json();
        doc.set("schema_version", 99u64);
        assert!(matches!(ShardManifest::from_json(&doc), Err(ShardError::BadManifest(_))));
    }

    // (The FNV-1a reference-vector test lives with the implementation
    // in util::hash since the PR 4 move.)

    #[test]
    fn jobs_hash_separates_keys_and_workloads() {
        use crate::config::{ClusterConfig, ModelConfig};
        use crate::coordinator::SystemKind;
        use crate::workload::Trace;
        use std::sync::Arc;
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let trace = Arc::new(Trace::default());
        let job = |key: &str| {
            SweepJob::new(key, cfg.clone(), SystemKind::Gyges, None, Arc::clone(&trace))
        };
        // Key lists are length-delimited: ["ab","c"] != ["a","bc"].
        let ab_c = [job("ab"), job("c")];
        let a_bc = [job("a"), job("bc")];
        assert_ne!(job_list_hash(&ab_c), job_list_hash(&a_bc));
        // Identical keys but a different trace (e.g. another horizon)
        // must fingerprint differently too.
        let longer = Arc::new(Trace::hybrid_paper(3, 60.0));
        let same_key_other_trace =
            [SweepJob::new("ab", cfg.clone(), SystemKind::Gyges, None, longer), job("c")];
        assert_ne!(job_list_hash(&ab_c), job_list_hash(&same_key_other_trace));
        // A hold override is part of the fingerprint as well — and a
        // zero hold must not alias the no-override case.
        let with_hold = [job("ab").with_gyges_hold(15.0), job("c")];
        assert_ne!(job_list_hash(&ab_c), job_list_hash(&with_hold));
        let with_zero_hold = [job("ab").with_gyges_hold(0.0), job("c")];
        assert_ne!(job_list_hash(&ab_c), job_list_hash(&with_zero_hold));
        assert_ne!(job_list_hash(&with_hold), job_list_hash(&with_zero_hold));
    }
}
