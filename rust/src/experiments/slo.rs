//! The `fig-slo` experiment (`gyges slo`): what the composed pipeline
//! policies buy on an overloaded, SLO-classed production stream.
//!
//! Not a paper figure — the paper's clusters serve one traffic class —
//! but the natural companion to Figure 14 once the scheduler is a
//! filter/score pipeline: the same seeded production stream, now with a
//! hash-Bernoulli interactive/batch mix, swept over each base policy
//! (Gyges / RR / LLF) plain, with SLO lanes (`-slo`: interactive
//! backlog priority + preemption of queued batch prefills), and with
//! deadline admission control on top (`-slo-admit`: hopeless work is
//! shed at the decision stage instead of retried forever). Every job
//! replays the *identical* classed trace, so the only variable is the
//! policy composition. The whole sweep is a named sweep (`fig-slo`), so
//! sharding, trace-gen segment files, and CI's policy-pipeline-verify
//! smoke run all reuse the standard machinery.

use crate::config::{ClusterConfig, ModelConfig, Policy, PolicyId};
use crate::coordinator::SystemKind;
use crate::util::json::{write_repro_rows, Json};
use crate::util::table::Table;

use super::sweep::{self, run_sweep};
use super::{row_json, ShapeEntry, SweepShape, TraceSpec};

/// Seed of the classed workload trace group — fixed so the experiment
/// (and CI's smoke run) is one deterministic artifact.
pub const SLO_SEED: u64 = 0x510_C1A5;

/// Arrival rate (requests/s). Deliberately past what the paper-default
/// Qwen2.5-32B cluster sustains, so lanes and admission have work to do.
pub const SLO_QPS: f64 = 10.0;

/// Fraction of requests in the interactive class; the rest are batch.
pub const SLO_INTERACTIVE_FRAC: f64 = 0.9;

/// The fig-slo cluster config: paper defaults plus a bounded, backoff-ed
/// retry policy (under overload the backlog must shed load, not
/// livelock) and deadlines tight enough to bind within the sweep
/// horizon.
pub fn slo_cfg() -> ClusterConfig {
    let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    cfg.retry_max_attempts = 6;
    cfg.retry_backoff_base_s = 0.2;
    cfg.slo_interactive_deadline_s = 15.0;
    cfg.slo_batch_deadline_s = 90.0;
    cfg
}

/// The policy grid: each base policy plain, with SLO lanes, and with
/// lanes + admission control (9 jobs).
pub fn slo_policy_grid() -> Vec<PolicyId> {
    let mut grid = Vec::new();
    for base in [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges] {
        grid.push(PolicyId { base, cache: false, slo: false, admit: false });
        grid.push(PolicyId { base, cache: false, slo: true, admit: false });
        grid.push(PolicyId { base, cache: false, slo: true, admit: true });
    }
    grid
}

/// The `fig-slo` sweep shape: one classed stream, the full policy grid.
pub fn slo_shape(horizon_s: f64) -> SweepShape {
    let cfg = slo_cfg();
    let entries = slo_policy_grid()
        .into_iter()
        .map(|id| ShapeEntry {
            key: format!("slo/{}", id.name()),
            cfg: cfg.clone(),
            system: SystemKind::Gyges,
            policy: Some(id),
            gyges_hold: None,
            faults: None,
            static_deploy: false,
            arm_cache: false,
            trace_group: 0,
        })
        .collect();
    SweepShape {
        name: "fig-slo".into(),
        horizon_s,
        entries,
        traces: vec![TraceSpec::SloClassed {
            seed: SLO_SEED,
            qps: SLO_QPS,
            interactive_frac: SLO_INTERACTIVE_FRAC,
        }],
    }
}

/// Build the `fig-slo` job list for the sweep driver.
pub fn fig_slo_jobs(horizon_s: f64) -> Vec<super::sweep::SweepJob> {
    slo_shape(horizon_s).materialized_jobs()
}

/// Run the SLO-composition comparison and print/emit the table
/// (deterministic JSONL rows under `target/repro/fig-slo`).
pub fn fig_slo(horizon_s: f64) -> Vec<Json> {
    let jobs = fig_slo_jobs(horizon_s);
    let results = run_sweep(&jobs);
    sweep::warn_on_errors(&results);
    let mut t = Table::new([
        "policy", "tput (tps)", "ttft p50", "ttft p99", "int p99", "batch p99", "int slo",
        "completed", "preempts", "admit-drops", "dropped",
    ]);
    let mut rows = Vec::new();
    for out in &results {
        let c = &out.counters;
        // The classed stream guarantees a per-class breakdown; degrade
        // gracefully (dashes) rather than panic if a run saw no batch.
        let (int_p99, bat_p99, int_slo) = match &out.report.classes {
            Some(k) => (
                format!("{:.2}s", k.interactive_ttft_p99_s),
                format!("{:.2}s", k.batch_ttft_p99_s),
                format!("{:.1}%", k.interactive_slo * 100.0),
            ),
            None => ("-".into(), "-".into(), "-".into()),
        };
        t.row([
            out.key.clone(),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.2}s", out.report.ttft_p50_s),
            format!("{:.2}s", out.report.ttft_p99_s),
            int_p99,
            bat_p99,
            int_slo,
            format!("{}/{}", out.report.completed, out.report.total),
            format!("{}", c.preemptions),
            format!("{}", c.admission_dropped),
            format!("{}", c.dropped),
        ]);
        let mut row = row_json(&[
            ("key", Json::from(out.key.as_str())),
            ("tput", Json::from(out.report.throughput_tps)),
            ("ttft_p50", Json::from(out.report.ttft_p50_s)),
            ("ttft_p99", Json::from(out.report.ttft_p99_s)),
            ("slo_attainment", Json::from(out.report.slo_attainment)),
            ("completed", Json::from(out.report.completed)),
            ("total", Json::from(out.report.total)),
            ("preemptions", Json::from(c.preemptions)),
            ("admission_dropped", Json::from(c.admission_dropped)),
            ("dropped", Json::from(c.dropped)),
        ]);
        if let Some(k) = &out.report.classes {
            row.set("interactive_ttft_p50", k.interactive_ttft_p50_s)
                .set("interactive_ttft_p99", k.interactive_ttft_p99_s)
                .set("interactive_slo", k.interactive_slo)
                .set("batch_ttft_p50", k.batch_ttft_p50_s)
                .set("batch_ttft_p99", k.batch_ttft_p99_s)
                .set("batch_slo", k.batch_slo);
        }
        if let Some(e) = &out.error {
            row.set("error", e.as_str());
        }
        rows.push(row);
    }
    println!(
        "fig-slo — SLO lanes + admission control on an overloaded classed stream \
         ({SLO_QPS} qps, {:.0}% interactive, seed {SLO_SEED:#x})",
        SLO_INTERACTIVE_FRAC * 100.0
    );
    t.print();
    let _ = write_repro_rows("fig-slo", &rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweep::{results_to_jsonl, run_sweep_serial};

    #[test]
    fn slo_shape_builds_the_full_grid_over_one_trace() {
        let shape = slo_shape(120.0);
        assert_eq!(shape.name, "fig-slo");
        assert_eq!(shape.entries.len(), 9);
        assert_eq!(shape.traces.len(), 1);
        let names: Vec<&str> =
            shape.entries.iter().map(|e| e.policy.unwrap().name()).collect();
        assert!(names.contains(&"gyges") && names.contains(&"gyges-slo"));
        assert!(names.contains(&"gyges-slo-admit") && names.contains(&"rr-slo"));
        // Every entry replays trace group 0 — the composition is the
        // only variable.
        assert!(shape.entries.iter().all(|e| e.trace_group == 0));
    }

    #[test]
    fn slo_jobs_are_deterministic() {
        let jobs = fig_slo_jobs(45.0);
        let a = results_to_jsonl(&run_sweep_serial(&jobs));
        let b = results_to_jsonl(&run_sweep_serial(&jobs));
        assert_eq!(a, b, "same classed stream must reproduce byte-identically");
    }
}
