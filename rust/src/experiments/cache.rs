//! The `fig-cache` experiment (`gyges cache`): what prefix-cache-aware
//! routing buys on a shared-prefix production stream.
//!
//! Not a paper figure — the paper's workloads are prefix-free — but the
//! natural probe of the cache subsystem: a seeded production stream
//! with a system-prompt + multi-turn-session prefix overlay
//! ([`crate::workload::PrefixMix::paper`]), swept over each base policy
//! (Gyges / RR / LLF) plain and with cache-affinity scoring (`-cache`).
//! Every job arms the SAME prefix-cache model — baselines measure their
//! hit-rates track-only — and replays the *identical* prefixed trace,
//! so the only variable is whether routing can see the cache. The whole
//! sweep is a named sweep (`fig-cache`), so sharding, trace-gen segment
//! files, and CI's cache-verify smoke run all reuse the standard
//! machinery.

use crate::config::{ClusterConfig, ModelConfig, Policy, PolicyId};
use crate::coordinator::SystemKind;
use crate::util::json::{write_repro_rows, Json};
use crate::util::table::Table;
use crate::workload::PrefixMix;

use super::sweep::{self, run_sweep};
use super::{row_json, ShapeEntry, SweepShape, TraceSpec};

/// Seed of the prefixed workload trace group — fixed so the experiment
/// (and CI's smoke run) is one deterministic artifact.
pub const CACHE_SEED: u64 = 0xCAC_4E;

/// Arrival rate (requests/s). Busy but not saturating: routing still
/// has real choices, so affinity and load trade off visibly.
pub const CACHE_QPS: f64 = 6.0;

/// The fig-cache cluster config: unmodified paper defaults — the cache
/// experiment varies routing awareness, nothing else.
pub fn cache_cfg() -> ClusterConfig {
    ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
}

/// The policy grid: each base policy plain and cache-aware (6 jobs).
pub fn cache_policy_grid() -> Vec<PolicyId> {
    let mut grid = Vec::new();
    for base in [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges] {
        grid.push(PolicyId { base, cache: false, slo: false, admit: false });
        grid.push(PolicyId { base, cache: true, slo: false, admit: false });
    }
    grid
}

/// The `fig-cache` sweep shape: one prefixed stream, the plain/-cache
/// grid, the cache model armed on every job.
pub fn cache_shape(horizon_s: f64) -> SweepShape {
    let cfg = cache_cfg();
    let entries = cache_policy_grid()
        .into_iter()
        .map(|id| ShapeEntry {
            key: format!("cache/{}", id.name()),
            cfg: cfg.clone(),
            system: SystemKind::Gyges,
            policy: Some(id),
            gyges_hold: None,
            faults: None,
            static_deploy: false,
            arm_cache: true,
            trace_group: 0,
        })
        .collect();
    SweepShape {
        name: "fig-cache".into(),
        horizon_s,
        entries,
        traces: vec![TraceSpec::Prefixed {
            seed: CACHE_SEED,
            qps: CACHE_QPS,
            mix: PrefixMix::paper(),
        }],
    }
}

/// Build the `fig-cache` job list for the sweep driver.
pub fn fig_cache_jobs(horizon_s: f64) -> Vec<super::sweep::SweepJob> {
    cache_shape(horizon_s).materialized_jobs()
}

/// Run the cache-awareness comparison and print/emit the table
/// (deterministic JSONL rows under `target/repro/fig-cache`).
pub fn fig_cache(horizon_s: f64) -> Vec<Json> {
    let jobs = fig_cache_jobs(horizon_s);
    let results = run_sweep(&jobs);
    sweep::warn_on_errors(&results);
    let mut t = Table::new([
        "policy", "hit-rate", "hit/miss blocks", "evicted", "invalid", "tput (tps)", "ttft p50",
        "ttft p99", "completed",
    ]);
    let mut rows = Vec::new();
    for out in &results {
        // Every fig-cache job arms the cache; a missing tally means the
        // job list was built outside this module — surface zeros rather
        // than panic.
        let c = out.cache.unwrap_or_default();
        t.row([
            out.key.clone(),
            format!("{:.1}%", c.hit_rate() * 100.0),
            format!("{}/{}", c.hit_blocks, c.miss_blocks),
            format!("{}", c.evicted_blocks),
            format!("{}", c.invalidations),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.2}s", out.report.ttft_p50_s),
            format!("{:.2}s", out.report.ttft_p99_s),
            format!("{}/{}", out.report.completed, out.report.total),
        ]);
        let mut row = row_json(&[
            ("key", Json::from(out.key.as_str())),
            ("hit_rate", Json::from(c.hit_rate())),
            ("hit_blocks", Json::from(c.hit_blocks)),
            ("miss_blocks", Json::from(c.miss_blocks)),
            ("evicted_blocks", Json::from(c.evicted_blocks)),
            ("invalidations", Json::from(c.invalidations)),
            ("tput", Json::from(out.report.throughput_tps)),
            ("ttft_p50", Json::from(out.report.ttft_p50_s)),
            ("ttft_p99", Json::from(out.report.ttft_p99_s)),
            ("completed", Json::from(out.report.completed)),
            ("total", Json::from(out.report.total)),
        ]);
        if let Some(e) = &out.error {
            row.set("error", e.as_str());
        }
        rows.push(row);
    }
    println!(
        "fig-cache — prefix-cache-aware routing on a shared-prefix stream \
         ({CACHE_QPS} qps, seed {CACHE_SEED:#x})"
    );
    t.print();
    let _ = write_repro_rows("fig-cache", &rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweep::{results_to_jsonl, run_sweep_serial};

    #[test]
    fn cache_shape_builds_the_full_grid_over_one_trace() {
        let shape = cache_shape(120.0);
        assert_eq!(shape.name, "fig-cache");
        assert_eq!(shape.entries.len(), 6);
        assert_eq!(shape.traces.len(), 1);
        let names: Vec<&str> =
            shape.entries.iter().map(|e| e.policy.unwrap().name()).collect();
        assert!(names.contains(&"gyges") && names.contains(&"gyges-cache"));
        assert!(names.contains(&"rr-cache") && names.contains(&"llf-cache"));
        // Every entry arms the cache over trace group 0 — routing
        // awareness is the only variable.
        assert!(shape.entries.iter().all(|e| e.arm_cache && e.trace_group == 0));
    }

    #[test]
    fn cache_jobs_are_deterministic() {
        let jobs = fig_cache_jobs(45.0);
        let a = results_to_jsonl(&run_sweep_serial(&jobs));
        let b = results_to_jsonl(&run_sweep_serial(&jobs));
        assert_eq!(a, b, "same prefixed stream must reproduce byte-identically");
    }

    #[test]
    fn cache_aware_routing_hits_more_than_load_only() {
        let results = run_sweep_serial(&fig_cache_jobs(60.0));
        let hit_blocks = |suffix: &str| -> u64 {
            results
                .iter()
                .filter(|r| r.key.ends_with(suffix))
                .map(|r| r.cache.expect("fig-cache arms every job").hit_blocks)
                .sum()
        };
        let aware = hit_blocks("-cache");
        let blind: u64 =
            results.iter().map(|r| r.cache.unwrap().hit_blocks).sum::<u64>() - aware;
        for r in &results {
            let c = r.cache.unwrap();
            assert!(c.lookups > 0, "{}: prefixed stream must drive lookups", r.key);
        }
        assert!(
            aware > blind,
            "affinity scoring must concentrate sessions: {aware} aware vs {blind} blind hits"
        );
    }
}
