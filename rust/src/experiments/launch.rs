//! Multi-hour sweep launcher: `gyges trace-gen` + `gyges sweep-launch`.
//!
//! `trace-gen` writes a named sweep's traces as JSONL segment files (one
//! directory per trace group, one file per `segment_s` window, manifest
//! with per-file integrity hashes — see `workload/source.rs`), generated
//! deterministically and resumable at any segment index. `sweep-launch`
//! then fans `sweep-shard` jobs over those files — as child `gyges`
//! processes (one per shard, bounded concurrency) or in-process — and
//! reuses [`merge_shards`] to reassemble the stripes into the exact
//! bytes the serial whole-trace driver would produce. Streamed shards
//! replay via [`JobTrace::Dir`], so a worker's peak trace memory is one
//! segment regardless of the horizon; CI `cmp`s the merged output
//! against an unsharded whole-trace run to prove byte-identity across
//! the whole pipeline.

use super::shard::{merge_shards, read_shard_dir, write_shard, ShardSpec};
use super::sweep::{JobTrace, SweepJob};
use super::{named_sweep_default_horizon, named_sweep_shape, NAMED_SWEEPS};
use crate::sim::SimTime;
use crate::util::Args;
use crate::workload::source::{segment_ticks, write_segments};
use crate::workload::{ChunkedTrace, ProductionStream, SegmentDir, StreamSource};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Directory name of trace group `g` under a trace root.
pub fn group_dir_name(g: usize) -> String {
    format!("trace-{g:04}")
}

/// Does an on-disk segment directory describe exactly this sweep group
/// at these generation parameters? The manifest records the REQUESTED
/// window length verbatim (see `SegmentDirWriter`), so this compares
/// requested-vs-requested through the one shared [`segment_ticks`]
/// derivation.
fn dir_matches(sd: &SegmentDir, sweep: &str, g: usize, horizon: SimTime, segment_s: f64) -> bool {
    sd.label == sweep
        && sd.group == g
        && sd.horizon == horizon
        && sd.segment == segment_ticks(segment_s)
}

/// Count of contiguous `segment-XXXXX.jsonl` files present from index 0
/// (how far an interrupted generation got in this group).
fn contiguous_existing_segments(dir: &Path) -> usize {
    let mut k = 0;
    while dir.join(SegmentDir::segment_file_name(k)).exists() {
        k += 1;
    }
    k
}

/// Generate a named sweep's traces and write them as segment files:
/// one [`SegmentDir`] per trace group under `out_root`. Groups are
/// materialized ONE at a time (the writer itself holds one segment of
/// output). `resume_from` is applied PER GROUP: groups whose sealed
/// manifest already matches these parameters are left untouched, and an
/// unfinished group skips at most the files intact on disk minus one —
/// the last contiguous file is always rewritten (an interruption may
/// have truncated it) and the skipped prefix is byte-verified in place.
/// Resuming a run interrupted partway through a multi-group sweep
/// (fig12's four models, fig14's QPS grid) therefore repairs exactly
/// the missing tail instead of aborting on groups that never started.
pub fn trace_gen_named(
    sweep: &str,
    horizon_s: f64,
    segment_s: f64,
    out_root: &Path,
    resume_from: usize,
) -> Result<Vec<SegmentDir>, String> {
    let shape = named_sweep_shape(sweep, horizon_s)
        .ok_or_else(|| format!("unknown sweep {sweep:?} (known: {})", NAMED_SWEEPS.join(", ")))?;
    let horizon = SimTime::from_secs_f64(shape.horizon_s);
    let mut dirs = Vec::with_capacity(shape.traces.len());
    for (g, spec) in shape.traces.iter().enumerate() {
        let dir = out_root.join(group_dir_name(g));
        if resume_from > 0 {
            if let Ok(sd) = SegmentDir::open(&dir) {
                if dir_matches(&sd, sweep, g, horizon, segment_s) {
                    // Sealed and parameter-identical: the group finished.
                    dirs.push(sd);
                    continue;
                }
            }
        }
        // An interruption can only have truncated the LAST contiguous
        // file (each file is complete before the next begins), so the
        // repair always rewrites that one instead of trusting it to a
        // byte-compare that would abort on a half-written tail.
        let on_disk = contiguous_existing_segments(&dir);
        let effective = resume_from.min(on_disk.saturating_sub(1));
        let trace = spec.build(shape.horizon_s);
        let mut source = ChunkedTrace::with_horizon(trace, segment_s, shape.horizon_s);
        dirs.push(write_segments(&dir, sweep, g, segment_s, &mut source, effective)?);
    }
    Ok(dirs)
}

/// Build a named sweep's job list with every trace group replayed from
/// its `trace-gen` segment directory under `root` — no trace is ever
/// materialized; jobs stream one segment at a time and produce rows
/// byte-identical to the whole-trace job list.
pub fn streamed_named_jobs(
    sweep: &str,
    horizon_s: f64,
    root: &Path,
) -> Result<Vec<SweepJob>, String> {
    let shape = named_sweep_shape(sweep, horizon_s)
        .ok_or_else(|| format!("unknown sweep {sweep:?} (known: {})", NAMED_SWEEPS.join(", ")))?;
    let mut dirs = Vec::with_capacity(shape.traces.len());
    for g in 0..shape.traces.len() {
        let dir = root.join(group_dir_name(g));
        let sd = SegmentDir::open(&dir)?;
        if sd.label != sweep {
            return Err(format!(
                "{}: segment directory is labeled {:?}, expected sweep {sweep:?}",
                dir.display(),
                sd.label
            ));
        }
        if sd.group != g {
            return Err(format!(
                "{}: segment directory declares group {}, expected {g}",
                dir.display(),
                sd.group
            ));
        }
        // A stale directory from an earlier run at another horizon would
        // replay the wrong sweep under the requested label — refuse it
        // instead of silently merging wrong-horizon rows.
        let want = SimTime::from_secs_f64(shape.horizon_s);
        if sd.horizon != want {
            return Err(format!(
                "{}: segment directory was generated at horizon {} s, expected {} s — \
                 re-run trace-gen (or delete the directory / pass the matching --horizon)",
                dir.display(),
                sd.horizon.as_secs_f64(),
                shape.horizon_s
            ));
        }
        dirs.push(Arc::new(sd));
    }
    Ok(shape.jobs_with(|g| JobTrace::Dir(Arc::clone(&dirs[g]))))
}

/// Everything `sweep-launch` needs to drive one segmented sweep.
#[derive(Clone, Debug)]
pub struct LaunchPlan {
    pub sweep: String,
    pub horizon_s: f64,
    pub segment_s: f64,
    pub shards: usize,
    /// Root of the per-group segment directories (generated here if its
    /// group-0 manifest is absent).
    pub trace_root: PathBuf,
    /// Where shard JSONL + manifests land.
    pub shard_dir: PathBuf,
    /// Merged output path.
    pub out: PathBuf,
    /// Max concurrent shard child processes.
    pub max_procs: usize,
    /// Run shards in this process instead of spawning `gyges` children.
    pub in_process: bool,
}

/// What a launch did, for logging and tests.
#[derive(Clone, Debug)]
pub struct LaunchReport {
    pub shards: usize,
    pub rows: usize,
    pub bytes: usize,
    pub generated_traces: bool,
}

fn clear_stale_shards(dir: &Path, sweep: &str) -> Result<(), String> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Ok(()) };
    let prefix = format!("{sweep}-shard-");
    for entry in entries.filter_map(|e| e.ok()) {
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(&prefix) {
            std::fs::remove_file(entry.path())
                .map_err(|e| format!("remove stale {}: {e}", entry.path().display()))?;
        }
    }
    Ok(())
}

/// Run one shard as a child `gyges sweep-shard --stream-dir` process.
fn spawn_shard(plan: &LaunchPlan, k: usize) -> Result<std::process::Child, String> {
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    std::process::Command::new(exe)
        .arg("sweep-shard")
        .arg(&plan.sweep)
        .arg("--stream-dir")
        .arg(&plan.trace_root)
        .arg("--shard")
        .arg(format!("{k}/{}", plan.shards))
        .arg("--horizon")
        .arg(format!("{}", plan.horizon_s))
        .arg("--out-dir")
        .arg(&plan.shard_dir)
        .spawn()
        .map_err(|e| format!("spawn shard {k}: {e}"))
}

/// Drive the whole pipeline: ensure segment files exist, run every
/// shard over them (children or in-process), then merge the stripes —
/// rejecting incomplete or inconsistent shard sets — and write the
/// reassembled JSONL to `plan.out`.
pub fn run_launch(plan: &LaunchPlan) -> Result<LaunchReport, String> {
    if plan.shards == 0 {
        return Err("sweep-launch: --shards must be >= 1".into());
    }
    if !plan.segment_s.is_finite() || plan.segment_s <= 0.0 {
        return Err("sweep-launch: --segment-s must be a positive number".into());
    }
    let shape = named_sweep_shape(&plan.sweep, plan.horizon_s).ok_or_else(|| {
        format!("unknown sweep {:?} (known: {})", plan.sweep, NAMED_SWEEPS.join(", "))
    })?;
    // Missing/partial generation is repaired; a SEALED directory whose
    // parameters differ from the request is REFUSED, never overwritten —
    // reusing it would produce wrong rows (horizon) or void the
    // one-segment memory bound (segment size), and clobbering it would
    // destroy minutes-to-hours of generation the operator pointed at
    // explicitly.
    let horizon = SimTime::from_secs_f64(shape.horizon_s);
    let mut generated_traces = false;
    for g in 0..shape.traces.len() {
        let dir = plan.trace_root.join(group_dir_name(g));
        match SegmentDir::open(&dir) {
            Ok(sd) if dir_matches(&sd, &plan.sweep, g, horizon, plan.segment_s) => {}
            Ok(sd) => {
                return Err(format!(
                    "{}: existing segment directory was generated at horizon {} s / segment \
                     {} s, but this launch asked for {} s / {} s — delete the directory or \
                     pass the matching --horizon/--segment-s",
                    dir.display(),
                    sd.horizon.as_secs_f64(),
                    sd.segment.as_secs_f64(),
                    shape.horizon_s,
                    plan.segment_s
                ));
            }
            Err(_) => generated_traces = true,
        }
    }
    if generated_traces {
        // usize::MAX resume = "repair": sealed parameter-matching groups
        // are skipped wholesale, partial groups keep (and byte-verify)
        // every file already on disk and write only the missing tail —
        // an interrupted hour-scale generation never starts over.
        let repair = usize::MAX;
        trace_gen_named(&plan.sweep, plan.horizon_s, plan.segment_s, &plan.trace_root, repair)?;
    }
    clear_stale_shards(&plan.shard_dir, &plan.sweep)?;
    if plan.in_process {
        let jobs = streamed_named_jobs(&plan.sweep, plan.horizon_s, &plan.trace_root)?;
        for k in 0..plan.shards {
            let spec = ShardSpec::new(k, plan.shards).map_err(|e| e.to_string())?;
            write_shard(&plan.shard_dir, &plan.sweep, &jobs, spec).map_err(|e| e.to_string())?;
        }
    } else {
        let mut pending: Vec<usize> = (0..plan.shards).collect();
        let mut running: Vec<(usize, std::process::Child)> = Vec::new();
        let cap = plan.max_procs.max(1);
        let mut failure: Option<String> = None;
        while failure.is_none() && (!pending.is_empty() || !running.is_empty()) {
            while failure.is_none() && running.len() < cap && !pending.is_empty() {
                let k = pending.remove(0);
                match spawn_shard(plan, k) {
                    Ok(child) => running.push((k, child)),
                    Err(e) => failure = Some(e),
                }
            }
            // Reap ANY finished child (poll, don't block on the oldest):
            // one slow shard must not keep finished slots from refilling.
            let mut reaped = false;
            let mut i = 0;
            while failure.is_none() && i < running.len() {
                match running[i].1.try_wait() {
                    Ok(Some(status)) => {
                        let (k, _) = running.remove(i);
                        reaped = true;
                        if !status.success() {
                            failure =
                                Some(format!("shard {k}/{} exited with {status}", plan.shards));
                        }
                    }
                    Ok(None) => i += 1,
                    Err(e) => failure = Some(format!("wait shard {}: {e}", running[i].0)),
                }
            }
            if failure.is_none() && !reaped && !running.is_empty() {
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
        }
        if let Some(e) = failure {
            // Never orphan children: a failed launch kills and reaps the
            // rest so a re-run cannot race their half-written shard files.
            for (_, child) in &mut running {
                let _ = child.kill();
                let _ = child.wait();
            }
            return Err(e);
        }
    }
    let inputs =
        read_shard_dir(&plan.shard_dir, &plan.sweep).map_err(|e| format!("sweep-launch: {e}"))?;
    if inputs.len() != plan.shards {
        return Err(format!(
            "sweep-launch: expected {} shard files under {}, found {}",
            plan.shards,
            plan.shard_dir.display(),
            inputs.len()
        ));
    }
    let merged = merge_shards(&inputs).map_err(|e| format!("sweep-launch merge: {e}"))?;
    if let Some(parent) = plan.out.parent() {
        std::fs::create_dir_all(parent)
            .map_err(|e| format!("create {}: {e}", parent.display()))?;
    }
    std::fs::write(&plan.out, &merged)
        .map_err(|e| format!("write {}: {e}", plan.out.display()))?;
    Ok(LaunchReport {
        shards: plan.shards,
        rows: merged.lines().count(),
        bytes: merged.len(),
        generated_traces,
    })
}

// ---------------------------------------------------------------------
// CLI glue
// ---------------------------------------------------------------------

/// `gyges trace-gen <sweep|production> ...` — write deterministic
/// segment files. Named sweeps chunk their canonical traces (exactly
/// the requests whole-trace replay serves); `production` streams a
/// seeded [`ProductionStream`] one segment at a time (O(segment)
/// generator memory, any-index resume by construction).
pub fn trace_gen_cli(args: &Args) -> i32 {
    let Some(what) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!(
            "usage: gyges trace-gen <{}|production> [--horizon S] [--segment-s S] \
             [--out-dir DIR] [--resume-from K] [--qps Q --seed N --bursty \
             --interactive-frac F]",
            NAMED_SWEEPS.join("|")
        );
        return 2;
    };
    let default_horizon =
        if what == "production" { 3600.0 } else { named_sweep_default_horizon(what) };
    let parsed = (|| -> Result<(f64, usize, f64, u64, f64), String> {
        Ok((
            args.parsed_strict("segment-s", 60.0f64)?,
            args.parsed_strict("resume-from", 0usize)?,
            args.parsed_strict("qps", 2.0f64)?,
            args.parsed_strict("seed", 0x57AEA_u64)?,
            args.parsed_strict("horizon", default_horizon)?,
        ))
    })();
    let (segment_s, resume_from, qps, seed, horizon) = match parsed {
        Ok(v) => v,
        Err(e) => {
            eprintln!("trace-gen: {e}");
            return 2;
        }
    };
    // The finiteness check also rejects NaN, which `<= 0` alone would
    // wave through into a 1-ns-window generation spin.
    if !segment_s.is_finite() || segment_s <= 0.0 {
        eprintln!("trace-gen: --segment-s must be a positive number");
        return 2;
    }
    if what == "production" {
        // --bursty overlays the Figure-2b long-request process (phase
        // boundaries derived from the seed, so resume-from-any-index
        // still holds — see `workload::LongBursts`).
        let longs = args.flag("bursty").then(crate::workload::LongBursts::paper);
        // --interactive-frac F marks each request interactive with
        // probability F (hash-Bernoulli in (seed, id), resume-safe);
        // absent, the stream is classless exactly as before.
        let slo = match args.parsed_strict("interactive-frac", f64::NAN) {
            Ok(f) if f.is_nan() => None,
            Ok(f) if (0.0..=1.0).contains(&f) => {
                Some(crate::workload::SloMix { interactive_frac: f })
            }
            Ok(_) => {
                eprintln!("trace-gen: --interactive-frac must be in [0, 1]");
                return 2;
            }
            Err(e) => {
                eprintln!("trace-gen: {e}");
                return 2;
            }
        };
        // --prefixed overlays the shared-prefix session structure
        // (pure in (seed, id), so resume-from-any-index still holds —
        // see `workload::PrefixMix`).
        let prefix = args.flag("prefixed").then(crate::workload::PrefixMix::paper);
        let spec =
            ProductionStream { seed, qps, segment_s, horizon_s: horizon, longs, slo, prefix };
        if !spec.qps.is_finite() || spec.qps <= 0.0 {
            // A zero rate would trip Prng::exp's assert deep in
            // generation; an infinite one would spin forever.
            eprintln!("trace-gen: --qps must be a positive finite number");
            return 2;
        }
        let dir = PathBuf::from(args.get_or("out-dir", "target/segments/production"))
            .join(group_dir_name(0));
        // The manifest needs every segment's metadata, so the stream is
        // walked from 0 either way; `resume_from` only skips rewriting
        // the earlier files (their bytes are already on disk).
        let mut source = StreamSource::new(spec);
        match write_segments(&dir, "production", 0, segment_s, &mut source, resume_from) {
            Ok(sd) => {
                println!(
                    "production stream: {} requests in {} segments → {}",
                    sd.requests,
                    sd.files.len(),
                    dir.display()
                );
                0
            }
            Err(e) => {
                eprintln!("trace-gen: {e}");
                1
            }
        }
    } else {
        let out_root = PathBuf::from(args.get_or("out-dir", &format!("target/segments/{what}")));
        match trace_gen_named(what, horizon, segment_s, &out_root, resume_from) {
            Ok(dirs) => {
                for sd in &dirs {
                    println!(
                        "{what} group {}: {} requests in {} segments → {}",
                        sd.group,
                        sd.requests,
                        sd.files.len(),
                        sd.dir.display()
                    );
                }
                0
            }
            Err(e) => {
                eprintln!("trace-gen: {e}");
                1
            }
        }
    }
}

/// `gyges sweep-launch <sweep> ...` — the multi-hour pipeline in one
/// command: trace-gen (if needed) → N streamed `sweep-shard` jobs →
/// manifest-verified merge.
pub fn sweep_launch_cli(args: &Args) -> i32 {
    let Some(sweep) = args.positional.get(1).map(|s| s.as_str()) else {
        eprintln!(
            "usage: gyges sweep-launch <{}> [--horizon S] [--segment-s S] [--shards N] \
             [--trace-dir DIR] [--out-dir DIR] [--out FILE] [--procs J] [--in-process]",
            NAMED_SWEEPS.join("|")
        );
        return 2;
    };
    let default_procs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let parsed = (|| -> Result<(f64, f64, usize, usize), String> {
        Ok((
            args.parsed_strict("horizon", named_sweep_default_horizon(sweep))?,
            args.parsed_strict("segment-s", 60.0f64)?,
            args.parsed_strict("shards", 1usize)?,
            args.parsed_strict("procs", default_procs)?,
        ))
    })();
    let (horizon_s, segment_s, shards, max_procs) = match parsed {
        Ok(v) => v,
        Err(e) => {
            eprintln!("sweep-launch: {e}");
            return 2;
        }
    };
    let plan = LaunchPlan {
        sweep: sweep.to_string(),
        horizon_s,
        segment_s,
        shards,
        trace_root: PathBuf::from(args.get_or("trace-dir", &format!("target/segments/{sweep}"))),
        shard_dir: PathBuf::from(args.get_or("out-dir", "target/launch-shards")),
        out: PathBuf::from(args.get_or("out", &format!("target/{sweep}-launched.jsonl"))),
        max_procs,
        in_process: args.flag("in-process"),
    };
    match run_launch(&plan) {
        Ok(rep) => {
            println!(
                "{sweep}: launched {} streamed shard(s){} → merged {} rows ({} bytes) → {}",
                rep.shards,
                if rep.generated_traces { " (traces generated)" } else { "" },
                rep.rows,
                rep.bytes,
                plan.out.display()
            );
            0
        }
        Err(e) => {
            eprintln!("sweep-launch: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweep::{results_to_jsonl, run_sweep_serial};
    use crate::experiments::{named_sweep_jobs, shard::job_list_hash};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gyges-launch-{name}-{}", std::process::id()))
    }

    #[test]
    fn streamed_jobs_hash_identically_to_materialized_jobs() {
        let root = tmp("hash");
        let _ = std::fs::remove_dir_all(&root);
        trace_gen_named("fig13", 240.0, 30.0, &root, 0).unwrap();
        let streamed = streamed_named_jobs("fig13", 240.0, &root).unwrap();
        let canonical = named_sweep_jobs("fig13", 240.0).unwrap();
        assert_eq!(
            job_list_hash(&streamed),
            job_list_hash(&canonical),
            "segment-dir jobs must fingerprint as the same sweep"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn trace_gen_resume_repairs_partial_multi_group_generation() {
        let root = tmp("resume-groups");
        let _ = std::fs::remove_dir_all(&root);
        let full = trace_gen_named("fig14", 60.0, 10.0, &root, 0).unwrap();
        assert_eq!(full.len(), 3, "fig14 has one trace group per QPS");
        // Simulate an interrupted run: group 0 finished, group 1 lost its
        // tail and manifest, group 2 never started.
        let g1 = root.join(group_dir_name(1));
        for k in 2..full[1].files.len() {
            std::fs::remove_file(g1.join(SegmentDir::segment_file_name(k))).unwrap();
        }
        std::fs::remove_file(SegmentDir::manifest_path(&g1)).unwrap();
        std::fs::remove_dir_all(root.join(group_dir_name(2))).unwrap();
        // Resume must adapt per group: skip the sealed group, verify and
        // extend the partial one, regenerate the missing one — even with
        // a resume index beyond what some groups have on disk.
        let repaired = trace_gen_named("fig14", 60.0, 10.0, &root, 4).unwrap();
        for (a, b) in full.iter().zip(&repaired) {
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn in_process_launch_matches_whole_trace_serial_bytes() {
        let root = tmp("pipe");
        let _ = std::fs::remove_dir_all(&root);
        let plan = LaunchPlan {
            sweep: "fig13".into(),
            horizon_s: 240.0,
            segment_s: 45.0,
            shards: 2,
            trace_root: root.join("segments"),
            shard_dir: root.join("shards"),
            out: root.join("merged.jsonl"),
            max_procs: 1,
            in_process: true,
        };
        let rep = run_launch(&plan).unwrap();
        assert!(rep.generated_traces);
        assert_eq!(rep.shards, 2);
        let merged = std::fs::read_to_string(&plan.out).unwrap();
        let canonical = named_sweep_jobs("fig13", 240.0).unwrap();
        let serial = results_to_jsonl(&run_sweep_serial(&canonical));
        assert_eq!(merged, serial, "streamed launch must reproduce the serial whole-trace bytes");
        // Re-launching over the existing segment files skips generation.
        let rep2 = run_launch(&plan).unwrap();
        assert!(!rep2.generated_traces);
        let _ = std::fs::remove_dir_all(&root);
    }
}
