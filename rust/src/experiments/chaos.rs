//! The `fig-faults` chaos experiment (`gyges chaos`): goodput, SLO
//! attainment, and drop rate for Gyges vs RR/LLF/static under a seeded
//! fault storm.
//!
//! Not a paper figure — Gyges is evaluated on healthy clusters — but
//! the natural robustness companion to Figure 12: the same saturating
//! short traffic + long bursts workload, now with host crashes,
//! instance stalls, mid-flight transformation aborts, and KV-migration
//! link outages injected through the event queue. Every comparator
//! sees the *identical* storm (one [`FaultPlan`] shared across jobs),
//! so the only variable is how the policy absorbs it. The whole sweep
//! is a named sweep (`fig-faults`), so sharding, checkpointed
//! snapshot/resume, and CI's chaos-verify kill/resume `cmp` all reuse
//! the standard machinery.

use crate::config::{ClusterConfig, ModelConfig, Policy};
use crate::coordinator::SystemKind;
use crate::faults::FaultPlan;
use crate::util::json::{write_repro_rows, Json};
use crate::util::table::Table;

use super::sweep::{self, run_sweep};
use super::{row_json, ShapeEntry, SweepShape, TraceSpec};

/// Seed for both the storm and the workload trace group — fixed so the
/// experiment (and CI's chaos-verify job) is one deterministic artifact.
pub const CHAOS_SEED: u64 = 0xC8A05;

/// Fault storm intensity, expected faults per minute across the fleet.
pub const CHAOS_FAULTS_PER_MIN: f64 = 4.0;

/// The chaos cluster config: paper defaults plus a bounded, backoff-ed
/// retry policy — under capacity loss the backlog must shed load
/// (counted drops), not livelock.
pub fn chaos_cfg() -> ClusterConfig {
    let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    cfg.retry_max_attempts = 6;
    cfg.retry_backoff_base_s = 0.2;
    cfg
}

/// The storm every `fig-faults` job shares.
pub fn chaos_plan(cfg: &ClusterConfig, horizon_s: f64) -> FaultPlan {
    FaultPlan::storm(CHAOS_SEED, horizon_s, cfg.hosts, cfg.gpus_per_host, CHAOS_FAULTS_PER_MIN)
}

/// The `fig-faults` sweep shape: the Figure-12 workload under one
/// shared fault storm, across Gyges / RR / LLF and a static (no
/// transformation) deployment.
pub fn chaos_shape(horizon_s: f64) -> SweepShape {
    let cfg = chaos_cfg();
    let plan = chaos_plan(&cfg, horizon_s);
    let mut entries: Vec<ShapeEntry> = [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges]
        .into_iter()
        .map(|policy| ShapeEntry {
            key: format!("faults/{}", policy.name()),
            cfg: cfg.clone(),
            system: SystemKind::Gyges,
            policy: Some(policy.into()),
            gyges_hold: None,
            faults: Some(plan.clone()),
            static_deploy: false,
            arm_cache: false,
            trace_group: 0,
        })
        .collect();
    entries.push(ShapeEntry {
        key: "faults/static".into(),
        cfg: cfg.clone(),
        system: SystemKind::Gyges,
        policy: Some(Policy::Gyges.into()),
        gyges_hold: None,
        faults: Some(plan),
        static_deploy: true,
        arm_cache: false,
        trace_group: 0,
    });
    SweepShape {
        name: "fig-faults".into(),
        horizon_s,
        entries,
        traces: vec![TraceSpec::Fig12 { cfg, seed: CHAOS_SEED }],
    }
}

/// Build the `fig-faults` job list for the sweep driver.
pub fn fig_faults_jobs(horizon_s: f64) -> Vec<super::sweep::SweepJob> {
    chaos_shape(horizon_s).materialized_jobs()
}

/// Run the chaos comparison and print/emit the goodput / SLO / drop
/// table (deterministic JSONL rows under `target/repro/fig-faults`).
pub fn fig_faults(horizon_s: f64) -> Vec<Json> {
    let jobs = fig_faults_jobs(horizon_s);
    let results = run_sweep(&jobs);
    sweep::warn_on_errors(&results);
    let mut t = Table::new([
        "deployment", "goodput (tps)", "SLO attain", "completed", "dropped", "crashes",
        "requeued", "rollbacks", "blocked scale-ups",
    ]);
    let mut rows = Vec::new();
    for out in &results {
        let c = &out.counters;
        let served = out.report.total as f64;
        let drop_rate = if served > 0.0 { c.dropped as f64 / (served + c.dropped as f64) } else { 0.0 };
        t.row([
            out.key.clone(),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.1}%", out.report.slo_attainment * 100.0),
            format!("{}/{}", out.report.completed, out.report.total),
            format!("{} ({:.1}%)", c.dropped, drop_rate * 100.0),
            format!("{}", c.crashed_instances),
            format!("{}", c.crash_requeued),
            format!("{}", c.transform_rollbacks),
            format!("{}", c.scale_up_blocked),
        ]);
        let mut row = row_json(&[
            ("key", Json::from(out.key.as_str())),
            ("goodput_tps", Json::from(out.report.throughput_tps)),
            ("slo_attainment", Json::from(out.report.slo_attainment)),
            ("completed", Json::from(out.report.completed)),
            ("total", Json::from(out.report.total)),
            ("dropped", Json::from(c.dropped)),
            ("drop_rate", Json::from(drop_rate)),
            ("fault_events", Json::from(c.fault_events)),
            ("crashed_instances", Json::from(c.crashed_instances)),
            ("crash_requeued", Json::from(c.crash_requeued)),
            ("transform_rollbacks", Json::from(c.transform_rollbacks)),
            ("stalled_instances", Json::from(c.stalled_instances)),
            ("scale_up_blocked", Json::from(c.scale_up_blocked)),
        ]);
        if let Some(e) = &out.error {
            row.set("error", e.as_str());
        }
        rows.push(row);
    }
    println!(
        "fig-faults — goodput/SLO/drops under a seeded fault storm ({CHAOS_FAULTS_PER_MIN} \
         faults/min, seed {CHAOS_SEED:#x})"
    );
    t.print();
    let _ = write_repro_rows("fig-faults", &rows);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sweep::{results_to_jsonl, run_sweep_serial};

    #[test]
    fn chaos_shape_builds_and_shares_one_storm() {
        let shape = chaos_shape(120.0);
        assert_eq!(shape.name, "fig-faults");
        assert_eq!(shape.entries.len(), 4);
        let plans: Vec<&FaultPlan> =
            shape.entries.iter().map(|e| e.faults.as_ref().expect("every job faulted")).collect();
        assert!(!plans[0].is_empty(), "storm must inject at least one fault in 120 s");
        assert!(plans.windows(2).all(|w| w[0] == w[1]), "all comparators share one storm");
        assert!(shape.entries.iter().filter(|e| e.static_deploy).count() == 1);
    }

    #[test]
    fn chaos_jobs_are_deterministic() {
        let jobs = fig_faults_jobs(60.0);
        let a = results_to_jsonl(&run_sweep_serial(&jobs));
        let b = results_to_jsonl(&run_sweep_serial(&jobs));
        assert_eq!(a, b, "same storm + same trace must reproduce byte-identically");
    }
}
