//! Experiment harness: one function per paper table/figure, each printing
//! the paper's number next to the measured one and writing JSON rows to
//! `target/repro/`. The `benches/` binaries and the `gyges repro` CLI both
//! dispatch here (see DESIGN.md §4 for the experiment index).
//!
//! Simulation sweeps (Figures 12–14) go through the [`sweep`] driver: jobs
//! fan out across cores and merge in fixed order, so the printed tables
//! and `target/repro/` rows are identical to a serial run. The [`shard`]
//! layer stretches the same guarantee across processes/hosts/CI matrix
//! jobs: `gyges sweep-shard` runs one stripe of a named job list and
//! `gyges sweep-merge` reassembles the stripes to the serial driver's
//! exact bytes (manifest-verified). The [`launch`] layer stretches it to
//! multi-hour traces: `gyges trace-gen` writes segment files and `gyges
//! sweep-launch` fans streamed shard jobs over them (O(segment) trace
//! memory per worker) before merging with the same machinery.

pub mod branch;
pub mod cache;
pub mod chaos;
pub mod launch;
pub mod shard;
pub mod slo;
pub mod sweep;

use crate::baselines::{fig14_systems, run_static_hybrid, StaticHybridConfig};
use crate::config::calib;
use crate::config::{ClusterConfig, GpuSpec, ModelConfig, Policy, PolicyId};
use crate::coordinator::{run_system, SystemKind};
use crate::kvcache::fig9_series;
use crate::sim::{EngineModel, SimTime};
use crate::transform::fig11_sweep;
use crate::util::json::{write_repro_rows, Json};
use crate::util::table::Table;
use crate::weights::{fig10_series, page_counts, LayerPadPlan};
use crate::workload::{LengthModel, Trace};
use std::sync::Arc;
use sweep::{run_sweep, JobTrace, SweepJob};

fn row_json(pairs: &[(&str, Json)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in pairs {
        o.set(k, v.clone());
    }
    o
}

// ---------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------

/// Table 1: performance of different parallelism strategies
/// (Qwen2.5-32B on 4×H20).
pub fn table1() -> Vec<Json> {
    let e = EngineModel::new(ModelConfig::qwen2_5_32b(), GpuSpec::h20());
    let paper = [
        (
            1u64,
            4u64,
            calib::table1::MAX_SEQ_TP1,
            calib::table1::TPS_TP1,
            calib::table1::TOTAL_TPS_4X_TP1,
        ),
        (2, 2, calib::table1::MAX_SEQ_TP2, calib::table1::TPS_TP2, calib::table1::TOTAL_TPS_2X_TP2),
        (4, 1, calib::table1::MAX_SEQ_TP4, calib::table1::TPS_TP4, calib::table1::TOTAL_TPS_TP4),
    ];
    let mut t = Table::new([
        "deploy", "max seq (paper)", "max seq (ours)", "tps/inst (paper)",
        "tps/inst (ours)", "total tps (paper)", "total tps (ours)",
    ]);
    let mut rows = Vec::new();
    for (tp, n_inst, p_seq, p_tps, p_total) in paper {
        let seq = e.max_seq(tp);
        let tps = e.saturated_tps(tp);
        let total = tps * n_inst as f64;
        t.row([
            format!("{n_inst}x(TP{tp})"),
            format!("{:.2}K", p_seq as f64 / 1000.0),
            format!("{:.2}K", seq as f64 / 1000.0),
            format!("{p_tps:.0}"),
            format!("{tps:.0}"),
            format!("{p_total:.0}"),
            format!("{total:.0}"),
        ]);
        rows.push(row_json(&[
            ("tp", Json::from(tp)),
            ("max_seq_paper", Json::from(p_seq)),
            ("max_seq_ours", Json::from(seq)),
            ("tps_paper", Json::from(p_tps)),
            ("tps_ours", Json::from(tps)),
        ]));
    }
    println!("Table 1 — parallelism strategies (Qwen2.5-32B, H20)");
    t.print();
    let _ = write_repro_rows("table1", &rows);
    rows
}

// ---------------------------------------------------------------------
// Table 2 / Table 3
// ---------------------------------------------------------------------

/// Table 2: KV layout benefits (shift/trim complexity, measured on the
/// real page-pool mechanics).
pub fn table2() -> Vec<Json> {
    use crate::kvcache::{KvLayout, KvManager};
    let model = ModelConfig::qwen2_5_32b();
    let mut t =
        Table::new(["layout", "hierarchy", "shift ops on 1000 appends", "trim copies/block"]);
    let mut rows = Vec::new();
    for layout in [KvLayout::Raw, KvLayout::PageFriendly, KvLayout::HeaderCentric] {
        let mut mgr = KvManager::new(&model, 1, layout, 3 * crate::util::GIB);
        mgr.admit(1, 100).unwrap();
        for _ in 0..999 {
            mgr.append(1, mgr.tokens_per_block).unwrap();
        }
        let geo = mgr.geometry();
        let trim = layout.trim_copies_per_block(&geo, geo.num_heads - geo.num_heads / 4);
        t.row([
            format!("{layout:?}"),
            layout.hierarchy().to_string(),
            format!("{}", mgr.shift_ops),
            format!("{trim}"),
        ]);
        rows.push(row_json(&[
            ("layout", Json::from(format!("{layout:?}"))),
            ("shift_ops", Json::from(mgr.shift_ops)),
            ("trim_copies_per_block", Json::from(trim)),
        ]));
    }
    println!("Table 2 — KV cache layout benefits (paper: O(#pages)->0 shifts, O(#tokens)->O(1) trim)");
    t.print();
    let _ = write_repro_rows("table2", &rows);
    rows
}

/// Table 3: MLP weight pages per tensor (exact shape math).
pub fn table3() -> Vec<Json> {
    let mut t = Table::new([
        "model", "structure", "pages TP1 (paper)", "pages TP1 (ours)", "pages TP4 (paper)",
        "pages TP4 (ours)",
    ]);
    let mut rows = Vec::new();
    for (m, (p1, _), (p4, _)) in crate::weights::pages::table3_rows() {
        let c1 = page_counts(&m, 1);
        let c4 = page_counts(&m, 4);
        t.row([
            m.name.to_string(),
            format!("[{}, {}, {}]", m.hidden_size, m.inter_size,
                    if m.num_experts > 0 { m.num_experts.to_string() } else { "-".into() }),
            format!("{p1}"),
            format!("{}", c1.per_tensor),
            format!("{p4}"),
            format!("{}", c4.per_tensor),
        ]);
        rows.push(row_json(&[
            ("model", Json::from(m.name)),
            ("tp1_paper", Json::from(p1)),
            ("tp1_ours", Json::from(c1.per_tensor)),
            ("tp4_paper", Json::from(p4)),
            ("tp4_ours", Json::from(c4.per_tensor)),
        ]));
    }
    println!("Table 3 — MLP weight pages per tensor (2 MiB granularity)");
    t.print();
    let _ = write_repro_rows("table3", &rows);
    rows
}

// ---------------------------------------------------------------------
// Figure 2
// ---------------------------------------------------------------------

/// Figure 2: workload dynamics — length CCDF + long-request burstiness.
pub fn fig2() -> Vec<Json> {
    let lm = LengthModel::production();
    let thresholds = [1_000u64, 4_000, 10_000, 50_000, 100_000];
    let ccdf = lm.ccdf(42, 200_000, &thresholds);
    let mut t = Table::new(["input len >=", "fraction of requests"]);
    let mut rows = Vec::new();
    for (thr, frac) in &ccdf {
        t.row([format!("{thr}"), format!("{frac:.5}")]);
        rows.push(row_json(&[("threshold", Json::from(*thr)), ("ccdf", Json::from(*frac))]));
    }
    println!("Figure 2a — input-length distribution (long-tail CCDF)");
    t.print();

    // 2b: long arrivals per 10-minute bucket over 10 h (burstiness).
    let mut rng = crate::util::Prng::new(7);
    let arr = crate::workload::BurstyProcess::paper_long_requests()
        .arrivals(&mut rng, SimTime::from_secs_f64(36_000.0));
    let mut buckets = vec![0u32; 60];
    for a in &arr {
        buckets[(a.as_secs_f64() / 600.0) as usize] += 1;
    }
    let nonzero = buckets.iter().filter(|&&b| b > 0).count();
    let peak = *buckets.iter().max().unwrap();
    println!(
        "Figure 2b — long-request traffic over 10 h: {} arrivals, peak {} /10min, {}/60 buckets active (sporadic bursts)",
        arr.len(), peak, nonzero
    );
    rows.push(row_json(&[
        ("long_arrivals_10h", Json::from(arr.len())),
        ("peak_per_10min", Json::from(peak as u64)),
        ("active_buckets", Json::from(nonzero)),
    ]));
    let _ = write_repro_rows("fig2", &rows);
    rows
}

// ---------------------------------------------------------------------
// Figures 9 / 10 / 11
// ---------------------------------------------------------------------

/// Figure 9: KV-cache transformation time (a) and memory (b).
pub fn fig9() -> Vec<Json> {
    let mut t =
        Table::new(["model", "strategy", "extra time/layer", "peak extra mem/layer", "stages"]);
    let mut rows = Vec::new();
    for m in ModelConfig::eval_set() {
        for r in fig9_series(m.clone()) {
            t.row([
                m.name.to_string(),
                r.strategy.name().to_string(),
                format!("{}", r.per_layer_visible),
                crate::util::fmt_bytes(r.per_layer_peak_bytes),
                format!("{}", r.stages),
            ]);
            rows.push(row_json(&[
                ("model", Json::from(m.name)),
                ("strategy", Json::from(r.strategy.name())),
                ("visible_ms_per_layer", Json::from(r.per_layer_visible.as_millis_f64())),
                ("peak_bytes_per_layer", Json::from(r.per_layer_peak_bytes)),
            ]));
        }
    }
    println!("Figure 9 — KV transformation (paper: basic 3.15-4 ms/layer; gyges- ~-61%; gyges ~-86%; gyges mem < 70 MB)");
    t.print();
    let _ = write_repro_rows("fig9", &rows);
    rows
}

/// Figure 10: weight transformation time (a) and padding overhead (b).
pub fn fig10() -> Vec<Json> {
    let mut t =
        Table::new(["model", "strategy", "wall time/layer", "copied/layer", "padding overhead"]);
    let mut rows = Vec::new();
    for m in ModelConfig::eval_set() {
        let plan = LayerPadPlan::plan(&m, 4);
        for r in fig10_series(m.clone()) {
            t.row([
                m.name.to_string(),
                r.strategy.name().to_string(),
                format!("{}", r.per_layer_time()),
                crate::util::fmt_bytes(r.copied_bytes),
                format!("{:.2}%", plan.overhead_fraction() * 100.0),
            ]);
            rows.push(row_json(&[
                ("model", Json::from(m.name)),
                ("strategy", Json::from(r.strategy.name())),
                ("wall_ms_per_layer", Json::from(r.per_layer_time().as_millis_f64())),
                ("copied_bytes", Json::from(r.copied_bytes)),
                ("padding_overhead", Json::from(plan.overhead_fraction())),
            ]));
        }
    }
    println!("Figure 10 — weight transformation (paper: partial swap 611-696 ms/layer; gyges- -18.9..42.2%; gyges up to -67.6%; padding 0-14%)");
    t.print();
    let _ = write_repro_rows("fig10", &rows);
    rows
}

/// Figure 11: overall per-step transformation cost vs layers per step.
pub fn fig11() -> Vec<Json> {
    let m = ModelConfig::qwen2_5_32b();
    let g = GpuSpec::h20();
    let mut t =
        Table::new(["layers/step", "raw", "seesaw", "basic", "gyges-", "gyges", "gyges overhead"]);
    let mut rows = Vec::new();
    for r in fig11_sweep(&m, &g, 8) {
        let overhead = r.gyges.as_secs_f64() / r.raw_step.as_secs_f64() - 1.0;
        t.row([
            format!("{}", r.layers_per_step),
            format!("{}", r.raw_step),
            format!("{}", r.seesaw),
            format!("{}", r.basic),
            format!("{}", r.gyges_no_overlap),
            format!("{}", r.gyges),
            format!("{:.2}%", overhead * 100.0),
        ]);
        rows.push(row_json(&[
            ("layers_per_step", Json::from(r.layers_per_step)),
            ("raw_ms", Json::from(r.raw_step.as_millis_f64())),
            ("seesaw_ms", Json::from(r.seesaw.as_millis_f64())),
            ("basic_ms", Json::from(r.basic.as_millis_f64())),
            ("gyges_minus_ms", Json::from(r.gyges_no_overlap.as_millis_f64())),
            ("gyges_ms", Json::from(r.gyges.as_millis_f64())),
        ]));
    }
    // §6.2.3 headline: all-layers-in-one-step, Gyges vs Seesaw extra cost.
    let last = fig11_sweep(&m, &g, 8).pop().unwrap();
    let cut = 1.0
        - (last.gyges.as_secs_f64() - last.raw_step.as_secs_f64())
            / (last.seesaw.as_secs_f64() - last.raw_step.as_secs_f64());
    println!("Figure 11 — step time vs layers transformed per step (paper: gyges <1% overhead, -97.2% vs seesaw; ours: -{:.1}%)", cut * 100.0);
    t.print();
    let _ = write_repro_rows("fig11", &rows);
    rows
}

// ---------------------------------------------------------------------
// Figures 12 / 13 / 14
// ---------------------------------------------------------------------

/// The Figure-12 workload: saturating short traffic (1K in / 400 out at
/// 4 qps ≈ the capacity of a partially-degraded cluster) plus periodic
/// BURSTS of long requests — the §6.2.4 pattern where routing decisions
/// compound: a length-oblivious scheduler spreads burst members over TP1
/// instances, forcing extra transformations and starving short traffic.
pub fn fig12_trace(cfg: &ClusterConfig, seed: u64, horizon_s: f64) -> Trace {
    let e = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
    // Shorts sized so decode demand ≈ 55% of the healthy all-TP1 cluster —
    // a degraded (over-transformed) cluster dips below demand.
    let out_tokens = 400u64;
    let healthy_tps = cfg.total_gpus() as f64 * e.saturated_tps(1);
    let qps = 0.55 * healthy_tps / out_tokens as f64;
    // Longs per the paper's definition: beyond the TP2 limit (so the
    // TP4 configuration is required), but within TP4's reach.
    let long_len = ((e.max_seq(2) as f64 * 1.15) as u64).min(e.max_seq(4) * 8 / 10);
    let mut rng = crate::util::Prng::new(seed);
    let mut requests = Vec::new();
    let horizon = SimTime::from_secs_f64(horizon_s);
    for t in (crate::workload::Poisson { rate: qps }).arrivals(&mut rng, horizon) {
        requests.push(crate::workload::TraceRequest {
            id: 0,
            arrival: t,
            input_len: 1000,
            output_len: out_tokens - 50 + rng.gen_range(0, 100),
            class: crate::workload::SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    // Scripted long bursts (identical for every policy): 3 longs, 12 s
    // apart, every 150 s.
    let mut t_burst = 60.0;
    while t_burst + 40.0 < horizon_s {
        for k in 0..3 {
            requests.push(crate::workload::TraceRequest {
                id: 0,
                arrival: SimTime::from_secs_f64(t_burst + 12.0 * k as f64),
                input_len: long_len,
                output_len: 256,
                class: crate::workload::SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        t_burst += 150.0;
    }
    let mut trace = Trace { requests };
    trace.sort_and_renumber();
    trace
}

/// The Figure-12 policy set, in table order (baselines first).
pub const FIG12_POLICIES: [Policy; 3] = [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges];

// ---------------------------------------------------------------------
// Sweep shapes (job structure without materialized traces)
// ---------------------------------------------------------------------

/// One job's metadata in a [`SweepShape`]; `trace_group` points into
/// [`SweepShape::traces`].
#[derive(Clone)]
pub struct ShapeEntry {
    pub key: String,
    pub cfg: ClusterConfig,
    pub system: SystemKind,
    pub policy: Option<PolicyId>,
    pub gyges_hold: Option<f64>,
    /// Fault storm armed on this job (`fig-faults`); `None` elsewhere.
    pub faults: Option<crate::faults::FaultPlan>,
    /// Pin the deployment static (no transformation) — the chaos
    /// experiment's "static" comparator.
    pub static_deploy: bool,
    /// Arm the prefix-cache model even under a cache-blind policy
    /// (`fig-cache` baselines measure hit-rates track-only).
    pub arm_cache: bool,
    pub trace_group: usize,
}

/// How one trace group of a named sweep is generated.
#[derive(Clone)]
pub enum TraceSpec {
    /// The Figure-12 saturating workload for `cfg` (qps and long length
    /// derived from the model), seeded.
    Fig12 { cfg: ClusterConfig, seed: u64 },
    /// The fully scripted Figure-13 trace (ignores the horizon).
    Fig13,
    /// §6.3 production trace at `qps`.
    Production { seed: u64, qps: f64 },
    /// SLO-classed production stream (`fig-slo`): the seeded segment
    /// generator with a hash-Bernoulli interactive/batch mix.
    SloClassed { seed: u64, qps: f64, interactive_frac: f64 },
    /// Shared-prefix production stream (`fig-cache`): the seeded
    /// segment generator with a system-prompt + multi-turn-session
    /// prefix overlay.
    Prefixed { seed: u64, qps: f64, mix: crate::workload::PrefixMix },
}

impl TraceSpec {
    pub fn build(&self, horizon_s: f64) -> Trace {
        match self {
            TraceSpec::Fig12 { cfg, seed } => fig12_trace(cfg, *seed, horizon_s),
            TraceSpec::Fig13 => fig13_trace(),
            TraceSpec::Production { seed, qps } => Trace::production(*seed, *qps, horizon_s),
            TraceSpec::SloClassed { seed, qps, interactive_frac } => {
                crate::workload::ProductionStream {
                    seed: *seed,
                    qps: *qps,
                    segment_s: 30.0,
                    horizon_s,
                    longs: None,
                    slo: Some(crate::workload::SloMix { interactive_frac: *interactive_frac }),
                    prefix: None,
                }
                .materialize()
            }
            TraceSpec::Prefixed { seed, qps, mix } => crate::workload::ProductionStream {
                seed: *seed,
                qps: *qps,
                segment_s: 30.0,
                horizon_s,
                longs: None,
                slo: None,
                prefix: Some(*mix),
            }
            .materialize(),
        }
    }
}

/// The structure of a named sweep without its traces materialized: job
/// metadata plus one [`TraceSpec`] per trace group (fig12 has one group
/// per model, fig14 one per QPS). `gyges trace-gen` materializes one
/// group at a time to write segment files, and streamed replay
/// (`launch::streamed_named_jobs`) builds jobs over those files so the
/// serving process never holds more than one segment of any trace.
#[derive(Clone)]
pub struct SweepShape {
    pub name: String,
    pub horizon_s: f64,
    pub entries: Vec<ShapeEntry>,
    pub traces: Vec<TraceSpec>,
}

impl SweepShape {
    /// Materialize each trace group once (`Arc`-shared across its jobs)
    /// — the canonical job list every shard of this sweep agrees on.
    pub fn materialized_jobs(&self) -> Vec<SweepJob> {
        let traces: Vec<Arc<Trace>> =
            self.traces.iter().map(|s| Arc::new(s.build(self.horizon_s))).collect();
        self.jobs_with(|g| JobTrace::Full(Arc::clone(&traces[g])))
    }

    /// Build the job list with a caller-chosen trace delivery per group.
    pub fn jobs_with(&self, mut trace_for: impl FnMut(usize) -> JobTrace) -> Vec<SweepJob> {
        self.entries
            .iter()
            .map(|e| {
                let mut job = SweepJob::with_job_trace(
                    e.key.clone(),
                    e.cfg.clone(),
                    e.system,
                    e.policy,
                    trace_for(e.trace_group),
                );
                if let Some(h) = e.gyges_hold {
                    job = job.with_gyges_hold(h);
                }
                if let Some(plan) = &e.faults {
                    job = job.with_faults(plan.clone());
                }
                if e.static_deploy {
                    job = job.with_transformation_disabled();
                }
                if e.arm_cache {
                    job = job.with_cache();
                }
                job
            })
            .collect()
    }
}

/// The Figure-12 sweep shape (model × policy; one trace group per
/// model).
pub fn fig12_shape(horizon_s: f64, models: &[ModelConfig]) -> SweepShape {
    let mut entries = Vec::new();
    let mut traces = Vec::new();
    for (g, m) in models.iter().enumerate() {
        let cfg = ClusterConfig::paper_default(m.clone());
        traces.push(TraceSpec::Fig12 { cfg: cfg.clone(), seed: 0xF16_12 });
        for policy in FIG12_POLICIES {
            entries.push(ShapeEntry {
                key: format!("{}/{}", m.name, policy.name()),
                cfg: cfg.clone(),
                system: SystemKind::Gyges,
                policy: Some(policy.into()),
                gyges_hold: None,
                faults: None,
                static_deploy: false,
                arm_cache: false,
                trace_group: g,
            });
        }
    }
    SweepShape { name: "fig12".into(), horizon_s, entries, traces }
}

/// Build the Figure-12 job list (model × policy) for the sweep driver.
pub fn fig12_jobs(horizon_s: f64, models: &[ModelConfig]) -> Vec<SweepJob> {
    fig12_shape(horizon_s, models).materialized_jobs()
}

/// Figure 12: scheduler comparison (RR / LLF / Gyges) per model.
pub fn fig12(horizon_s: f64, models: &[ModelConfig]) -> Vec<Json> {
    let results = run_sweep(&fig12_jobs(horizon_s, models));
    sweep::warn_on_errors(&results);
    let mut t = Table::new([
        "model", "policy", "tput (tps)", "ttft p50", "scale-ups", "gain vs best baseline",
    ]);
    let mut rows = Vec::new();
    for (m, by_policy) in models.iter().zip(results.chunks(FIG12_POLICIES.len())) {
        let best_baseline = by_policy[..2]
            .iter()
            .map(|o| o.report.throughput_tps)
            .fold(0.0, f64::max);
        for (policy, out) in FIG12_POLICIES.iter().zip(by_policy) {
            let gain = out.report.throughput_tps / best_baseline - 1.0;
            t.row([
                m.name.to_string(),
                policy.name().to_string(),
                format!("{:.1}", out.report.throughput_tps),
                format!("{:.2}s", out.report.ttft_p50_s),
                format!("{}", out.counters.scale_ups),
                if *policy == Policy::Gyges {
                    format!("{:+.1}%", gain * 100.0)
                } else {
                    "-".into()
                },
            ]);
            let mut row = row_json(&[
                ("model", Json::from(m.name)),
                ("policy", Json::from(policy.name())),
                ("tput", Json::from(out.report.throughput_tps)),
                ("ttft_p50", Json::from(out.report.ttft_p50_s)),
                ("scale_ups", Json::from(out.counters.scale_ups)),
            ]);
            if let Some(e) = &out.error {
                row.set("error", e.as_str());
            }
            rows.push(row);
        }
    }
    println!("Figure 12 — scheduling strategies (paper: gyges +26.1%..39.2% vs RR/LLF)");
    t.print();
    let _ = write_repro_rows("fig12", &rows);
    rows
}

/// The scripted Figure-13 trace: background shorts, one long at t=10
/// (creates a TP4), a second long at t=120 — the policies diverge there.
pub fn fig13_trace() -> Trace {
    let mut trace = Trace::default();
    let mut id = 0u64;
    for i in 0..2400 {
        trace.requests.push(crate::workload::TraceRequest {
            id,
            arrival: SimTime::from_secs_f64(i as f64 * 0.1),
            input_len: 1000,
            output_len: 100,
            class: crate::workload::SloClass::Interactive,
            prefix: Vec::new(),
        });
        id += 1;
    }
    for t_long in [10.0, 120.0] {
        trace.requests.push(crate::workload::TraceRequest {
            id,
            arrival: SimTime::from_secs_f64(t_long),
            input_len: 50_000,
            output_len: 256,
            class: crate::workload::SloClass::Interactive,
            prefix: Vec::new(),
        });
        id += 1;
    }
    // Renumber so ids are dense in arrival order (the longs are pushed
    // last but arrive mid-trace) — segment files require it.
    trace.sort_and_renumber();
    trace
}

/// The Figure-13 sweep shape (one scripted trace, three policies).
pub fn fig13_shape() -> SweepShape {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let entries = FIG12_POLICIES
        .iter()
        .map(|&policy| ShapeEntry {
            key: format!("fig13/{}", policy.name()),
            cfg: cfg.clone(),
            system: SystemKind::Gyges,
            policy: Some(policy.into()),
            gyges_hold: None,
            faults: None,
            static_deploy: false,
            arm_cache: false,
            trace_group: 0,
        })
        .collect();
    SweepShape {
        name: "fig13".into(),
        horizon_s: 240.0,
        entries,
        traces: vec![TraceSpec::Fig13],
    }
}

/// Build the Figure-13 job list (one trace, three policies).
pub fn fig13_jobs() -> Vec<SweepJob> {
    fig13_shape().materialized_jobs()
}

/// Figure 13: TPS trend around a long-request arrival at t=120 s.
pub fn fig13() -> Vec<Json> {
    let results = run_sweep(&fig13_jobs());
    sweep::warn_on_errors(&results);
    let mut rows = Vec::new();
    let mut t = Table::new([
        "policy", "scale-ups", "tput (tps)", "tps@110-120s", "tps@120-130s", "tps@130-140s",
    ]);
    for (policy, out) in FIG12_POLICIES.iter().zip(&results) {
        let series = &out.tps_series;
        let bucket = |lo: u64, hi: u64| -> f64 {
            let sum: u64 = series.iter().filter(|(s, _)| *s >= lo && *s < hi).map(|(_, c)| c).sum();
            sum as f64 / (hi - lo) as f64
        };
        t.row([
            policy.name().to_string(),
            format!("{}", out.counters.scale_ups),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.1}", bucket(110, 120)),
            format!("{:.1}", bucket(120, 130)),
            format!("{:.1}", bucket(130, 140)),
        ]);
        let mut row = row_json(&[
            ("policy", Json::from(policy.name())),
            ("scale_ups", Json::from(out.counters.scale_ups)),
            ("tput", Json::from(out.report.throughput_tps)),
            ("tps_120_130", Json::from(bucket(120, 130))),
        ]);
        if let Some(e) = &out.error {
            row.set("error", e.as_str());
        }
        rows.push(row);
    }
    println!("Figure 13 — TPS trend (paper: RR/LLF trigger a 2nd scale-up at t=120 s; gyges routes to the existing TP4)");
    t.print();
    let _ = write_repro_rows("fig13", &rows);
    rows
}

/// The Figure-14 sweep shape (QPS × system; one trace group per QPS).
pub fn fig14_shape(horizon_s: f64, qps_list: &[f64]) -> SweepShape {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let mut entries = Vec::new();
    let mut traces = Vec::new();
    for (g, &qps) in qps_list.iter().enumerate() {
        traces.push(TraceSpec::Production { seed: 0xF16_14, qps });
        for sys in fig14_systems() {
            entries.push(ShapeEntry {
                key: format!("qps{qps}/{}", sys.name()),
                cfg: cfg.clone(),
                system: sys,
                policy: None,
                gyges_hold: None,
                faults: None,
                static_deploy: false,
                arm_cache: false,
                trace_group: g,
            });
        }
    }
    SweepShape { name: "fig14".into(), horizon_s, entries, traces }
}

/// Build the Figure-14 job list (QPS × system) for the sweep driver.
pub fn fig14_jobs(horizon_s: f64, qps_list: &[f64]) -> Vec<SweepJob> {
    fig14_shape(horizon_s, qps_list).materialized_jobs()
}

/// Figure 14: end-to-end throughput / TTFT / TPOT vs KunServe/LoongServe.
pub fn fig14(horizon_s: f64, qps_list: &[f64]) -> Vec<Json> {
    let n_systems = fig14_systems().len();
    let results = run_sweep(&fig14_jobs(horizon_s, qps_list));
    sweep::warn_on_errors(&results);
    let mut t = Table::new([
        "qps", "system", "tput (tps)", "ttft p50", "ttft p99", "tpot p50", "gain vs best alt",
    ]);
    let mut rows = Vec::new();
    for (&qps, outs) in qps_list.iter().zip(results.chunks(n_systems)) {
        let reports: Vec<&crate::metrics::RunReport> = outs.iter().map(|o| &o.report).collect();
        let best_alt = reports[2..]
            .iter()
            .map(|r| r.throughput_tps)
            .fold(0.0, f64::max);
        for (r, out) in reports.iter().zip(outs) {
            let is_gyges = r.label.starts_with("gyges/");
            t.row([
                format!("{qps:.1}"),
                r.label.clone(),
                format!("{:.1}", r.throughput_tps),
                format!("{:.2}s", r.ttft_p50_s),
                format!("{:.2}s", r.ttft_p99_s),
                format!("{:.1}ms", r.tpot_p50_s * 1e3),
                if is_gyges {
                    format!("{:.2}x", r.throughput_tps / best_alt.max(1e-9))
                } else {
                    "-".into()
                },
            ]);
            let mut row = row_json(&[
                ("qps", Json::from(qps)),
                ("system", Json::from(r.label.clone())),
                ("tput", Json::from(r.throughput_tps)),
                ("ttft_p50", Json::from(r.ttft_p50_s)),
                ("ttft_p99", Json::from(r.ttft_p99_s)),
                ("tpot_p50", Json::from(r.tpot_p50_s)),
            ]);
            if let Some(e) = &out.error {
                row.set("error", e.as_str());
            }
            rows.push(row);
        }
    }
    println!("Figure 14 — end-to-end (paper: gyges 1.75x-6.57x tput, TTFT -53%, TPOT -74%; overlap -26.7% TTFT)");
    t.print();
    let _ = write_repro_rows("fig14", &rows);
    rows
}

// ---------------------------------------------------------------------
// Named sweeps (sharding + CLI entry points)
// ---------------------------------------------------------------------

/// Hold values the A3 hysteresis ablation sweeps (ablation_sweeps bench).
pub const ABLATION_HOLDS: [f64; 4] = [0.0, 15.0, 45.0, 120.0];

/// The A3 hysteresis-ablation sweep shape: the Figure-12 workload under
/// the Gyges policy with `long_hold_s` swept over [`ABLATION_HOLDS`].
pub fn ablation_hold_shape(horizon_s: f64) -> SweepShape {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let entries = ABLATION_HOLDS
        .iter()
        .map(|&hold| ShapeEntry {
            key: format!("hold{hold}"),
            cfg: cfg.clone(),
            system: SystemKind::Gyges,
            policy: Some(Policy::Gyges.into()),
            gyges_hold: Some(hold),
            faults: None,
            static_deploy: false,
            arm_cache: false,
            trace_group: 0,
        })
        .collect();
    SweepShape {
        name: "ablation-hold".into(),
        horizon_s,
        entries,
        traces: vec![TraceSpec::Fig12 { cfg, seed: 7 }],
    }
}

/// Build the A3 ablation job list.
pub fn ablation_hold_jobs(horizon_s: f64) -> Vec<SweepJob> {
    ablation_hold_shape(horizon_s).materialized_jobs()
}

/// The canonical job list of a named sweep — the shared vocabulary of
/// `gyges sweep-shard` / `sweep-merge`, the figure benches' `--shard`
/// mode, and CI's shard matrix. Every process sharding one sweep MUST
/// build its jobs through this function with the same `horizon_s`, or
/// the manifests' key-list hashes will (correctly) refuse to merge.
/// `fig13` ignores the horizon (its trace is fully scripted).
pub fn named_sweep_jobs(name: &str, horizon_s: f64) -> Option<Vec<SweepJob>> {
    named_sweep_shape(name, horizon_s).map(|s| s.materialized_jobs())
}

/// The structure of a named sweep (see [`named_sweep_jobs`]) WITHOUT
/// materializing its traces — what `gyges trace-gen` and the streamed
/// launcher build from. The `fig13` shape ignores the horizon (its
/// trace is fully scripted), matching `named_sweep_jobs`.
pub fn named_sweep_shape(name: &str, horizon_s: f64) -> Option<SweepShape> {
    let mut shape = match name {
        "fig12" => fig12_shape(horizon_s, &ModelConfig::eval_set()),
        "fig12-qwen" => fig12_shape(horizon_s, &[ModelConfig::qwen2_5_32b()]),
        "fig13" => fig13_shape(),
        "fig14" => fig14_shape(horizon_s, &[2.0, 6.0, 10.0]),
        "ablation-hold" => ablation_hold_shape(horizon_s),
        "fig-faults" => chaos::chaos_shape(horizon_s),
        "fig-slo" => slo::slo_shape(horizon_s),
        "fig-cache" => cache::cache_shape(horizon_s),
        _ => return None,
    };
    // Registry aliases (fig12-qwen) keep their registry name so segment
    // directories and manifests label themselves consistently.
    shape.name = name.to_string();
    Some(shape)
}

/// Names [`named_sweep_jobs`] understands (usage strings, error text).
pub const NAMED_SWEEPS: [&str; 8] = [
    "fig12",
    "fig12-qwen",
    "fig13",
    "fig14",
    "ablation-hold",
    "fig-faults",
    "fig-slo",
    "fig-cache",
];

/// Default horizon (seconds) of a named sweep when the caller passes
/// none — the same default its canonical figure bench uses, so a
/// default-argument `sweep-shard` run produces the canonical figure
/// (fig14's bench runs 300 s; fig12/ablation run 240 s; fig13 ignores
/// the horizon entirely).
pub fn named_sweep_default_horizon(name: &str) -> f64 {
    match name {
        "fig14" => 300.0,
        _ => 240.0,
    }
}

/// §3.3 companion: static hybrid vs Gyges (motivation experiment).
pub fn static_hybrid_compare(horizon_s: f64) -> Vec<Json> {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let trace = Trace::hybrid_paper(0x57A7, horizon_s);
    let st = run_static_hybrid(&cfg, &StaticHybridConfig::paper_default(), &trace);
    let gy = run_system(cfg, SystemKind::Gyges, None, trace);
    let mut t = Table::new(["deployment", "tput (tps)", "ttft p50", "completed"]);
    for (name, o) in [("static 1xTP4+4xTP1", &st), ("gyges dynamic", &gy)] {
        t.row([
            name.to_string(),
            format!("{:.1}", o.report.throughput_tps),
            format!("{:.2}s", o.report.ttft_p50_s),
            format!("{}/{}", o.report.completed, o.report.total),
        ]);
    }
    println!("§3.3 — static hybrid vs dynamic transformation");
    t.print();
    vec![row_json(&[
        ("static_tput", Json::from(st.report.throughput_tps)),
        ("gyges_tput", Json::from(gy.report.throughput_tps)),
    ])]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_experiments_produce_rows() {
        assert_eq!(table1().len(), 3);
        assert_eq!(table2().len(), 3);
        assert_eq!(table3().len(), 4);
    }

    #[test]
    fn fig9_and_10_produce_full_series() {
        assert_eq!(fig9().len(), 12); // 4 models × 3 strategies
        assert_eq!(fig10().len(), 12);
    }

    #[test]
    fn fig11_rows_cover_sweep() {
        let rows = fig11();
        assert!(rows.len() >= 6);
    }

    #[test]
    fn named_sweeps_resolve_and_unknown_names_do_not() {
        for name in NAMED_SWEEPS {
            let jobs = named_sweep_jobs(name, 60.0).unwrap_or_else(|| panic!("{name} missing"));
            assert!(!jobs.is_empty(), "{name} built an empty job list");
        }
        assert_eq!(named_sweep_jobs("fig12", 60.0).unwrap().len(), 12);
        assert_eq!(named_sweep_jobs("ablation-hold", 60.0).unwrap().len(), ABLATION_HOLDS.len());
        assert!(named_sweep_jobs("fig99", 60.0).is_none());
        // Per-sweep defaults match each figure bench's canonical run.
        assert_eq!(named_sweep_default_horizon("fig14"), 300.0);
        assert_eq!(named_sweep_default_horizon("fig12"), 240.0);
    }

    #[test]
    fn fig13_gyges_avoids_second_scale_up() {
        let rows = fig13();
        let get = |policy: &str, key: &str| -> f64 {
            rows.iter()
                .find(|r| r.get("policy").and_then(|p| p.as_str()) == Some(policy))
                .and_then(|r| r.get(key))
                .and_then(|v| v.as_f64())
                .unwrap()
        };
        assert!(
            get("gyges", "scale_ups") <= get("llf", "scale_ups"),
            "gyges must not transform more than LLF"
        );
    }
}
