//! Descriptive statistics and a small benchmark harness.
//!
//! criterion is unavailable in the offline registry snapshot, so the
//! `benches/` binaries (harness = false) use [`Bench`] from here: warmup,
//! repeated timed runs, and a percentile summary.

use std::time::Instant;

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary of `xs`. Returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// One benchmark measurement: wall time of repeated invocations.
pub struct Bench {
    pub name: String,
    warmup_iters: usize,
    sample_iters: usize,
    samples: usize,
}

/// Result of a [`Bench`] run, in nanoseconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub ns_per_iter: Summary,
}

impl BenchResult {
    /// Human-readable "12.3 µs/iter (p50 11.9 µs)" line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>10}  p99 {:>10}  (n={})",
            self.name,
            fmt_ns(self.ns_per_iter.mean),
            fmt_ns(self.ns_per_iter.p50),
            fmt_ns(self.ns_per_iter.p99),
            self.ns_per_iter.n
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    /// A bench with sane defaults (tunable via builder methods).
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup_iters: 3, sample_iters: 10, samples: 20 }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.sample_iters = n.max(1);
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    /// Run `f` repeatedly and measure. A `black_box`-style sink is applied
    /// to the closure's return value to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            sink(f());
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.sample_iters {
                sink(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            per_iter.push(dt / self.sample_iters as f64);
        }
        BenchResult { name: self.name.clone(), ns_per_iter: Summary::of(&per_iter) }
    }
}

/// Optimizer barrier (std::hint::black_box stand-in that works on stable).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_measures_something() {
        let r = Bench::new("spin").iters(100).samples(5).run(|| {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.ns_per_iter.mean > 0.0);
        assert_eq!(r.name, "spin");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
