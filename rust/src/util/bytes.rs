//! Byte-size constants and formatting shared across the memory models.

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// CUDA virtual-memory-management minimum allocation granularity (2 MiB).
/// This is the page size every layout/padding decision in the paper (and in
/// `kvcache`/`weights`) revolves around.
pub const VMM_PAGE: u64 = 2 * MIB;

/// Round `bytes` up to a multiple of `unit`.
#[inline]
pub fn align_up(bytes: u64, unit: u64) -> u64 {
    debug_assert!(unit > 0);
    bytes.div_ceil(unit) * unit
}

/// Number of `unit`-sized pages needed to hold `bytes` (ceiling).
#[inline]
pub fn pages_for(bytes: u64, unit: u64) -> u64 {
    bytes.div_ceil(unit)
}

/// Exact page count as a fraction (Table 3 reports decimals like 1012.5).
#[inline]
pub fn pages_exact(bytes: u64, unit: u64) -> f64 {
    bytes as f64 / unit as f64
}

/// Human-readable size ("62.34 GB" style, decimal units to match the paper).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

/// Human-readable binary size ("2.00 MiB").
pub fn fmt_bytes_bin(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= GIB as f64 {
        format!("{:.2} GiB", b / GIB as f64)
    } else if b >= MIB as f64 {
        format!("{:.2} MiB", b / MIB as f64)
    } else if b >= KIB as f64 {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, VMM_PAGE), 0);
        assert_eq!(align_up(1, VMM_PAGE), VMM_PAGE);
        assert_eq!(align_up(VMM_PAGE, VMM_PAGE), VMM_PAGE);
        assert_eq!(align_up(VMM_PAGE + 1, VMM_PAGE), 2 * VMM_PAGE);
    }

    #[test]
    fn pages_exact_matches_table3_style() {
        // 1012.5 pages ↔ 2025 MiB
        assert!((pages_exact(2025 * MIB, VMM_PAGE) - 1012.5).abs() < 1e-9);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_bytes(62_340_000_000), "62.34 GB");
        assert!(fmt_bytes_bin(2 * MIB).contains("MiB"));
        assert_eq!(fmt_bytes(12), "12 B");
    }
}
