//! Stable, dependency-free content hashing shared by the shard manifests
//! and the trace-segment files (integrity fingerprints, not security).

/// FNV-1a 64-bit over raw bytes — stable across platforms and runs,
/// which is all the manifests need (integrity, not security).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fixed-width lowercase-hex form of a 64-bit hash.
pub fn hex64(h: u64) -> String {
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hex_is_zero_padded() {
        assert_eq!(hex64(0x1), "0000000000000001");
        assert_eq!(hex64(u64::MAX), "ffffffffffffffff");
    }
}
