//! Shared utilities: PRNG, statistics/bench harness, CLI/JSON parsing,
//! byte math, table rendering, logging.
//!
//! These substitute for crates (clap/serde/criterion/rand) that are absent
//! from the offline registry snapshot — see DESIGN.md §9.

pub mod bytes;
pub mod cli;
pub mod hash;
pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;

pub use bytes::{align_up, fmt_bytes, pages_exact, pages_for, GIB, KIB, MIB, VMM_PAGE};
pub use cli::Args;
pub use json::Json;
pub use prng::Prng;
pub use stats::{Bench, BenchResult, Summary};
pub use table::Table;
