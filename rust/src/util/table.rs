//! Aligned plain-text table printer used by every bench/repro binary to
//! render the paper's tables and figure series next to measured values.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table { header: header.into_iter().map(|s| s.into()).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(|s| s.into()).collect();
        self.rows.push(r);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a header underline; columns padded to max width.
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                let pad = w - c.chars().count();
                line.push_str(c);
                line.push_str(&" ".repeat(pad));
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `d` decimals (helper for table cells).
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Format "measured (paper P)" comparison cells.
pub fn vs_paper(measured: f64, paper: f64, d: usize) -> String {
    format!("{measured:.d$} (paper {paper:.d$})")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["a", "long-header", "c"]);
        t.row(["1", "2", "3"]);
        t.row(["wide-cell", "x", "y"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // header and rows align on the second column start
        let col2_hdr = lines[0].find("long-header").unwrap();
        let col2_row = lines[3].find('x').unwrap();
        assert_eq!(col2_hdr, col2_row);
    }

    #[test]
    fn missing_cells_ok() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
        assert!(t.render().contains("only-one"));
    }

    #[test]
    fn helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(vs_paper(2.0, 3.0, 1), "2.0 (paper 3.0)");
    }
}
