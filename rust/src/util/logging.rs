//! Tiny `log` facade backend: level-filtered stderr logger.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::atomic::{AtomicBool, Ordering};

static LOGGER: StderrLogger = StderrLogger;
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{lvl}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the stderr logger (idempotent). `GYGES_LOG` env var overrides:
/// error|warn|info|debug|trace.
pub fn init(default: LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let filter = match std::env::var("GYGES_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => default,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(filter);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Warn);
        init(LevelFilter::Trace); // second call must not panic
        log::info!("smoke");
    }
}
