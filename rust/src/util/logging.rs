//! Level-filtered stderr logging, dependency-free (the `log` facade crate
//! is unavailable in the offline registry snapshot, like clap/serde —
//! see DESIGN.md §9).
//!
//! Use the [`crate::log_error!`]..[`crate::log_trace!`] macros, or call
//! [`log`] directly. Until [`init`] runs, everything is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn by_name(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

/// 0 = off (pre-init); otherwise the maximum enabled `Level as u8`.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Install the stderr logger (idempotent — the first call wins). The
/// `GYGES_LOG` env var overrides: error|warn|info|debug|trace.
pub fn init(default: Level) {
    let level = std::env::var("GYGES_LOG")
        .ok()
        .as_deref()
        .and_then(Level::by_name)
        .unwrap_or(default);
    let _ = MAX_LEVEL.compare_exchange(0, level as u8, Ordering::SeqCst, Ordering::SeqCst);
}

/// Is `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record to stderr if `level` is enabled.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {target}: {msg}", level.name());
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_first_call_wins() {
        init(Level::Warn);
        init(Level::Trace); // second call must not raise the level
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        // GYGES_LOG may override in a dev shell; without it, Trace is off.
        if std::env::var("GYGES_LOG").is_err() {
            assert!(!enabled(Level::Trace));
        }
        crate::log_info!("smoke {}", 42);
    }

    #[test]
    fn level_names_roundtrip() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Trace] {
            assert_eq!(Level::by_name(l.name().trim()), Some(l));
        }
        assert_eq!(Level::by_name("nope"), None);
    }
}
