//! Minimal JSON value + writer (serde is unavailable offline).
//!
//! Benches and the metrics reporter emit machine-readable rows under
//! `target/repro/` with this. Only what we need: objects, arrays, strings,
//! numbers, bools, null — plus a tolerant parser for reading manifests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Field access on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer view of a number (fails on fractions — the
    /// shard manifests and bench gate read counts/indices with this).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9.0e15 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors for the snapshot/manifest codecs: fetch
    /// `key` from an object and coerce, with a `"{ctx}: bad {key}"` /
    /// `"{ctx}: missing {key}"` error naming the record being decoded.
    /// These replace the near-identical per-codec closures each decoder
    /// used to carry.
    pub fn req_u64(&self, key: &str, ctx: &str) -> Result<u64, String> {
        self.get(key).and_then(|v| v.as_u64()).ok_or_else(|| format!("{ctx}: bad {key:?}"))
    }

    pub fn req_f64(&self, key: &str, ctx: &str) -> Result<f64, String> {
        self.get(key).and_then(|v| v.as_f64()).ok_or_else(|| format!("{ctx}: bad {key:?}"))
    }

    pub fn req_bool(&self, key: &str, ctx: &str) -> Result<bool, String> {
        self.get(key).and_then(|v| v.as_bool()).ok_or_else(|| format!("{ctx}: bad {key:?}"))
    }

    pub fn req_str(&self, key: &str, ctx: &str) -> Result<&str, String> {
        self.get(key).and_then(|v| v.as_str()).ok_or_else(|| format!("{ctx}: missing {key:?}"))
    }

    pub fn req_arr(&self, key: &str, ctx: &str) -> Result<&[Json], String> {
        self.get(key).and_then(|v| v.as_arr()).ok_or_else(|| format!("{ctx}: missing {key:?}"))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (tolerant: trailing whitespace ok).
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = parse_value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing garbage at byte {i}"));
        }
        Ok(v)
    }
}

/// Compact serialization (`.to_string()` comes via the blanket
/// `ToString`; an inherent `to_string` would shadow it and trip clippy's
/// `inherent_to_string`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<Json, String> {
    skip_ws(b, i);
    if *i >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*i] {
        b'n' => lit(b, i, "null", Json::Null),
        b't' => lit(b, i, "true", Json::Bool(true)),
        b'f' => lit(b, i, "false", Json::Bool(false)),
        b'"' => parse_string(b, i).map(Json::Str),
        b'[' => {
            *i += 1;
            let mut v = Vec::new();
            skip_ws(b, i);
            if *i < b.len() && b[*i] == b']' {
                *i += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, i)?);
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b',') => *i += 1,
                    Some(b']') => {
                        *i += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(format!("expected , or ] at {i}", i = *i)),
                }
            }
        }
        b'{' => {
            *i += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, i);
            if *i < b.len() && b[*i] == b'}' {
                *i += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, i);
                let k = parse_string(b, i)?;
                skip_ws(b, i);
                if b.get(*i) != Some(&b':') {
                    return Err(format!("expected : at {i}", i = *i));
                }
                *i += 1;
                m.insert(k, parse_value(b, i)?);
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b',') => *i += 1,
                    Some(b'}') => {
                        *i += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected , or }} at {i}", i = *i)),
                }
            }
        }
        _ => parse_number(b, i),
    }
}

fn lit(b: &[u8], i: &mut usize, word: &str, val: Json) -> Result<Json, String> {
    if b[*i..].starts_with(word.as_bytes()) {
        *i += word.len();
        Ok(val)
    } else {
        Err(format!("bad literal at {i}", i = *i))
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<String, String> {
    if b.get(*i) != Some(&b'"') {
        return Err(format!("expected string at {i}", i = *i));
    }
    *i += 1;
    let mut s = String::new();
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(s);
            }
            b'\\' => {
                *i += 1;
                match b.get(*i) {
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*i + 1..*i + 5])
                            .map_err(|_| "bad \\u".to_string())?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *i += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *i += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let start = *i;
                let len = utf8_len(b[*i]);
                *i += len;
                s.push_str(std::str::from_utf8(&b[start..*i]).map_err(|e| e.to_string())?);
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], i: &mut usize) -> Result<Json, String> {
    let start = *i;
    while *i < b.len()
        && matches!(b[*i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *i += 1;
    }
    let txt = std::str::from_utf8(&b[start..*i]).map_err(|e| e.to_string())?;
    txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {txt:?}: {e}"))
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

/// Write rows of JSON (one per line) to `target/repro/<name>.jsonl`.
pub fn write_repro_rows(name: &str, rows: &[Json]) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/repro");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.jsonl"));
    let mut body = String::new();
    for r in rows {
        body.push_str(&r.to_string());
        body.push('\n');
    }
    std::fs::write(&path, body)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut o = Json::obj();
        o.set("name", "gyges").set("tps", 448.0).set("ok", true);
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn escaping() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::parse(&s).unwrap(), Json::Str("a\"b\\c\nd".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-150.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn typed_accessors() {
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(0.5).as_u64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Null.as_bool(), None);
        assert_eq!(Json::Str("1".into()).as_u64(), None);
    }

    #[test]
    fn required_field_accessors() {
        let v = Json::parse(r#"{"n": 3, "x": 0.5, "s": "hi", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(v.req_u64("n", "t").unwrap(), 3);
        assert_eq!(v.req_f64("x", "t").unwrap(), 0.5);
        assert_eq!(v.req_str("s", "t").unwrap(), "hi");
        assert!(v.req_bool("b", "t").unwrap());
        assert_eq!(v.req_arr("a", "t").unwrap().len(), 1);
        assert_eq!(v.req_u64("x", "t").unwrap_err(), "t: bad \"x\"");
        assert_eq!(v.req_u64("zz", "t").unwrap_err(), "t: bad \"zz\"");
        assert_eq!(v.req_str("zz", "t").unwrap_err(), "t: missing \"zz\"");
        assert_eq!(v.req_arr("n", "t").unwrap_err(), "t: missing \"n\"");
    }

    #[test]
    fn from_impls() {
        let v: Json = vec![1u64, 2, 3].into();
        assert_eq!(v.as_arr().unwrap().len(), 3);
    }
}
