//! Property-testing harness (proptest is unavailable offline).
//!
//! A property runs against many generated cases from a seeded [`Prng`];
//! on failure we report the seed + case index so the exact case replays,
//! and perform a simple halving shrink over integer parameters when the
//! property exposes them through [`Shrinkable`].

use crate::util::prng::Prng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // GYGES_PROPTEST_CASES overrides for CI-depth runs.
        let cases = std::env::var("GYGES_PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        Config { cases, seed: 0x6779_6765_73 } // "gyges"
    }
}

/// Run `prop` on `cases` random inputs produced by `gen`.
/// Panics with seed/case info on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Prng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case}/{total} (seed {seed:#x}):\n  input: {input:?}\n  error: {msg}",
                total = cfg.cases,
                seed = cfg.seed,
            );
        }
    }
}

/// Convenience: run with default config.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Prng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall(name, Config::default(), gen, prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert equality helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "sum-commutes",
            Config { cases: 50, seed: 1 },
            |r| (r.gen_range(0, 100), r.gen_range(0, 100)),
            |&(a, b)| {
                count += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_panics_with_context() {
        forall(
            "always-fails",
            Config { cases: 10, seed: 2 },
            |r| r.gen_range(0, 10),
            |_| Err("nope".into()),
        );
    }
}
