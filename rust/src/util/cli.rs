//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Used by the `gyges` binary, the examples, and the bench
//! harnesses.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (first element must NOT be argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option (parse error → None).
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Typed option with default.
    pub fn parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get_parsed(key).unwrap_or(default)
    }

    /// Typed option, strict: absent → `Ok(default)`; present but
    /// malformed → `Err`. For flags where a typo'd value must never
    /// silently become the default (e.g. a sweep horizon: every shard
    /// would agree on the wrong job list and merge cleanly into a
    /// figure the operator never asked for).
    pub fn parsed_strict<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| format!("--{key} {raw:?} is not a valid value")),
        }
    }

    /// Was a bare `--flag` given? (`--flag=true/false` also honoured.)
    pub fn flag(&self, key: &str) -> bool {
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// First positional, if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_key_value() {
        let a = parse("serve --model qwen2.5-32b --qps 0.6");
        assert_eq!(a.command(), Some("serve"));
        assert_eq!(a.get("model"), Some("qwen2.5-32b"));
        assert_eq!(a.get_parsed::<f64>("qps"), Some(0.6));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("--seed=42 --name=x");
        assert_eq!(a.get_parsed::<u64>("seed"), Some(42));
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn parses_flags() {
        let a = parse("bench --verbose --samples 10");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_parsed::<usize>("samples"), Some(10));
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn parsed_strict_rejects_malformed_but_defaults_when_absent() {
        let a = parse("launch --horizon 3600s");
        assert_eq!(a.parsed_strict::<f64>("segment-s", 60.0), Ok(60.0));
        assert!(a.parsed_strict::<f64>("horizon", 240.0).is_err());
        let ok = parse("launch --horizon 3600");
        assert_eq!(ok.parsed_strict::<f64>("horizon", 240.0), Ok(3600.0));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.parsed_or::<u64>("seed", 7), 7);
        assert!(a.command().is_none());
    }

    #[test]
    fn positional_order_preserved() {
        let a = parse("one two --k v three");
        assert_eq!(a.positional, vec!["one", "two", "three"]);
    }
}
