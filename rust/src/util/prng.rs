//! Deterministic PRNG for workload generation and property tests.
//!
//! The offline registry snapshot only ships `rand_core`, so we implement
//! xoshiro256++ (Blackman & Vigna) on top of it. All simulation randomness
//! flows through [`Prng`] so every experiment is reproducible from a seed.

use rand_core::{Error, RngCore, SeedableRng};

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64, used to expand a 64-bit seed into the full state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1)
        (self.next() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (half-open). Panics if lo >= hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire's nearly-divisionless method.
        let mut x = self.next();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(0, n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0,1]
        -u.ln() / lambda
    }

    /// Standard normal variate (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal variate with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto variate with scale `xm` and shape `alpha` (heavy tail).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator with an independent stream.
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next())
    }
}

impl RngCore for Prng {
    fn next_u32(&mut self) -> u32 {
        (self.next() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        for chunk in dest.chunks_mut(8) {
            let v = self.next().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Prng {
    type Seed = [u8; 8];
    fn from_seed(seed: Self::Seed) -> Self {
        Prng::new(u64::from_le_bytes(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next() == b.next()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Prng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Prng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(0, 8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Prng::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Prng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn pareto_heavier_than_exponential() {
        let mut r = Prng::new(13);
        let n = 100_000;
        let big = (0..n).filter(|_| r.pareto(1.0, 1.5) > 20.0).count();
        assert!(big > 0, "pareto tail should produce large values");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_works() {
        let mut r = Prng::new(23);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
