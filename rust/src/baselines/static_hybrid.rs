//! Static hybrid deployment baseline (§3.3): fixed instances of varying
//! parallelism — e.g. one TP4 plus four TP1 on an 8-GPU host — with no
//! runtime transformation. Long requests can only go to the TP4; its
//! capacity is reserved whether or not long requests are present.

use crate::config::{ClusterConfig, Policy};
use crate::coordinator::cluster::{ClusterSim, SimOutcome, SystemKind};
use crate::workload::Trace;

/// Static deployment shape.
#[derive(Clone, Debug)]
pub struct StaticHybridConfig {
    /// (degree, count) pairs per host; degrees × counts must sum to
    /// gpus_per_host.
    pub groups: Vec<(u64, usize)>,
}

impl StaticHybridConfig {
    /// The paper's production example: one TP4 + four TP1 per 8-GPU host.
    pub fn paper_default() -> StaticHybridConfig {
        StaticHybridConfig { groups: vec![(4, 1), (1, 4)] }
    }

    pub fn gpus_per_host(&self) -> usize {
        self.groups.iter().map(|(d, c)| *d as usize * c).sum()
    }
}

/// Run a static hybrid deployment on a trace: same simulator, but scale-up
/// and scale-down are disabled (the policy can only assign or defer).
pub fn run_static_hybrid(
    cfg: &ClusterConfig,
    shape: &StaticHybridConfig,
    trace: &Trace,
) -> SimOutcome {
    assert_eq!(
        shape.gpus_per_host(),
        cfg.gpus_per_host,
        "shape must cover the host exactly"
    );
    let mut sim = ClusterSim::new(cfg.clone(), SystemKind::Gyges, trace.clone())
        .with_policy(Policy::LeastLoadFirst);
    // Rebuild the instance set to the static shape, disable transformation.
    sim.replace_instances(|host, gpu_base| {
        let mut out = Vec::new();
        let mut gpu = gpu_base;
        for (degree, count) in &shape.groups {
            for _ in 0..*count {
                let workers: Vec<usize> = (gpu..gpu + *degree as usize).collect();
                gpu += *degree as usize;
                out.push((host, workers, *degree));
            }
        }
        out
    });
    sim.disable_transformation();
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::run_system;

    #[test]
    fn static_shape_math() {
        let s = StaticHybridConfig::paper_default();
        assert_eq!(s.gpus_per_host(), 8);
    }

    #[test]
    fn static_hybrid_serves_mixed_trace() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let trace = Trace::hybrid_paper(23, 120.0);
        let out = run_static_hybrid(&cfg, &StaticHybridConfig::paper_default(), &trace);
        assert!(out.report.completed > 0);
        assert_eq!(out.counters.scale_ups, 0, "static deployment never transforms");
        assert_eq!(out.counters.scale_downs, 0);
    }

    #[test]
    fn gyges_beats_static_hybrid_under_short_heavy_load() {
        // §3.3: reserving a TP4 for sporadic longs wastes throughput.
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        // Decode-bound short load (small inputs, 10 qps × 300 output tokens) — demand
        // (~6000 tps) saturates both systems, so throughput converges to capacity:
        // static ≈ 4×TP1 + TP4 < 8×TP1 (Table 1's 2.33× decode gap).
        let mut trace = Trace::default();
        for i in 0..600u64 {
            trace.requests.push(crate::workload::TraceRequest {
                id: i,
                arrival: crate::sim::SimTime::from_secs_f64(i as f64 * 0.05),
                input_len: 200,
                output_len: 300,
                class: crate::workload::SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        trace.sort();
        let st = run_static_hybrid(&cfg, &StaticHybridConfig::paper_default(), &trace);
        let gy = run_system(cfg, SystemKind::Gyges, None, trace);
        assert!(
            gy.report.throughput_tps > st.report.throughput_tps,
            "gyges {} vs static {}",
            gy.report.throughput_tps,
            st.report.throughput_tps
        );
    }

    #[test]
    #[should_panic(expected = "cover the host")]
    fn shape_mismatch_rejected() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let bad = StaticHybridConfig { groups: vec![(4, 1)] };
        run_static_hybrid(&cfg, &bad, &Trace::default());
    }
}
