//! Baseline systems (paper §3.3 / §6.3).
//!
//! The *mechanism* models live where they act:
//! * Seesaw's blocking CPU-shared-memory re-shard → [`crate::transform::Mechanism::Seesaw`]
//! * KunServe's dynamic PP / LoongServe's elastic SP inefficiency →
//!   [`crate::coordinator::ParallelKind`] step scaling
//! * RR / LLF schedulers → [`crate::coordinator::scheduler`]
//!
//! This module adds the **static hybrid** deployment (the production
//! practice Gyges replaces: one TP4 + four TP1 instances per 8-GPU host,
//! §3.3) and convenience runners for the Figure 14 comparison series.

pub mod static_hybrid;

pub use static_hybrid::{run_static_hybrid, StaticHybridConfig};

use crate::config::ClusterConfig;
use crate::coordinator::{run_system, SimOutcome, SystemKind};
use crate::workload::Trace;

/// The systems compared end-to-end in Figure 14.
pub fn fig14_systems() -> Vec<SystemKind> {
    vec![
        SystemKind::Gyges,
        SystemKind::GygesNoOverlap,
        SystemKind::KunServe,
        SystemKind::LoongServe,
    ]
}

/// Run every Figure-14 system on the same trace.
pub fn run_fig14(cfg: &ClusterConfig, trace: &Trace) -> Vec<SimOutcome> {
    fig14_systems()
        .into_iter()
        .map(|sys| run_system(cfg.clone(), sys, None, trace.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn fig14_systems_all_run() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let trace = Trace::hybrid_paper(3, 90.0);
        let outs = run_fig14(&cfg, &trace);
        assert_eq!(outs.len(), 4);
        for o in &outs {
            assert!(o.report.completed > 0, "{}: nothing completed", o.report.label);
        }
    }

    #[test]
    fn gyges_beats_pp_sp_on_throughput() {
        // §6.3's central claim, scaled down: on a mixed trace Gyges
        // sustains at least the PP/SP baselines' throughput.
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let trace = Trace::hybrid_paper(17, 300.0);
        let outs = run_fig14(&cfg, &trace);
        let gy = outs[0].report.throughput_tps;
        let ks = outs[2].report.throughput_tps;
        let ls = outs[3].report.throughput_tps;
        assert!(gy >= ks * 0.95, "gyges {gy} vs kunserve {ks}");
        assert!(gy >= ls * 0.95, "gyges {gy} vs loongserve {ls}");
    }
}
