//! Padded-FFN reference math (Eq. 2): FFN′(I) = f(I·U′)·D′ equals
//! FFN(I) = f(I·U)·D when U gains zero *columns* and D gains matching
//! zero *rows*.
//!
//! This is the Rust mirror of python/compile/kernels/ref.py; the property
//! tests here and the pytest suite check the same identity on both sides
//! of the language boundary, and the Pallas kernel is validated against
//! the Python twin.

/// Dense row-major f64 matrix (small sizes; used for verification only —
/// the serving hot path runs the AOT-compiled HLO, not this).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Matrix product.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(r, k);
                if a == 0.0 {
                    continue;
                }
                for c in 0..other.cols {
                    out.data[r * other.cols + c] += a * other.at(k, c);
                }
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Max |a−b| against another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// GELU (tanh approximation — matches the Pallas kernel).
pub fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + ((2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x.powi(3))).tanh())
}

/// Plain FFN: f(I·U)·D.
pub fn ffn(input: &Mat, up: &Mat, down: &Mat, f: impl Fn(f64) -> f64) -> Mat {
    input.matmul(up).map(&f).matmul(down)
}

/// Build U′ from U by splitting columns into `shards` shards and inserting
/// `pad_cols[k]` zero columns after shard k (§4.2: U′ = [U₁ 0 U₂ 0 …]).
pub fn pad_columns(u: &Mat, shards: usize, pad_cols: &[usize]) -> Mat {
    assert_eq!(pad_cols.len(), shards);
    assert_eq!(u.cols % shards, 0);
    let shard_w = u.cols / shards;
    let total_pad: usize = pad_cols.iter().sum();
    let mut out = Mat::zeros(u.rows, u.cols + total_pad);
    let mut dst = 0;
    for s in 0..shards {
        for c in 0..shard_w {
            for r in 0..u.rows {
                let v = u.at(r, s * shard_w + c);
                out.set(r, dst + c, v);
            }
        }
        dst += shard_w + pad_cols[s];
    }
    out
}

/// Build D′ from D by splitting rows into shards and inserting matching
/// zero rows (D′ = [D₁ᵀ 0 D₂ᵀ 0 …]ᵀ).
pub fn pad_rows(d: &Mat, shards: usize, pad_rows_: &[usize]) -> Mat {
    assert_eq!(pad_rows_.len(), shards);
    assert_eq!(d.rows % shards, 0);
    let shard_h = d.rows / shards;
    let total_pad: usize = pad_rows_.iter().sum();
    let mut out = Mat::zeros(d.rows + total_pad, d.cols);
    let mut dst = 0;
    for s in 0..shards {
        for r in 0..shard_h {
            for c in 0..d.cols {
                out.set(dst + r, c, d.at(s * shard_h + r, c));
            }
        }
        dst += shard_h + pad_rows_[s];
    }
    out
}

/// Whether an activation maps 0 → 0. Not required for the FFN′ identity
/// (D′'s zero rows annihilate the padded intermediate regardless of
/// f(0)), but zero-preserving activations additionally keep the padded
/// intermediate itself sparse, which the Pallas kernel exploits by
/// skipping pad blocks.
pub fn zero_preserving(f: impl Fn(f64) -> f64) -> bool {
    f(0.0).abs() < 1e-15
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(rng: &mut Prng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    /// Eq. 2: the padded FFN equals the raw FFN exactly.
    #[test]
    fn padded_ffn_equals_raw_ffn() {
        let mut rng = Prng::new(42);
        for _ in 0..10 {
            let (b, h, i) = (3, 8, 16);
            let input = rand_mat(&mut rng, b, h);
            let up = rand_mat(&mut rng, h, i);
            let down = rand_mat(&mut rng, i, h);
            let shards = 4;
            let pads = [2usize, 1, 3, 2];
            let up_p = pad_columns(&up, shards, &pads);
            let down_p = pad_rows(&down, shards, &pads);
            let raw = ffn(&input, &up, &down, gelu);
            let padded = ffn(&input, &up_p, &down_p, gelu);
            assert!(raw.max_abs_diff(&padded) < 1e-12);
        }
    }

    /// The identity holds for ANY activation — D′'s zero rows cancel the
    /// pad columns even when f(0) ≠ 0 — which is stronger than Eq. 2
    /// needs. (f(0)=0 additionally keeps the intermediate sparse.)
    #[test]
    fn identity_holds_even_for_non_zero_preserving_activation() {
        assert!(zero_preserving(gelu));
        assert!(zero_preserving(|x: f64| x.max(0.0)));
        assert!(!zero_preserving(|x: f64| x + 1.0));

        let mut rng = Prng::new(7);
        let input = rand_mat(&mut rng, 2, 4);
        let up = rand_mat(&mut rng, 4, 8);
        let down = rand_mat(&mut rng, 8, 4);
        let up_p = pad_columns(&up, 2, &[1, 1]);
        let down_p = pad_rows(&down, 2, &[1, 1]);
        let shifted = |x: f64| x + 1.0;
        let raw = ffn(&input, &up, &down, shifted);
        let padded = ffn(&input, &up_p, &down_p, shifted);
        assert!(raw.max_abs_diff(&padded) < 1e-12);
    }

    #[test]
    fn pad_shapes() {
        let u = Mat::zeros(4, 8);
        let up = pad_columns(&u, 4, &[1, 1, 1, 1]);
        assert_eq!((up.rows, up.cols), (4, 12));
        let d = Mat::zeros(8, 4);
        let dp = pad_rows(&d, 4, &[1, 1, 1, 1]);
        assert_eq!((dp.rows, dp.cols), (12, 4));
    }

    #[test]
    fn zero_padding_is_noop() {
        let mut rng = Prng::new(3);
        let u = rand_mat(&mut rng, 4, 8);
        let up = pad_columns(&u, 2, &[0, 0]);
        assert_eq!(u, up);
    }

    #[test]
    fn matmul_reference() {
        let a = Mat::from_fn(2, 2, |r, c| (r * 2 + c) as f64 + 1.0); // [1 2; 3 4]
        let b = Mat::from_fn(2, 2, |_, _| 1.0);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }
}
