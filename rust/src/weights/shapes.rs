//! Per-layer weight tensor shapes under tensor parallelism.
//!
//! TP splits the MLP column-wise on `up_proj`/`gate_proj` (output dim) and
//! row-wise on `down_proj` (input dim); each worker holds a
//! `[hidden, inter/tp]` and `[inter/tp, hidden]` slice. These shapes feed
//! the Table-3 page math and the padding planner.

use crate::config::{MlpKind, ModelConfig};

/// Which MLP projection a tensor is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proj {
    /// `[hidden, inter]`, column-split under TP.
    Up,
    /// `[hidden, inter]`, column-split (SwiGLU only).
    Gate,
    /// `[inter, hidden]`, row-split under TP.
    Down,
}

/// One worker's shard of one projection tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorShard {
    pub proj: Proj,
    pub rows: u64,
    pub cols: u64,
    pub dtype_bytes: u64,
}

impl TensorShard {
    pub fn bytes(&self) -> u64 {
        self.rows * self.cols * self.dtype_bytes
    }
}

/// The MLP tensor shards one worker holds for one layer at TP `tp`
/// (per expert for MoE models).
pub fn mlp_shards(model: &ModelConfig, tp: u64) -> Vec<TensorShard> {
    assert!(tp >= 1 && model.inter_size % tp == 0, "tp must divide inter_size");
    let shard_inter = model.inter_size / tp;
    let d = model.dtype_bytes;
    let mut v = vec![TensorShard {
        proj: Proj::Up,
        rows: model.hidden_size,
        cols: shard_inter,
        dtype_bytes: d,
    }];
    if model.mlp == MlpKind::SwiGlu {
        v.push(TensorShard {
            proj: Proj::Gate,
            rows: model.hidden_size,
            cols: shard_inter,
            dtype_bytes: d,
        });
    }
    v.push(TensorShard {
        proj: Proj::Down,
        rows: shard_inter,
        cols: model.hidden_size,
        dtype_bytes: d,
    });
    v
}

/// Total per-worker MLP bytes for one layer at TP `tp` (all experts).
pub fn mlp_shard_bytes(model: &ModelConfig, tp: u64) -> u64 {
    let per_expert: u64 = mlp_shards(model, tp).iter().map(|s| s.bytes()).sum();
    per_expert * model.num_experts.max(1)
}

/// Byte offset ranges (within the layer's contiguous MLP region) that
/// belong to worker `rank` of `tp`, assuming tensors are laid out
/// [up | gate? | down] with each tensor stored shard-major (shard r of
/// every tensor is contiguous). Used by the migration planner.
pub fn shard_ranges(model: &ModelConfig, tp: u64, rank: u64) -> Vec<(u64, u64)> {
    assert!(rank < tp);
    let mut ranges = Vec::new();
    let mut base = 0u64;
    for s in mlp_shards(model, 1) {
        let full = s.bytes();
        let shard = full / tp;
        let start = base + rank * shard;
        ranges.push((start, start + shard));
        base += full;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sizes_divide_evenly() {
        let m = ModelConfig::qwen2_5_32b();
        for tp in [1, 2, 4] {
            let total: u64 = mlp_shard_bytes(&m, tp);
            assert_eq!(total, m.mlp_layer_bytes() / tp);
        }
    }

    #[test]
    fn swiglu_has_three_tensors() {
        let m = ModelConfig::qwen2_5_32b();
        assert_eq!(mlp_shards(&m, 1).len(), 3);
        let tiny = ModelConfig::gyges_tiny(); // Gelu
        assert_eq!(mlp_shards(&tiny, 1).len(), 2);
    }

    #[test]
    fn shard_ranges_partition_the_layer() {
        let m = ModelConfig::llama3_8b();
        let tp = 4;
        let mut all: Vec<(u64, u64)> = (0..tp).flat_map(|r| shard_ranges(&m, tp, r)).collect();
        all.sort_unstable();
        // Ranges must tile [0, layer_bytes) without gaps or overlaps.
        let mut expect = 0u64;
        for (a, b) in &all {
            assert_eq!(*a, expect, "gap/overlap at {a}");
            expect = *b;
        }
        let per_expert_total: u64 = mlp_shards(&m, 1).iter().map(|s| s.bytes()).sum();
        assert_eq!(expect, per_expert_total);
    }

    #[test]
    fn up_and_down_transpose_shapes() {
        let m = ModelConfig::llama2_7b();
        let shards = mlp_shards(&m, 2);
        let up = shards.iter().find(|s| s.proj == Proj::Up).unwrap();
        let down = shards.iter().find(|s| s.proj == Proj::Down).unwrap();
        assert_eq!(up.rows, m.hidden_size);
        assert_eq!(up.cols, m.inter_size / 2);
        assert_eq!(down.rows, m.inter_size / 2);
        assert_eq!(down.cols, m.hidden_size);
    }
}
