//! Table 3: MLP weight page counts per tensor under the 2 MiB CUDA VMM
//! granularity — "decimals mean unaligned placements of tensors".
//!
//! A fractional page count means a TP shard boundary falls inside a page:
//! direct partitioning would strand partially-used pages (Figure 6a),
//! which is exactly what the padding of §4.2 eliminates.

use crate::config::ModelConfig;
use crate::util::bytes::{pages_exact, VMM_PAGE};

/// Page counts for one model at one TP degree.
#[derive(Clone, Debug)]
pub struct PageCounts {
    pub model: &'static str,
    pub tp: u64,
    /// Pages of one projection tensor shard (× experts for MoE) — the
    /// first number in the paper's Table 3 cells.
    pub per_tensor: f64,
    /// Pages of the fused gate+up shard (the second number where the
    /// paper reports a pair).
    pub per_fused_tensor: f64,
    /// True iff the shard does NOT align to the 2 MiB granularity.
    pub unaligned: bool,
}

/// Compute Table-3 page counts for `model` at TP `tp`.
pub fn page_counts(model: &ModelConfig, tp: u64) -> PageCounts {
    let experts = model.num_experts.max(1);
    let shard_bytes = model.up_proj_bytes() / tp * experts;
    let fused_bytes = 2 * model.up_proj_bytes() / tp * experts;
    let per_tensor = pages_exact(shard_bytes, VMM_PAGE);
    let per_fused = pages_exact(fused_bytes, VMM_PAGE);
    PageCounts {
        model: model.name,
        tp,
        per_tensor,
        per_fused_tensor: per_fused,
        unaligned: shard_bytes % VMM_PAGE != 0,
    }
}

/// Number of pages wasted per tensor shard without padding (the stranded
/// tail of the last page, expressed in pages).
pub fn stranded_fraction(model: &ModelConfig, tp: u64) -> f64 {
    let c = page_counts(model, tp);
    let frac = c.per_tensor - c.per_tensor.floor();
    if frac == 0.0 {
        0.0
    } else {
        1.0 - frac
    }
}

/// The paper's Table 3 rows (model, TP1 pair, TP4 pair).
pub fn table3_rows() -> Vec<(ModelConfig, (f64, f64), (f64, f64))> {
    vec![
        (ModelConfig::gpt_oss_120b(), (1012.5, 2025.0), (253.125, 506.25)),
        (ModelConfig::gpt_oss_20b(), (253.125, 506.25), (63.28125, 126.5625)),
        (ModelConfig::llama3_1_70b(), (224.0, 224.0), (56.0, 56.0)),
        (ModelConfig::qwen2_5_32b(), (135.0, 135.0), (33.75, 33.75)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reproduce every Table 3 cell exactly.
    #[test]
    fn table3_exact_reproduction() {
        for (model, (tp1_single, _tp1_fused), (tp4_single, _tp4_fused)) in table3_rows() {
            let c1 = page_counts(&model, 1);
            let c4 = page_counts(&model, 4);
            assert!(
                (c1.per_tensor - tp1_single).abs() < 1e-9,
                "{}: TP1 {} vs paper {}",
                model.name,
                c1.per_tensor,
                tp1_single
            );
            assert!(
                (c4.per_tensor - tp4_single).abs() < 1e-9,
                "{}: TP4 {} vs paper {}",
                model.name,
                c4.per_tensor,
                tp4_single
            );
        }
    }

    #[test]
    fn fused_is_double_single() {
        for (model, (tp1_single, tp1_fused), _) in table3_rows() {
            let c = page_counts(&model, 1);
            assert!((c.per_fused_tensor - 2.0 * c.per_tensor).abs() < 1e-9);
            // cross-check against the paper's pairs where they differ
            if (tp1_fused - tp1_single).abs() > 1e-9 {
                assert!((c.per_fused_tensor - tp1_fused).abs() < 1e-9);
            }
        }
    }

    /// "More than half of the models encounter this fragmentation issue."
    #[test]
    fn misalignment_detection() {
        assert!(page_counts(&ModelConfig::gpt_oss_120b(), 1).unaligned);
        assert!(page_counts(&ModelConfig::gpt_oss_20b(), 4).unaligned);
        assert!(!page_counts(&ModelConfig::llama3_1_70b(), 1).unaligned);
        assert!(!page_counts(&ModelConfig::qwen2_5_32b(), 1).unaligned);
        assert!(page_counts(&ModelConfig::qwen2_5_32b(), 4).unaligned); // 33.75
    }

    #[test]
    fn stranded_fraction_bounds() {
        for m in ModelConfig::all() {
            for tp in [1, 2, 4] {
                if m.inter_size % tp != 0 {
                    continue;
                }
                let s = stranded_fraction(&m, tp);
                assert!((0.0..1.0).contains(&s), "{}: {s}", m.name);
            }
        }
    }
}
