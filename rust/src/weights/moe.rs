//! Expert-parallel (EP) extension for MoE models (paper §2: 3.2% of
//! production instances run TP+EP; GPT-OSS-120B/20B appear in Table 3).
//!
//! EP places whole experts on workers, so an EP re-balance migrates
//! expert-sized contiguous blobs — the analogue of the header-centric
//! property for MLP weights: no sub-tensor splitting, so with per-expert
//! padding to the 2 MiB page the transformation is map/unmap only.
//! Gyges' TP transformation composes with EP: the TP degree splits each
//! resident expert's tensors, EP splits the expert set.

use super::padding::TensorPadPlan;
use super::shapes::{mlp_shards, TensorShard};
use crate::config::ModelConfig;
use crate::util::bytes::VMM_PAGE;

/// A TP×EP placement for a MoE model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoePlacement {
    pub tp: u64,
    pub ep: u64,
}

impl MoePlacement {
    /// Workers used by one instance.
    pub fn workers(&self) -> u64 {
        self.tp * self.ep
    }

    /// Valid for `model`? (EP must divide experts, TP the inter dim.)
    pub fn valid_for(&self, model: &ModelConfig) -> bool {
        model.num_experts > 1
            && model.num_experts % self.ep == 0
            && model.inter_size % self.tp == 0
    }
}

/// Experts resident on each worker group under `p`.
pub fn experts_per_group(model: &ModelConfig, p: MoePlacement) -> u64 {
    assert!(p.valid_for(model), "invalid placement");
    model.num_experts / p.ep
}

/// Bytes of one expert's MLP tensors under TP degree `tp` (one shard).
pub fn expert_shard_bytes(model: &ModelConfig, tp: u64) -> u64 {
    mlp_shards(model, tp).iter().map(TensorShard::bytes).sum()
}

/// Per-expert padded shard bytes (every projection padded to the page).
pub fn expert_padded_shard_bytes(model: &ModelConfig, tp: u64) -> u64 {
    mlp_shards(model, tp)
        .iter()
        .map(|s| TensorPadPlan::plan(s, tp).padded_shard_bytes)
        .sum()
}

/// Padding overhead fraction for per-expert page alignment.
pub fn expert_padding_overhead(model: &ModelConfig, tp: u64) -> f64 {
    let raw = expert_shard_bytes(model, tp);
    if raw == 0 {
        return 0.0;
    }
    (expert_padded_shard_bytes(model, tp) - raw) as f64 / raw as f64
}

/// Report of an EP re-balance: moving `experts_moved` experts between
/// worker groups (e.g. EP4→EP2 doubles residency per group).
#[derive(Clone, Debug)]
pub struct EpRebalanceReport {
    /// Experts transferred per worker.
    pub experts_moved: u64,
    /// Bytes transferred per worker (whole padded experts — contiguous).
    pub bytes_moved: u64,
    /// Pages mapped/unmapped per worker (no copies with padding).
    pub pages_touched: u64,
}

/// Plan an EP re-balance `from.ep → to.ep` at constant TP.
pub fn plan_ep_rebalance(
    model: &ModelConfig,
    from: MoePlacement,
    to: MoePlacement,
) -> EpRebalanceReport {
    assert_eq!(from.tp, to.tp, "EP re-balance at constant TP");
    assert!(from.valid_for(model) && to.valid_for(model));
    let before = experts_per_group(model, from);
    let after = experts_per_group(model, to);
    let delta = after.abs_diff(before);
    let per_expert = expert_padded_shard_bytes(model, from.tp) * model.num_layers;
    EpRebalanceReport {
        experts_moved: delta,
        bytes_moved: delta * per_expert,
        pages_touched: delta * per_expert / VMM_PAGE,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moe() -> ModelConfig {
        ModelConfig::gpt_oss_20b()
    }

    #[test]
    fn placement_validity() {
        let m = moe();
        assert!(MoePlacement { tp: 1, ep: 4 }.valid_for(&m));
        assert!(MoePlacement { tp: 4, ep: 8 }.valid_for(&m));
        assert!(!MoePlacement { tp: 1, ep: 7 }.valid_for(&m), "7 ∤ 32");
        let dense = ModelConfig::qwen2_5_32b();
        assert!(!MoePlacement { tp: 1, ep: 2 }.valid_for(&dense));
    }

    #[test]
    fn residency_math() {
        let m = moe(); // 32 experts
        assert_eq!(experts_per_group(&m, MoePlacement { tp: 1, ep: 4 }), 8);
        assert_eq!(experts_per_group(&m, MoePlacement { tp: 2, ep: 32 }), 1);
    }

    #[test]
    fn expert_padding_is_page_aligned_and_bounded() {
        let m = moe();
        for tp in [1u64, 2, 4] {
            let padded = expert_padded_shard_bytes(&m, tp);
            assert_eq!(padded % VMM_PAGE, 0, "tp{tp}");
            let overhead = expert_padding_overhead(&m, tp);
            // GPT-OSS per-expert tensors are small (7.9 pages at TP1), so
            // per-expert alignment costs more than dense models — this is
            // the Figure-10b upper range (≤14%).
            assert!((0.0..0.16).contains(&overhead), "tp{tp}: {overhead}");
        }
    }

    #[test]
    fn rebalance_moves_whole_experts() {
        let m = moe();
        let r = plan_ep_rebalance(
            &m,
            MoePlacement { tp: 1, ep: 4 },
            MoePlacement { tp: 1, ep: 2 },
        );
        assert_eq!(r.experts_moved, 8); // 8 → 16 resident
        assert_eq!(r.bytes_moved % VMM_PAGE, 0, "whole padded experts move");
        assert_eq!(r.pages_touched * VMM_PAGE, r.bytes_moved);
    }

    #[test]
    fn table3_consistency() {
        // The per-tensor page counts of Table 3 are per-expert × experts;
        // one expert's up_proj at TP1 is 2880×2880×2 B = 7.91015625 pages.
        let m = ModelConfig::gpt_oss_120b();
        let up = mlp_shards(&m, 1)[0];
        let pages = up.bytes() as f64 / VMM_PAGE as f64;
        assert!((pages - 1012.5 / 128.0).abs() < 1e-9);
    }
}
