//! Parallelism-aware weight padding (§4.2, Figure 6c).
//!
//! For every potential TP split boundary (determined by the largest TP
//! degree the instance may transform into), each shard is padded so it
//! starts and ends on a 2 MiB page boundary. Padding is expressed as
//! (a) whole zero columns — which keep FFN′ == FFN per Eq. 2 — plus
//! (b) a sub-column byte tail that is never read by the GEMM.
//! With this plan, scale-up is pure page release and scale-down is pure
//! page re-map: no weight bytes are ever copied.

use super::shapes::{mlp_shards, Proj, TensorShard};
use crate::config::ModelConfig;
use crate::util::bytes::{align_up, VMM_PAGE};

/// Padding plan for one projection tensor under a maximum TP degree.
#[derive(Clone, Debug)]
pub struct TensorPadPlan {
    pub proj: Proj,
    /// Unpadded bytes of one TP-`max_tp` shard.
    pub shard_bytes: u64,
    /// Shard bytes after padding (page-aligned).
    pub padded_shard_bytes: u64,
    /// Zero columns (Up/Gate) or zero rows (Down) inserted per boundary.
    pub zero_vectors: u64,
    /// Sub-column tail padding bytes per boundary.
    pub tail_bytes: u64,
    /// Number of shards (= max_tp).
    pub shards: u64,
}

impl TensorPadPlan {
    pub fn plan(shard: &TensorShard, max_tp: u64) -> TensorPadPlan {
        // Column-split tensors shard by columns; row-split by rows. Either
        // way the "vector" (one column / one row) byte size is:
        let vec_bytes = match shard.proj {
            Proj::Up | Proj::Gate => shard.rows * shard.dtype_bytes, // per column
            Proj::Down => shard.cols * shard.dtype_bytes,            // per row
        };
        let shard_bytes = shard.bytes(); // already a TP-`max_tp` shard
        let padded = align_up(shard_bytes, VMM_PAGE);
        let pad = padded - shard_bytes;
        TensorPadPlan {
            proj: shard.proj,
            shard_bytes,
            padded_shard_bytes: padded,
            zero_vectors: pad / vec_bytes,
            tail_bytes: pad % vec_bytes,
            shards: max_tp,
        }
    }

    /// Total padded tensor bytes (all shards).
    pub fn padded_total(&self) -> u64 {
        self.padded_shard_bytes * self.shards
    }

    /// Total unpadded tensor bytes.
    pub fn unpadded_total(&self) -> u64 {
        self.shard_bytes * self.shards
    }

    /// Pages per padded shard (always integral — that is the point).
    pub fn pages_per_shard(&self) -> u64 {
        self.padded_shard_bytes / VMM_PAGE
    }
}

/// Padding plan for a whole layer's MLP at a given max TP degree.
#[derive(Clone, Debug)]
pub struct LayerPadPlan {
    pub tensors: Vec<TensorPadPlan>,
    pub max_tp: u64,
    /// Experts multiplier (MoE).
    pub experts: u64,
}

impl LayerPadPlan {
    /// Build the plan for `model` supporting transformation up to `max_tp`.
    pub fn plan(model: &ModelConfig, max_tp: u64) -> LayerPadPlan {
        let tensors = mlp_shards(model, max_tp)
            .iter()
            .map(|s| TensorPadPlan::plan(s, max_tp))
            .collect();
        LayerPadPlan { tensors, max_tp, experts: model.num_experts.max(1) }
    }

    /// Padded layer MLP bytes.
    pub fn padded_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.padded_total()).sum::<u64>() * self.experts
    }

    /// Unpadded layer MLP bytes.
    pub fn unpadded_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.unpadded_total()).sum::<u64>() * self.experts
    }

    /// Memory overhead fraction introduced by padding (Figure 10b:
    /// 0%–14% across models).
    pub fn overhead_fraction(&self) -> f64 {
        let u = self.unpadded_bytes();
        if u == 0 {
            return 0.0;
        }
        (self.padded_bytes() - u) as f64 / u as f64
    }

    /// Per-worker padded MLP bytes at TP degree `tp` (tp ≤ max_tp and the
    /// worker holds max_tp/tp padded shards per tensor).
    pub fn worker_bytes(&self, tp: u64) -> u64 {
        assert!(tp <= self.max_tp && self.max_tp % tp == 0);
        self.padded_bytes() / tp
    }

    /// Pages RELEASED per worker per layer when scaling `from_tp → to_tp`
    /// (scale-up): the shards handed off to other workers. With padding,
    /// these are whole pages — release is a driver call, zero copies.
    pub fn pages_released_per_worker(&self, from_tp: u64, to_tp: u64) -> u64 {
        assert!(to_tp > from_tp);
        let before = self.worker_bytes(from_tp);
        let after = self.worker_bytes(to_tp);
        (before - after) / VMM_PAGE
    }

    /// Bytes each worker must RECEIVE per layer when scaling down
    /// `from_tp → to_tp` (it re-acquires shards other workers held).
    pub fn bytes_received_per_worker(&self, from_tp: u64, to_tp: u64) -> u64 {
        assert!(to_tp < from_tp);
        self.worker_bytes(to_tp) - self.worker_bytes(from_tp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_shards_are_page_aligned() {
        for m in ModelConfig::all() {
            if m.inter_size % 4 != 0 {
                continue;
            }
            let plan = LayerPadPlan::plan(&m, 4);
            for t in &plan.tensors {
                assert_eq!(t.padded_shard_bytes % VMM_PAGE, 0, "{}", m.name);
                assert!(t.padded_shard_bytes >= t.shard_bytes);
                assert!(t.padded_shard_bytes - t.shard_bytes < VMM_PAGE);
            }
        }
    }

    #[test]
    fn overhead_within_paper_band() {
        // Figure 10b: padding overhead ranges 0%–14%.
        for m in ModelConfig::eval_set() {
            let plan = LayerPadPlan::plan(&m, 4);
            let f = plan.overhead_fraction();
            assert!((0.0..=0.14).contains(&f), "{}: overhead {f}", m.name);
        }
    }

    #[test]
    fn aligned_models_need_no_padding_at_tp1() {
        // Llama-3.1-70B TP1 tensors are exactly 224 pages — zero padding.
        let m = ModelConfig::llama3_1_70b();
        let plan = LayerPadPlan::plan(&m, 1);
        assert_eq!(plan.overhead_fraction(), 0.0);
    }

    #[test]
    fn qwen_tp4_pads_33_75_to_34_pages() {
        let m = ModelConfig::qwen2_5_32b();
        let plan = LayerPadPlan::plan(&m, 4);
        let up = plan.tensors.iter().find(|t| t.proj == Proj::Up).unwrap();
        assert_eq!(up.pages_per_shard(), 34); // 33.75 → 34
    }

    #[test]
    fn zero_vector_decomposition_consistent() {
        for m in ModelConfig::eval_set() {
            let plan = LayerPadPlan::plan(&m, 4);
            for t in &plan.tensors {
                let vec_bytes = match t.proj {
                    Proj::Up | Proj::Gate => m.hidden_size * m.dtype_bytes,
                    Proj::Down => m.hidden_size * m.dtype_bytes,
                };
                let pad = t.padded_shard_bytes - t.shard_bytes;
                assert_eq!(t.zero_vectors * vec_bytes + t.tail_bytes, pad, "{}", m.name);
                assert!(t.tail_bytes < vec_bytes);
            }
        }
    }

    #[test]
    fn scale_up_releases_expected_pages() {
        let m = ModelConfig::qwen2_5_32b();
        let plan = LayerPadPlan::plan(&m, 4);
        let released = plan.pages_released_per_worker(1, 4);
        // Worker drops 3/4 of its padded MLP layer.
        let expect = (plan.padded_bytes() - plan.padded_bytes() / 4) / VMM_PAGE;
        assert_eq!(released, expect);
        assert!(released > 0);
    }

    #[test]
    fn scale_down_receives_what_scale_up_released() {
        let m = ModelConfig::llama3_8b();
        let plan = LayerPadPlan::plan(&m, 4);
        let released_bytes = plan.pages_released_per_worker(1, 4) * VMM_PAGE;
        let received = plan.bytes_received_per_worker(4, 1);
        assert_eq!(released_bytes, received);
    }
}
