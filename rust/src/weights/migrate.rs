//! Model-weight transformation strategies (§4.2, Figure 10a).
//!
//! * **Partial swap** (basic): unaligned shard boundaries force the worker
//!   to allocate an aligned staging region and copy the shard (Figure 6b),
//!   page-at-a-time through the driver, plus a TP-group reconfiguration
//!   per layer.
//! * **Gyges⁻** (padding, no overlap): shards are pre-padded to page
//!   boundaries (Figure 6c) — scale-up releases whole pages (driver call
//!   only); scale-down re-maps pages and pulls shards over NVLink.
//! * **Gyges**: Gyges⁻ with the reconfiguration and the scale-down
//!   all-to-all overlapped onto an independent stream.
//!
//! Each report distinguishes **wall** time (what Figure 10a plots for a
//! single layer's transformation) from **step-visible** time (what
//! inference steps actually absorb — Figure 11's currency). Fixed costs
//! (group reconfiguration) are paid once per transformation; marginal
//! costs accrue per layer.

use super::padding::LayerPadPlan;
use crate::config::{GpuSpec, ModelConfig};
use crate::sim::clock::SimDuration;
use crate::sim::comm::CommModel;
use crate::sim::vmm::VmmCosts;
use crate::util::bytes::VMM_PAGE;

/// Strategy under comparison (Figure 10a series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightStrategy {
    PartialSwap,
    GygesNoOverlap,
    Gyges,
}

impl WeightStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            WeightStrategy::PartialSwap => "partial-swap",
            WeightStrategy::GygesNoOverlap => "gyges-",
            WeightStrategy::Gyges => "gyges",
        }
    }
}

/// Calibration constants (DESIGN.md §5). Fit so that (a) Partial Swap's
/// single-layer wall time spans the paper's 611–696 ms across its four
/// models, (b) Gyges⁻'s saving lands in the published 18.9–42.2% band,
/// and (c) Gyges' total saving peaks at the published 67.6%.
mod cal {
    /// NCCL communicator / TP-group rebuild — needed by every strategy.
    pub const COMM_REBUILD_MS: f64 = 450.0;
    /// Staging-region allocation + bookkeeping (partial swap only).
    pub const ALLOC_MS: f64 = 122.6;
    /// Per-2MiB-page driver-mediated copy (unmap→copy→map), partial swap.
    pub const SWAP_PER_PAGE_MS: f64 = 1.164;
    /// Fraction of the rebuild hidden by Gyges' overlapping.
    pub const OVERLAP_HIDDEN: f64 = 0.5;
    /// Residual per-step sync fraction that stays visible under overlap.
    pub const VISIBLE_RESIDUAL: f64 = 0.05;
}

/// Report of one weight transformation.
#[derive(Clone, Debug)]
pub struct WeightMigrationReport {
    pub strategy: WeightStrategy,
    /// One-time wall cost (group reconfiguration, staging alloc).
    pub fixed_wall: SimDuration,
    /// Additional wall cost per layer.
    pub marginal_wall: SimDuration,
    /// One-time serving-visible cost.
    pub fixed_visible: SimDuration,
    /// Serving-visible cost per layer.
    pub marginal_visible: SimDuration,
    /// Bytes copied on-device per layer (zero with padding).
    pub copied_bytes: u64,
    /// Pages released (scale-up) or mapped (scale-down) per worker/layer.
    pub pages_touched: u64,
    /// Peak extra memory per worker during one layer's transformation.
    pub peak_extra_bytes: u64,
}

impl WeightMigrationReport {
    /// Figure 10a's quantity: wall time of transforming a single layer.
    pub fn per_layer_time(&self) -> SimDuration {
        self.fixed_wall + self.marginal_wall
    }

    /// Wall time of transforming `layers` layers (fixed cost amortized).
    pub fn total_wall(&self, layers: u64) -> SimDuration {
        self.fixed_wall + SimDuration(self.marginal_wall.0 * layers)
    }

    /// Step-visible time of transforming `layers` layers.
    pub fn total_visible(&self, layers: u64) -> SimDuration {
        self.fixed_visible + SimDuration(self.marginal_visible.0 * layers)
    }
}

/// Parameters of a weight transformation.
#[derive(Clone, Debug)]
pub struct WeightMigrationSpec {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    pub from_tp: u64,
    pub to_tp: u64,
}

impl WeightMigrationSpec {
    pub fn paper_default(model: ModelConfig) -> WeightMigrationSpec {
        let gpu = GpuSpec::for_model(&model);
        WeightMigrationSpec { model, gpu, from_tp: 1, to_tp: 4 }
    }

    pub fn is_scale_up(&self) -> bool {
        self.to_tp > self.from_tp
    }
}

/// Simulate one weight transformation.
pub fn run_weight_migration(
    spec: &WeightMigrationSpec,
    strategy: WeightStrategy,
) -> WeightMigrationReport {
    let vmm = VmmCosts::default();
    let comm = CommModel::for_gpu(&spec.gpu);
    let max_tp = spec.from_tp.max(spec.to_tp);
    let plan = LayerPadPlan::plan(&spec.model, max_tp);
    let rebuild = SimDuration::from_millis_f64(cal::COMM_REBUILD_MS);

    match strategy {
        WeightStrategy::PartialSwap => {
            // Without padding, the retained shard (scale-up) or received
            // shards (scale-down) are unaligned: stage-copy page by page.
            let shard_bytes = if spec.is_scale_up() {
                spec.model.mlp_layer_bytes() / spec.to_tp
            } else {
                plan.bytes_received_per_worker(spec.from_tp, spec.to_tp)
            };
            let pages = shard_bytes.div_ceil(VMM_PAGE);
            let copy = SimDuration::from_millis_f64(cal::SWAP_PER_PAGE_MS * pages as f64);
            let a2a_marginal = if spec.is_scale_up() {
                SimDuration::ZERO
            } else {
                comm.all_to_all(spec.from_tp as u32, shard_bytes, spec.gpu.sm_count)
            };
            let fixed = rebuild + SimDuration::from_millis_f64(cal::ALLOC_MS);
            let marginal = copy + a2a_marginal;
            WeightMigrationReport {
                strategy,
                fixed_wall: fixed,
                marginal_wall: marginal,
                fixed_visible: fixed,
                marginal_visible: marginal,
                copied_bytes: shard_bytes,
                pages_touched: pages,
                peak_extra_bytes: shard_bytes,
            }
        }
        WeightStrategy::GygesNoOverlap | WeightStrategy::Gyges => {
            let (pages, a2a, extra) = if spec.is_scale_up() {
                // Pure page release: one batched driver call per layer.
                let p = plan.pages_released_per_worker(spec.from_tp, spec.to_tp);
                (p, SimDuration::ZERO, 0u64)
            } else {
                // Scale-down: map fresh pages and pull shards over NVLink.
                let bytes = plan.bytes_received_per_worker(spec.from_tp, spec.to_tp);
                let p = bytes / VMM_PAGE;
                let t = comm.all_to_all(spec.from_tp as u32, bytes, spec.gpu.sm_count);
                (p, t, bytes)
            };
            let driver = vmm.op_time(pages);
            if strategy == WeightStrategy::GygesNoOverlap {
                WeightMigrationReport {
                    strategy,
                    fixed_wall: rebuild,
                    marginal_wall: driver + a2a,
                    fixed_visible: rebuild,
                    marginal_visible: driver + a2a,
                    copied_bytes: 0,
                    pages_touched: pages,
                    peak_extra_bytes: extra,
                }
            } else {
                // Overlap: rebuild and all-to-all ride the independent
                // stream; driver calls run on the CPU concurrently with
                // GPU kernels. Visible residue is a small sync slice.
                WeightMigrationReport {
                    strategy,
                    fixed_wall: rebuild.scale(1.0 - cal::OVERLAP_HIDDEN),
                    marginal_wall: driver + a2a.scale(1.0 - cal::OVERLAP_HIDDEN),
                    fixed_visible: rebuild.scale(cal::VISIBLE_RESIDUAL),
                    marginal_visible: driver.scale(cal::VISIBLE_RESIDUAL)
                        + a2a.scale(cal::VISIBLE_RESIDUAL),
                    copied_bytes: 0,
                    pages_touched: pages,
                    peak_extra_bytes: extra,
                }
            }
        }
    }
}

/// All three strategies for one model (Figure 10a row).
pub fn fig10_series(model: ModelConfig) -> Vec<WeightMigrationReport> {
    let spec = WeightMigrationSpec::paper_default(model);
    [
        WeightStrategy::PartialSwap,
        WeightStrategy::GygesNoOverlap,
        WeightStrategy::Gyges,
    ]
    .into_iter()
    .map(|s| run_weight_migration(&spec, s))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_swap_in_paper_band() {
        // §6.2.2: 611–696 ms per layer across the four eval models.
        for m in ModelConfig::eval_set() {
            let spec = WeightMigrationSpec::paper_default(m.clone());
            let r = run_weight_migration(&spec, WeightStrategy::PartialSwap);
            let ms = r.per_layer_time().as_millis_f64();
            assert!(
                (595.0..720.0).contains(&ms),
                "{}: partial swap {ms} ms",
                m.name
            );
        }
    }

    #[test]
    fn gyges_minus_saving_in_band() {
        // §6.2.2: padding cuts per-layer cost by 18.9%–42.2%.
        for m in ModelConfig::eval_set() {
            let spec = WeightMigrationSpec::paper_default(m.clone());
            let swap = run_weight_migration(&spec, WeightStrategy::PartialSwap);
            let minus = run_weight_migration(&spec, WeightStrategy::GygesNoOverlap);
            let saving = 1.0
                - minus.per_layer_time().as_secs_f64() / swap.per_layer_time().as_secs_f64();
            assert!(
                (0.15..0.45).contains(&saving),
                "{}: saving {saving}",
                m.name
            );
            assert_eq!(minus.copied_bytes, 0, "padding must eliminate copies");
        }
    }

    #[test]
    fn gyges_overlap_total_saving_up_to_67pct() {
        // §6.2.2: with overlapping, up to 67.6% cheaper than Partial Swap.
        let mut best = 0.0f64;
        for m in ModelConfig::eval_set() {
            let spec = WeightMigrationSpec::paper_default(m.clone());
            let swap = run_weight_migration(&spec, WeightStrategy::PartialSwap);
            let full = run_weight_migration(&spec, WeightStrategy::Gyges);
            let saving =
                1.0 - full.per_layer_time().as_secs_f64() / swap.per_layer_time().as_secs_f64();
            best = best.max(saving);
        }
        assert!((0.60..0.72).contains(&best), "best saving {best}");
    }

    #[test]
    fn gyges_visible_cost_is_tiny() {
        // Figure 11's premise: with overlap the per-layer visible cost is
        // orders of magnitude below the wall cost.
        let spec = WeightMigrationSpec::paper_default(ModelConfig::qwen2_5_32b());
        let full = run_weight_migration(&spec, WeightStrategy::Gyges);
        assert!(full.marginal_visible.as_millis_f64() < 1.0);
        assert!(full.fixed_visible < full.fixed_wall);
    }

    #[test]
    fn scale_up_is_release_only() {
        let spec = WeightMigrationSpec::paper_default(ModelConfig::llama3_8b());
        let r = run_weight_migration(&spec, WeightStrategy::GygesNoOverlap);
        assert_eq!(r.copied_bytes, 0);
        assert_eq!(r.peak_extra_bytes, 0);
        assert!(r.pages_touched > 0);
    }

    #[test]
    fn scale_down_moves_weights_back() {
        let mut spec = WeightMigrationSpec::paper_default(ModelConfig::llama3_8b());
        spec.from_tp = 4;
        spec.to_tp = 1;
        let r = run_weight_migration(&spec, WeightStrategy::GygesNoOverlap);
        assert!(r.peak_extra_bytes > 0);
        assert!(r.pages_touched > 0);
        let up = run_weight_migration(
            &WeightMigrationSpec::paper_default(ModelConfig::llama3_8b()),
            WeightStrategy::GygesNoOverlap,
        );
        assert!(r.marginal_wall > up.marginal_wall, "scale-down moves bytes");
    }

    #[test]
    fn series_complete_and_ordered() {
        let s = fig10_series(ModelConfig::qwen3_32b());
        assert_eq!(s.len(), 3);
        assert!(s[2].per_layer_time() < s[1].per_layer_time());
        assert!(s[1].per_layer_time() < s[0].per_layer_time());
    }

    #[test]
    fn total_wall_amortizes_fixed_cost() {
        let spec = WeightMigrationSpec::paper_default(ModelConfig::qwen2_5_32b());
        let r = run_weight_migration(&spec, WeightStrategy::PartialSwap);
        let layers = spec.model.num_layers;
        let total = r.total_wall(layers).as_secs_f64();
        let naive = r.per_layer_time().as_secs_f64() * layers as f64;
        assert!(total < naive, "fixed cost must amortize: {total} vs {naive}");
    }
}
