//! Model-weight subsystem (paper §4.2): TP shard shapes, Table-3 page
//! math, parallelism-aware padding, migration strategies, and the
//! padded-FFN correctness reference.

pub mod ffn;
pub mod migrate;
pub mod moe;
pub mod padding;
pub mod pages;
pub mod shapes;

pub use migrate::{
    fig10_series, run_weight_migration, WeightMigrationReport, WeightMigrationSpec,
    WeightStrategy,
};
pub use padding::{LayerPadPlan, TensorPadPlan};
pub use moe::{plan_ep_rebalance, EpRebalanceReport, MoePlacement};
pub use pages::{page_counts, stranded_fraction, PageCounts};
pub use shapes::{mlp_shard_bytes, mlp_shards, shard_ranges, Proj, TensorShard};
