//! Weight sharding + §4.2 padding on the Rust side — the serving twin of
//! python/compile/model.py's `shard_attn_weights` / `shard_mlp_weights`.
//!
//! The runtime holds the UNpadded full weights (as loaded from
//! artifacts/weights) and materializes per-rank shards for whatever TP
//! degree an instance currently runs — this is exactly the "transformation"
//! act: scale-up drops shard columns (page release), scale-down
//! re-materializes them. Padding inserts zero columns/rows to the
//! `block_inner` boundary so the padded-FFN artifacts accept the shards.

use super::artifact::Manifest;

/// One layer's full (unpadded, unsharded) weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wqkv: Vec<f32>, // [hidden, 3*heads*head_dim]
    pub wo: Vec<f32>,   // [heads*head_dim, hidden]
    pub up: Vec<f32>,   // [hidden, inner]
    pub down: Vec<f32>, // [inner, hidden]
    pub ln1: Vec<f32>,  // [hidden]
    pub ln2: Vec<f32>,  // [hidden]
}

impl LayerWeights {
    pub fn load(man: &Manifest, layer: usize) -> anyhow::Result<LayerWeights> {
        Ok(LayerWeights {
            wqkv: man.load_weight(&format!("l{layer}.wqkv"))?,
            wo: man.load_weight(&format!("l{layer}.wo"))?,
            up: man.load_weight(&format!("l{layer}.up"))?,
            down: man.load_weight(&format!("l{layer}.down"))?,
            ln1: man.load_weight(&format!("l{layer}.ln1"))?,
            ln2: man.load_weight(&format!("l{layer}.ln2"))?,
        })
    }
}

/// Attention shard of worker `rank` at degree `tp`:
/// (wqkv_shard [hidden, 3*h_shard*hd], wo_shard [h_shard*hd, hidden]).
pub fn shard_attn(
    man: &Manifest,
    w: &LayerWeights,
    tp: usize,
    rank: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (hidden, heads, hd) = (man.hidden, man.heads, man.head_dim);
    assert!(heads % tp == 0 && rank < tp);
    let hs = heads / tp;
    // wqkv logical shape [hidden, 3, heads, hd] row-major.
    let mut wqkv_s = Vec::with_capacity(hidden * 3 * hs * hd);
    for row in 0..hidden {
        for t in 0..3 {
            for h in rank * hs..(rank + 1) * hs {
                let base = ((row * 3 + t) * heads + h) * hd;
                wqkv_s.extend_from_slice(&w.wqkv[base..base + hd]);
            }
        }
    }
    // wo logical shape [heads, hd, hidden]: take this rank's head rows.
    let rows = hs * hd;
    let start = rank * rows * hidden;
    let wo_s = w.wo[start..start + rows * hidden].to_vec();
    (wqkv_s, wo_s)
}

/// Padded MLP shard of worker `rank` at degree `tp`:
/// (up_p [hidden, ps], down_p [ps, hidden]) with ps = padded_shard_inner.
pub fn shard_mlp(
    man: &Manifest,
    w: &LayerWeights,
    tp: usize,
    rank: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (hidden, inner) = (man.hidden, man.inner);
    assert!(inner % tp == 0 && rank < tp);
    let shard = inner / tp;
    let ps = man.padded_shard_inner[&tp];
    let pad = ps - shard;
    // up [hidden, inner] → columns [rank*shard, (rank+1)*shard) + zero pad.
    let mut up_p = Vec::with_capacity(hidden * ps);
    for row in 0..hidden {
        let base = row * inner + rank * shard;
        up_p.extend_from_slice(&w.up[base..base + shard]);
        up_p.extend(std::iter::repeat(0.0).take(pad));
    }
    // down [inner, hidden] → rows, then zero rows.
    let mut down_p = Vec::with_capacity(ps * hidden);
    let start = rank * shard * hidden;
    down_p.extend_from_slice(&w.down[start..start + shard * hidden]);
    down_p.extend(std::iter::repeat(0.0).take(pad * hidden));
    (up_p, down_p)
}

/// Bytes of padding a rank's MLP shard carries (the §4.2 overhead).
pub fn mlp_pad_bytes(man: &Manifest, tp: usize) -> usize {
    let shard = man.inner / tp;
    let ps = man.padded_shard_inner[&tp];
    (ps - shard) * man.hidden * 4 * 2 // zero cols in up + zero rows in down
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then(|| Manifest::load(&d).unwrap())
    }

    #[test]
    fn attn_shards_partition_wqkv() {
        let Some(man) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let w = LayerWeights::load(&man, 0).unwrap();
        for tp in [1usize, 2, 4] {
            // Reassemble the full wqkv from shards and compare.
            let hs = man.heads / tp;
            let shards: Vec<Vec<f32>> =
                (0..tp).map(|r| shard_attn(&man, &w, tp, r).0).collect();
            let mut rebuilt = vec![0.0f32; w.wqkv.len()];
            for (r, s) in shards.iter().enumerate() {
                for row in 0..man.hidden {
                    for t in 0..3 {
                        for h in 0..hs {
                            let src = ((row * 3 + t) * hs + h) * man.head_dim;
                            let dst =
                                ((row * 3 + t) * man.heads + r * hs + h) * man.head_dim;
                            rebuilt[dst..dst + man.head_dim]
                                .copy_from_slice(&s[src..src + man.head_dim]);
                        }
                    }
                }
            }
            assert_eq!(rebuilt, w.wqkv, "tp={tp}");
        }
    }

    #[test]
    fn mlp_shards_are_padded_with_zeros() {
        let Some(man) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let w = LayerWeights::load(&man, 1).unwrap();
        for tp in [1usize, 2, 4] {
            let shard = man.inner / tp;
            let ps = man.padded_shard_inner[&tp];
            let (up_p, down_p) = shard_mlp(&man, &w, tp, 0);
            assert_eq!(up_p.len(), man.hidden * ps);
            assert_eq!(down_p.len(), ps * man.hidden);
            // pad columns are zero
            for row in 0..man.hidden {
                for c in shard..ps {
                    assert_eq!(up_p[row * ps + c], 0.0);
                }
            }
            for r in shard..ps {
                for c in 0..man.hidden {
                    assert_eq!(down_p[r * man.hidden + c], 0.0);
                }
            }
            // real region matches the source
            assert_eq!(up_p[0..shard], w.up[0..shard]);
        }
    }

    #[test]
    fn tp1_shard_covers_everything() {
        let Some(man) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let w = LayerWeights::load(&man, 0).unwrap();
        let (wqkv_s, wo_s) = shard_attn(&man, &w, 1, 0);
        assert_eq!(wqkv_s, w.wqkv);
        assert_eq!(wo_s, w.wo);
        assert!(mlp_pad_bytes(&man, 4) > 0, "inner=960 must pad at tp4");
    }
}
