//! PJRT runtime: loads the HLO-text artifacts `make artifacts` produced
//! and executes them on the request path (Python never runs here).
//!
//! - [`artifact`] — manifest/weights/oracle loading
//! - [`client`] — PJRT CPU client + module compilation
//! - [`shard`] — TP weight sharding + §4.2 padding (Rust twin of model.py)
//! - [`executor`] — the per-layer TP serving loop with Rust as the
//!   all-reduce fabric, plus LIVE KV/weight transformation

pub mod artifact;
pub mod client;
pub mod executor;
pub mod shard;

pub use artifact::{Manifest, Oracle, WeightMeta};
pub use client::{literal_f32, literal_i32, to_f32, Engine};
pub use executor::{argmax, Session, TinyRuntime};
pub use shard::{mlp_pad_bytes, shard_attn, shard_mlp, LayerWeights};
