//! The gyges-tiny serving runtime: executes the AOT-compiled per-module
//! HLO artifacts with the Rust coordinator acting as the TP reduction
//! fabric, and performs LIVE parallelism transformation of the weight
//! shards and per-head KV caches — the paper's mechanism on a real model.
//!
//! Per decode step and per layer:
//!     o_partial[r]  = attn_tp{tp}(hidden, pos, kv[r], shard_r)   ∀ ranks
//!     h2            = hidden + Σ_r o_partial[r]          (rust all-reduce)
//!     mlp_partial[r]= mlp_tp{tp}(h2, padded shard_r)             ∀ ranks
//!     hidden        = h2 + Σ_r mlp_partial[r]            (rust all-reduce)
//!
//! §Perf: weights and KV caches live as DEVICE buffers (`execute_b`);
//! only [1, hidden] activations and scalars cross the host boundary each
//! step. Weight shards are built once per TP degree and shared across
//! sessions via `Rc`. (Before this pass every step deep-cloned ~13 MB of
//! literals; see EXPERIMENTS.md §Perf for the measured delta.)

use super::artifact::{Manifest, Oracle};
use super::client::{to_f32, Engine};
use super::shard::{shard_attn, shard_mlp, LayerWeights};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::rc::Rc;

/// One rank's immutable weight-shard buffers for one layer.
struct RankWeights {
    wqkv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    up_p: xla::PjRtBuffer,
    down_p: xla::PjRtBuffer,
}

/// Per-rank, per-layer session state.
struct RankLayer {
    weights: Rc<RankWeights>,
    /// KV cache buffer [blocks, h_shard, 2, tpb, hd], device-resident and
    /// fed back into the next step's execute_b.
    kv: xla::PjRtBuffer,
}

/// A serving session for one sequence (its KV caches live here).
pub struct Session {
    /// ranks × layers
    state: Vec<Vec<RankLayer>>,
    pub pos: usize,
    pub tokens: Vec<u32>,
}

/// The tiny-model runtime at a given TP degree.
pub struct TinyRuntime {
    pub man: Manifest,
    pub tp: usize,
    engine: Engine,
    layers: Vec<LayerWeights>,
    emb_buf: xla::PjRtBuffer,
    ln1: Vec<xla::PjRtBuffer>,
    ln2: Vec<xla::PjRtBuffer>,
    /// Weight-shard buffers per TP degree: [rank][layer], built lazily
    /// once and shared by every session (weights are immutable).
    shard_cache: BTreeMap<usize, Vec<Vec<Rc<RankWeights>>>>,
    /// Bytes moved by the last transformation (reporting).
    pub last_transform_bytes: usize,
}

impl TinyRuntime {
    /// Load artifacts and compile every module.
    pub fn load(artifacts: impl AsRef<std::path::Path>, tp: usize) -> Result<TinyRuntime> {
        let man = Manifest::load(artifacts)?;
        ensure!(man.tp_choices.contains(&tp), "tp {tp} not exported");
        let mut engine = Engine::cpu()?;
        engine.load_module("embed", man.module_path("embed")?)?;
        engine.load_module("lm_head", man.module_path("lm_head")?)?;
        for &t in &man.tp_choices {
            for kind in ["qkv", "kvupd", "attnout", "mlp"] {
                let name = format!("{kind}_tp{t}");
                engine.load_module(&name, man.module_path(&name)?)?;
            }
        }
        let layers: Vec<LayerWeights> = (0..man.layers)
            .map(|l| LayerWeights::load(&man, l))
            .collect::<Result<_>>()?;
        let emb = man.load_weight("emb")?;
        let emb_buf = engine.buffer_f32(&emb, &[man.vocab, man.hidden])?;
        let ln1 = layers
            .iter()
            .map(|w| engine.buffer_f32(&w.ln1, &[man.hidden]))
            .collect::<Result<_>>()?;
        let ln2 = layers
            .iter()
            .map(|w| engine.buffer_f32(&w.ln2, &[man.hidden]))
            .collect::<Result<_>>()?;
        Ok(TinyRuntime {
            man,
            tp,
            engine,
            layers,
            emb_buf,
            ln1,
            ln2,
            shard_cache: BTreeMap::new(),
            last_transform_bytes: 0,
        })
    }

    fn kv_dims(&self, tp: usize) -> [usize; 5] {
        [
            self.man.blocks,
            self.man.heads / tp,
            2,
            self.man.tokens_per_block,
            self.man.head_dim,
        ]
    }

    /// Build (or fetch) the shared weight-shard buffers for `tp`.
    fn shards_for(&mut self, tp: usize) -> Result<&Vec<Vec<Rc<RankWeights>>>> {
        if !self.shard_cache.contains_key(&tp) {
            let hs = self.man.heads / tp;
            let ps = self.man.padded_shard_inner[&tp];
            let mut ranks = Vec::with_capacity(tp);
            for rank in 0..tp {
                let mut per_layer = Vec::with_capacity(self.man.layers);
                for l in 0..self.man.layers {
                    let (wqkv, wo) = shard_attn(&self.man, &self.layers[l], tp, rank);
                    let (up_p, down_p) = shard_mlp(&self.man, &self.layers[l], tp, rank);
                    per_layer.push(Rc::new(RankWeights {
                        wqkv: self
                            .engine
                            .buffer_f32(&wqkv, &[self.man.hidden, 3 * hs * self.man.head_dim])?,
                        wo: self
                            .engine
                            .buffer_f32(&wo, &[hs * self.man.head_dim, self.man.hidden])?,
                        up_p: self.engine.buffer_f32(&up_p, &[self.man.hidden, ps])?,
                        down_p: self.engine.buffer_f32(&down_p, &[ps, self.man.hidden])?,
                    }));
                }
                ranks.push(per_layer);
            }
            self.shard_cache.insert(tp, ranks);
        }
        Ok(&self.shard_cache[&tp])
    }

    /// Start a fresh session (empty KV caches; weight shards shared).
    pub fn new_session(&mut self) -> Result<Session> {
        let tp = self.tp;
        let kv_dims = self.kv_dims(tp);
        let kv_len: usize = kv_dims.iter().product();
        let zeros = vec![0.0f32; kv_len];
        // Clone the shard Rc matrix up front (cheap) to end the borrow.
        let shards: Vec<Vec<Rc<RankWeights>>> = self.shards_for(tp)?.clone();
        let mut state = Vec::with_capacity(tp);
        for per_layer in shards {
            let mut layers = Vec::with_capacity(self.man.layers);
            for weights in per_layer {
                layers.push(RankLayer {
                    weights,
                    kv: self.engine.buffer_f32(&zeros, &kv_dims)?,
                });
            }
            state.push(layers);
        }
        Ok(Session { state, pos: 0, tokens: Vec::new() })
    }

    /// Feed one token; returns the logits. (Prefill = feeding the prompt
    /// token by token; decode = feeding the last generated token.)
    pub fn step(&mut self, sess: &mut Session, token: u32) -> Result<Vec<f32>> {
        ensure!(sess.pos < self.man.s_max, "sequence exceeds S_MAX");
        ensure!(sess.state.len() == self.tp, "session built for a different TP degree");
        let tp = self.tp;
        // embed (device)
        let tok_buf = self.engine.buffer_i32(token as i32)?;
        let hidden_buf = self
            .engine
            .run_b("embed", &[&tok_buf, &self.emb_buf])?
            .pop()
            .unwrap();
        let mut hidden = to_f32(&hidden_buf.to_literal_sync()?)?;
        let pos_buf = self.engine.buffer_i32(sess.pos as i32)?;
        let qkv_mod = format!("qkv_tp{tp}");
        let kvupd_mod = format!("kvupd_tp{tp}");
        let attnout_mod = format!("attnout_tp{tp}");
        let mlp_mod = format!("mlp_tp{tp}");

        for l in 0..self.man.layers {
            // ---- attention (all ranks) + rust all-reduce ----
            // Three single-output device-side executes per rank: qkv
            // projection, KV-cache update (stays on device), attention +
            // output projection. Only the [1,hidden] partial returns.
            let hidden_dev = self.engine.buffer_f32(&hidden, &[1, self.man.hidden])?;
            let mut o_sum = vec![0.0f32; self.man.hidden];
            for rank in 0..tp {
                let rl = &mut sess.state[rank][l];
                let qkv = self
                    .engine
                    .run_b(&qkv_mod, &[&hidden_dev, &rl.weights.wqkv, &self.ln1[l]])?
                    .pop()
                    .unwrap();
                rl.kv = self
                    .engine
                    .run_b(&kvupd_mod, &[&rl.kv, &qkv, &pos_buf])?
                    .pop()
                    .unwrap();
                let outs = self.engine.run_b(
                    &attnout_mod,
                    &[&qkv, &rl.kv, &pos_buf, &rl.weights.wo],
                )?;
                let part = to_f32(&outs[0].to_literal_sync()?)?;
                for (a, b) in o_sum.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            let h2: Vec<f32> = hidden.iter().zip(&o_sum).map(|(a, b)| a + b).collect();

            // ---- MLP (all ranks) + rust all-reduce ----
            let h2_dev = self.engine.buffer_f32(&h2, &[1, self.man.hidden])?;
            let mut m_sum = vec![0.0f32; self.man.hidden];
            for rank in 0..tp {
                let rl = &sess.state[rank][l];
                let outs = self.engine.run_b(
                    &mlp_mod,
                    &[&h2_dev, &rl.weights.up_p, &rl.weights.down_p, &self.ln2[l]],
                )?;
                let part = to_f32(&outs[0].to_literal_sync()?)?;
                for (a, b) in m_sum.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            hidden = h2.iter().zip(&m_sum).map(|(a, b)| a + b).collect();
        }

        let hidden_dev = self.engine.buffer_f32(&hidden, &[1, self.man.hidden])?;
        let out = self.engine.run_b("lm_head", &[&hidden_dev, &self.emb_buf])?;
        let logits = to_f32(&out[0].to_literal_sync()?)?;
        sess.pos += 1;
        sess.tokens.push(token);
        Ok(logits)
    }

    /// Greedy-generate `n` tokens after feeding `prompt`.
    pub fn generate(&mut self, sess: &mut Session, prompt: &[u32], n: usize) -> Result<Vec<u32>> {
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(sess, t)?;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = argmax(&logits) as u32;
            out.push(next);
            logits = self.step(sess, next)?;
        }
        Ok(out)
    }

    /// LIVE parallelism transformation: re-shard every session KV cache
    /// and switch the weight-shard set from the current degree to `to_tp`.
    /// The header-centric layout makes each (block, head) span contiguous,
    /// so KV moves as whole per-head spans (§4.1.2); weights need no copy
    /// at all — the padded shard buffers per degree are immutable, and
    /// scale-up simply stops referencing 3/4 of them (the runtime twin of
    /// "release the pages").
    pub fn transform(&mut self, sess: &mut Session, to_tp: usize) -> Result<()> {
        ensure!(self.man.tp_choices.contains(&to_tp), "tp {to_tp} not exported");
        let from_tp = self.tp;
        if from_tp == to_tp {
            return Ok(());
        }
        let man = self.man.clone();
        let (blocks, heads, tpb, hd) =
            (man.blocks, man.heads, man.tokens_per_block, man.head_dim);
        let hs_old = heads / from_tp;
        let hs_new = heads / to_tp;
        let head_span = 2 * tpb * hd;
        let kv_dims_new = self.kv_dims(to_tp);
        let mut moved = 0usize;

        // Make sure the target shard buffers exist (shared, no copies).
        let shards: Vec<Vec<Rc<RankWeights>>> = self.shards_for(to_tp)?.clone();

        let mut new_state: Vec<Vec<RankLayer>> = (0..to_tp)
            .map(|_| Vec::with_capacity(man.layers))
            .collect();
        for l in 0..man.layers {
            // 1) Gather full-head KV from the old shards.
            let mut full = vec![0.0f32; blocks * heads * head_span];
            for (rank, per_layer) in sess.state.iter().enumerate().take(from_tp) {
                let kv = to_f32(&per_layer[l].kv.to_literal_sync()?)?;
                for b in 0..blocks {
                    for h in 0..hs_old {
                        let src = (b * hs_old + h) * head_span;
                        let dst = (b * heads + rank * hs_old + h) * head_span;
                        full[dst..dst + head_span].copy_from_slice(&kv[src..src + head_span]);
                        moved += head_span * 4;
                    }
                }
            }
            // 2) Scatter into the new shard layout (contiguous spans).
            for (rank, state) in new_state.iter_mut().enumerate() {
                let mut shard = vec![0.0f32; blocks * hs_new * head_span];
                for b in 0..blocks {
                    for h in 0..hs_new {
                        let src = (b * heads + rank * hs_new + h) * head_span;
                        let dst = (b * hs_new + h) * head_span;
                        shard[dst..dst + head_span].copy_from_slice(&full[src..src + head_span]);
                    }
                }
                state.push(RankLayer {
                    weights: shards[rank][l].clone(),
                    kv: self.engine.buffer_f32(&shard, &kv_dims_new)?,
                });
            }
        }
        sess.state = new_state;
        self.tp = to_tp;
        self.last_transform_bytes = moved;
        Ok(())
    }

    /// Verify the artifacts reproduce the Python oracle exactly.
    pub fn verify_oracle(&mut self) -> Result<()> {
        let oracle = Oracle::load(&self.man.dir)?;
        let mut sess = self.new_session()?;
        let got = self.generate(&mut sess, &oracle.prompt, oracle.generated.len())?;
        ensure!(
            got == oracle.generated,
            "oracle mismatch: got {got:?}, want {:?}",
            oracle.generated
        );
        Ok(())
    }
}

/// Index of the maximum element.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn oracle_reproduced_at_tp1() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = TinyRuntime::load(&dir, 1).unwrap();
        rt.verify_oracle().unwrap();
    }

    #[test]
    fn all_tp_degrees_agree() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let prompt = [3u32, 17, 200, 41];
        let mut reference = None;
        for tp in [1usize, 2, 4] {
            let mut rt = TinyRuntime::load(&dir, tp).unwrap();
            let mut sess = rt.new_session().unwrap();
            let got = rt.generate(&mut sess, &prompt, 6).unwrap();
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(&got, want, "tp{tp} diverged"),
            }
        }
    }

    #[test]
    fn live_transformation_preserves_generation() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let prompt = [5u32, 9, 100, 7, 63];
        // Uninterrupted TP1 run.
        let mut rt_ref = TinyRuntime::load(&dir, 1).unwrap();
        let mut s_ref = rt_ref.new_session().unwrap();
        let want = rt_ref.generate(&mut s_ref, &prompt, 6).unwrap();

        // TP1 → prefill → TRANSFORM to TP4 mid-stream → continue decode.
        let mut rt = TinyRuntime::load(&dir, 1).unwrap();
        let mut sess = rt.new_session().unwrap();
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = rt.step(&mut sess, t).unwrap();
        }
        rt.transform(&mut sess, 4).unwrap();
        assert!(rt.last_transform_bytes > 0);
        let mut got = Vec::new();
        for _ in 0..6 {
            let next = argmax(&logits) as u32;
            got.push(next);
            logits = rt.step(&mut sess, next).unwrap();
        }
        assert_eq!(got, want, "transformation must not change results");

        // And back down to TP1 (scale-down path).
        rt.transform(&mut sess, 1).unwrap();
        let next = argmax(&logits) as u32;
        let _ = rt.step(&mut sess, next).unwrap();
    }

    #[test]
    fn shard_cache_is_shared_across_sessions() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = TinyRuntime::load(&dir, 2).unwrap();
        let _a = rt.new_session().unwrap();
        let _b = rt.new_session().unwrap();
        assert_eq!(rt.shard_cache.len(), 1);
        assert_eq!(rt.shard_cache[&2].len(), 2); // ranks
    }
}
