//! PJRT client wrapper: load HLO-text artifacts, compile once, execute
//! from the serving hot path. Python never runs here.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled model-module catalogue on one PJRT client.
pub struct Engine {
    pub client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// CPU PJRT client (the only backend in this environment).
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, executables: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file under `name` (idempotent).
    pub fn load_module(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded module on literal inputs, returning one literal
    /// per output. Handles both tupled (`return_tuple=True`) and untupled
    /// module exports.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("module {name:?} not loaded"))?;
        let outs = &exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?[0];
        let mut lits = Vec::with_capacity(outs.len());
        for b in outs {
            lits.push(b.to_literal_sync()?);
        }
        if lits.len() == 1 && matches!(lits[0].shape(), Ok(xla::Shape::Tuple(_))) {
            return Ok(lits.pop().unwrap().to_tuple()?);
        }
        Ok(lits)
    }

    /// HOT PATH (§Perf): execute on device-resident buffers, returning the
    /// raw output buffers without any host round-trip. Weights and KV
    /// caches stay on the device between steps; only activations cross.
    pub fn run_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("module {name:?} not loaded"))?;
        let mut outs = exe
            .execute_b(inputs)
            .with_context(|| format!("executing {name}"))?;
        Ok(outs.swap_remove(0))
    }

    /// Upload an f32 tensor to the device once (weights, KV init).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a scalar i32 (token ids, positions).
    pub fn buffer_i32(&self, x: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[x], &[], None)?)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}

/// Pack an f32 slice into a Literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar i32 literal.
pub fn literal_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Unpack a Literal to Vec<f32>.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(literal_f32(&[1.0], &[2, 2]).is_err());
    }

    #[test]
    fn engine_compiles_and_runs_lm_head() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let man = crate::runtime::artifact::Manifest::load(&dir).unwrap();
        let mut eng = Engine::cpu().unwrap();
        eng.load_module("lm_head", man.module_path("lm_head").unwrap()).unwrap();
        let hidden = vec![0.01f32; man.hidden];
        let emb = man.load_weight("emb").unwrap();
        let out = eng
            .run(
                "lm_head",
                &[
                    literal_f32(&hidden, &[1, man.hidden as i64]).unwrap(),
                    literal_f32(&emb, &[man.vocab as i64, man.hidden as i64]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let logits = to_f32(&out[0]).unwrap();
        assert_eq!(logits.len(), man.vocab);
        // verify against a hand computation for a few entries
        for v in 0..3 {
            let want: f32 = (0..man.hidden)
                .map(|h| 0.01f32 * emb[v * man.hidden + h])
                .sum();
            assert!((logits[v] - want).abs() < 1e-4, "{} vs {}", logits[v], want);
        }
    }
}
