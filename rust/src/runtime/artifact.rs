//! Artifact loading: manifest.json, weight binaries, oracle.json —
//! everything `make artifacts` produced on the Python side.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed manifest.json: gyges-tiny dims + module/weight catalogue.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub hidden: usize,
    pub inner: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub layers: usize,
    pub vocab: usize,
    pub tokens_per_block: usize,
    pub s_max: usize,
    pub blocks: usize,
    pub block_inner: usize,
    pub tp_choices: Vec<usize>,
    pub padded_shard_inner: BTreeMap<usize, usize>,
    pub modules: BTreeMap<String, String>,
    pub weights: BTreeMap<String, WeightMeta>,
}

/// One weight tensor's file + shape.
#[derive(Clone, Debug)]
pub struct WeightMeta {
    pub file: String,
    pub shape: Vec<usize>,
}

impl WeightMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .map(|x| x as usize)
        .ok_or_else(|| anyhow!("manifest missing {key}"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut padded = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("padded_shard_inner") {
            for (k, v) in m {
                padded.insert(
                    k.parse::<usize>().map_err(|e| anyhow!("bad tp key: {e}"))?,
                    v.as_f64().unwrap_or(0.0) as usize,
                );
            }
        }
        let mut modules = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("modules") {
            for (k, v) in m {
                modules.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
            }
        }
        let mut weights = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("weights") {
            for (k, v) in m {
                let file = v
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("weight {k}: no file"))?
                    .to_string();
                let shape = v
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow!("weight {k}: no shape"))?
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(0.0) as usize)
                    .collect();
                weights.insert(k.clone(), WeightMeta { file, shape });
            }
        }
        let tp_choices = j
            .get("tp_choices")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as usize).collect())
            .unwrap_or_else(|| vec![1, 2, 4]);

        Ok(Manifest {
            hidden: get_usize(&j, "hidden")?,
            inner: get_usize(&j, "inner")?,
            heads: get_usize(&j, "heads")?,
            head_dim: get_usize(&j, "head_dim")?,
            layers: get_usize(&j, "layers")?,
            vocab: get_usize(&j, "vocab")?,
            tokens_per_block: get_usize(&j, "tokens_per_block")?,
            s_max: get_usize(&j, "s_max")?,
            blocks: get_usize(&j, "blocks")?,
            block_inner: get_usize(&j, "block_inner")?,
            tp_choices,
            padded_shard_inner: padded,
            modules,
            weights,
            dir,
        })
    }

    /// Path of a module's HLO text file.
    pub fn module_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .modules
            .get(name)
            .ok_or_else(|| anyhow!("module {name:?} not in manifest"))?;
        Ok(self.dir.join(f))
    }

    /// Load one weight tensor as f32 (little-endian on disk).
    pub fn load_weight(&self, name: &str) -> Result<Vec<f32>> {
        let meta = self
            .weights
            .get(name)
            .ok_or_else(|| anyhow!("weight {name:?} not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != 4 * meta.numel() {
            bail!(
                "{name}: expected {} bytes, file has {}",
                4 * meta.numel(),
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// oracle.json: the greedy-decode continuation the e2e example verifies.
#[derive(Clone, Debug)]
pub struct Oracle {
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
}

impl Oracle {
    pub fn load(dir: impl AsRef<Path>) -> Result<Oracle> {
        let path = dir.as_ref().join("oracle.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("oracle parse: {e}"))?;
        let ints = |key: &str| -> Result<Vec<u32>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as u32).collect())
                .ok_or_else(|| anyhow!("oracle missing {key}"))
        };
        Ok(Oracle { prompt: ints("prompt")?, generated: ints("generated")? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.hidden, 256);
        assert_eq!(m.heads, 8);
        assert_eq!(m.modules.len(), 14);
        for tp in &m.tp_choices {
            assert_eq!(m.padded_shard_inner[tp] % m.block_inner, 0);
        }
        // every module file exists
        for name in m.modules.keys() {
            assert!(m.module_path(name).unwrap().exists(), "{name}");
        }
    }

    #[test]
    fn weights_load_with_right_sizes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let emb = m.load_weight("emb").unwrap();
        assert_eq!(emb.len(), m.vocab * m.hidden);
        let up = m.load_weight("l0.up").unwrap();
        assert_eq!(up.len(), m.hidden * m.inner);
        assert!(m.load_weight("nonexistent").is_err());
    }

    #[test]
    fn oracle_loads() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let o = Oracle::load(&dir).unwrap();
        assert!(!o.prompt.is_empty());
        assert_eq!(o.generated.len(), 8);
    }
}
