//! Real-model serving front end: batched request intake over the PJRT
//! runtime with wall-clock TTFT/TPOT/throughput measurement, including
//! live parallelism transformation when a long request arrives.
//!
//! This is the path `examples/serve_e2e.rs` exercises end to end.

use crate::runtime::{argmax, TinyRuntime};
use crate::util::stats::Summary;
use anyhow::Result;
use std::time::Instant;

/// One serving request for the tiny model.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

/// Measured outcome of one request.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub id: u64,
    pub output: Vec<u32>,
    pub ttft_s: f64,
    pub tpot_s: f64,
    pub total_s: f64,
}

/// Aggregate serving report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub results: Vec<ServeResult>,
    pub wall_s: f64,
    pub total_tokens: usize,
    pub throughput_tps: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    pub transforms: usize,
    pub transform_bytes: usize,
}

/// Serving policy knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// TP degree to start at.
    pub initial_tp: usize,
    /// Prompt length above which the server scales up to `high_tp`.
    pub long_threshold: usize,
    pub high_tp: usize,
    /// Scale back down when no long request is active.
    pub auto_scale_down: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { initial_tp: 1, long_threshold: 48, high_tp: 4, auto_scale_down: true }
    }
}

/// A single-instance real-model server (the e2e demonstrator).
pub struct RealServer {
    pub rt: TinyRuntime,
    pub cfg: ServerConfig,
    transforms: usize,
    transform_bytes: usize,
}

impl RealServer {
    pub fn new(artifacts: impl AsRef<std::path::Path>, cfg: ServerConfig) -> Result<RealServer> {
        let rt = TinyRuntime::load(artifacts, cfg.initial_tp)?;
        Ok(RealServer { rt, cfg, transforms: 0, transform_bytes: 0 })
    }

    /// Serve a batch of requests FIFO, transforming parallelism when the
    /// workload demands it (long prompt → scale up; afterwards → down).
    pub fn serve(&mut self, requests: &[ServeRequest]) -> Result<ServeReport> {
        let wall0 = Instant::now();
        let mut results = Vec::with_capacity(requests.len());
        let mut total_tokens = 0usize;

        for req in requests {
            // Transformation-aware placement (the §5 decision, single
            // instance edition): long prompts need the high-TP config.
            let needs_high = req.prompt.len() + req.max_new_tokens >= self.cfg.long_threshold;
            let mut sess = self.rt.new_session()?;
            if needs_high && self.rt.tp != self.cfg.high_tp {
                self.rt.transform(&mut sess, self.cfg.high_tp)?;
                self.transforms += 1;
                self.transform_bytes += self.rt.last_transform_bytes;
            } else if !needs_high && self.cfg.auto_scale_down && self.rt.tp != self.cfg.initial_tp
            {
                self.rt.transform(&mut sess, self.cfg.initial_tp)?;
                self.transforms += 1;
                self.transform_bytes += self.rt.last_transform_bytes;
            }

            let t0 = Instant::now();
            let mut logits = Vec::new();
            for &t in &req.prompt {
                logits = self.rt.step(&mut sess, t)?;
            }
            let ttft = t0.elapsed().as_secs_f64();
            let mut output = Vec::with_capacity(req.max_new_tokens);
            let gen0 = Instant::now();
            for _ in 0..req.max_new_tokens {
                if sess.pos >= self.rt.man.s_max {
                    break;
                }
                let next = argmax(&logits) as u32;
                output.push(next);
                logits = self.rt.step(&mut sess, next)?;
            }
            let gen_s = gen0.elapsed().as_secs_f64();
            let n_out = output.len().max(1);
            total_tokens += output.len();
            results.push(ServeResult {
                id: req.id,
                tpot_s: gen_s / n_out as f64,
                ttft_s: ttft,
                total_s: t0.elapsed().as_secs_f64(),
                output,
            });
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        let ttft = Summary::of(&results.iter().map(|r| r.ttft_s).collect::<Vec<_>>());
        let tpot = Summary::of(&results.iter().map(|r| r.tpot_s).collect::<Vec<_>>());
        Ok(ServeReport {
            results,
            wall_s,
            total_tokens,
            throughput_tps: total_tokens as f64 / wall_s.max(1e-9),
            ttft,
            tpot,
            transforms: self.transforms,
            transform_bytes: self.transform_bytes,
        })
    }
}

/// Build a mixed short/long workload over the tiny model's vocab.
pub fn synthetic_workload(
    seed: u64,
    shorts: usize,
    longs: usize,
    vocab: usize,
) -> Vec<ServeRequest> {
    let mut rng = crate::util::Prng::new(seed);
    let mut reqs = Vec::new();
    for i in 0..shorts {
        let len = 4 + rng.index(8);
        let prompt = (0..len).map(|_| rng.index(vocab) as u32).collect();
        reqs.push(ServeRequest { id: i as u64, prompt, max_new_tokens: 8 });
    }
    for i in 0..longs {
        let len = 56 + rng.index(16);
        let prompt = (0..len).map(|_| rng.index(vocab) as u32).collect();
        reqs.push(ServeRequest {
            id: (shorts + i) as u64,
            prompt,
            max_new_tokens: 12,
        });
    }
    rng.shuffle(&mut reqs);
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn serves_mixed_workload_with_transformations() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut server = RealServer::new(&dir, ServerConfig::default()).unwrap();
        let reqs = synthetic_workload(1, 3, 1, server.rt.man.vocab);
        let report = server.serve(&reqs).unwrap();
        assert_eq!(report.results.len(), 4);
        assert!(report.throughput_tps > 0.0);
        assert!(report.transforms >= 1, "the long request must trigger a transform");
        for r in &report.results {
            assert!(!r.output.is_empty());
        }
    }

    #[test]
    fn deterministic_outputs_across_runs() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let reqs = synthetic_workload(2, 2, 0, 1024);
        let mut a = RealServer::new(&dir, ServerConfig::default()).unwrap();
        let mut b = RealServer::new(&dir, ServerConfig::default()).unwrap();
        let ra = a.serve(&reqs).unwrap();
        let rb = b.serve(&reqs).unwrap();
        for (x, y) in ra.results.iter().zip(&rb.results) {
            assert_eq!(x.output, y.output);
        }
    }
}
