//! Bench regression gate: compare a fresh `BENCH_sim.json` against the
//! committed baseline and fail on a large throughput regression.
//!
//! CI runs `gyges bench-gate` right after the bench-smoke step. The gate
//! compares the headline rates (`single_thread.events_per_sec` and the
//! ≥256-instance `routing_microbench.speedup`) — but ONLY between
//! snapshots that measured the same workload shape: the request counts,
//! fleet size, and sample count are checked first, and any mismatch
//! skips the comparison loudly (commit CI's own `BENCH_sim` artifact as
//! the baseline and the knobs match by construction). The default 25%
//! tolerance absorbs runner noise.
//!
//! Schema v3 adds the `scaling_curve` section (fleet-size sweep). The
//! gate compares `events_per_sec` per fleet size, matching baseline and
//! fresh points by `hosts` and gating only points whose shape knobs
//! (`instances`, `requests`, plus the curve-level `qps_per_instance`
//! and `horizon_s`) agree; any mismatched or unmatched point is skipped
//! loudly. A v2 baseline with no curve leaves the curve ungated (noted
//! as info) so the gate stays green across the schema bump.
//!
//! A baseline with `measured != true` is a hand-written complexity
//! placeholder (PR 1/PR 2 shipped those because their build containers
//! had no Rust toolchain); the gate SKIPS rather than compare against
//! projections, and starts biting on the first commit of a harness-
//! produced baseline. A *fresh* file that is not a measured harness
//! output always fails — the gate must never pass vacuously because the
//! bench step silently produced nothing.

use crate::util::json::Json;

/// Dotted paths of the gated headline metrics (bigger is better).
pub const GATED_METRICS: [&str; 2] =
    ["single_thread.events_per_sec", "routing_microbench.speedup"];

/// Informational metrics printed but never gated (too machine-dependent).
const INFO_METRICS: [&str; 1] = ["sweep.speedup"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateVerdict {
    /// Every gated metric is within tolerance.
    Pass,
    /// Baseline is a placeholder — nothing real to compare against.
    Skip,
    /// A gated metric regressed beyond tolerance (or a snapshot is
    /// malformed).
    Fail,
}

/// Outcome plus human-readable per-metric lines for the CI log.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub verdict: GateVerdict,
    pub lines: Vec<String>,
}

impl GateReport {
    /// Process exit code for CLI use.
    pub fn exit_code(&self) -> i32 {
        match self.verdict {
            GateVerdict::Pass | GateVerdict::Skip => 0,
            GateVerdict::Fail => 1,
        }
    }
}

/// Walk a dotted path (`"single_thread.events_per_sec"`) into a doc.
fn get_path<'a>(doc: &'a Json, path: &str) -> Option<&'a Json> {
    let mut cur = doc;
    for seg in path.split('.') {
        cur = cur.get(seg)?;
    }
    Some(cur)
}

fn is_measured(doc: &Json) -> bool {
    doc.get("measured").and_then(Json::as_bool) == Some(true)
}

/// Schema v3 scaling-curve gate: compare `events_per_sec` per fleet
/// size. Returns `true` when a matched same-shape point regressed
/// beyond tolerance. Shape mismatches never fail — they skip loudly,
/// same policy as the top-level workload knobs.
fn gate_scaling_curve(
    baseline: &Json,
    fresh: &Json,
    max_regress: f64,
    lines: &mut Vec<String>,
) -> bool {
    let (bc, nc) = match (baseline.get("scaling_curve"), fresh.get("scaling_curve")) {
        (Some(b), Some(n)) => (b, n),
        _ => {
            lines.push(
                "info: scaling_curve absent from a snapshot (schema v2 baseline?) — not gated"
                    .into(),
            );
            return false;
        }
    };
    for knob in ["qps_per_instance", "horizon_s"] {
        let b = bc.get(knob).and_then(Json::as_f64);
        let n = nc.get(knob).and_then(Json::as_f64);
        if b != n {
            lines.push(format!(
                "skip: scaling_curve.{knob} differs (baseline {b:?}, fresh {n:?}) — \
                 curves measured different workloads, curve not gated"
            ));
            return false;
        }
    }
    let empty: Vec<Json> = Vec::new();
    let bpoints = match bc.get("points") {
        Some(Json::Arr(v)) => v,
        _ => &empty,
    };
    let npoints = match nc.get("points") {
        Some(Json::Arr(v)) => v,
        _ => &empty,
    };
    let mut failed = false;
    for bp in bpoints {
        let hosts = bp.get("hosts").and_then(Json::as_f64);
        let h = hosts.unwrap_or(f64::NAN);
        let found = npoints.iter().find(|p| p.get("hosts").and_then(Json::as_f64) == hosts);
        let np = match found {
            Some(p) => p,
            None => {
                lines.push(format!(
                    "skip: scaling_curve point hosts={h:.0} absent from fresh snapshot"
                ));
                continue;
            }
        };
        let same_shape = ["instances", "requests"].iter().all(|k| {
            bp.get(k).and_then(Json::as_f64) == np.get(k).and_then(Json::as_f64)
        });
        if !same_shape {
            lines.push(format!(
                "skip: scaling_curve point hosts={h:.0} measured a different workload shape"
            ));
            continue;
        }
        let base = bp.get("events_per_sec").and_then(Json::as_f64);
        let new = np.get("events_per_sec").and_then(Json::as_f64);
        match (base, new) {
            (Some(b), Some(n)) if b > 0.0 => {
                let ratio = n / b;
                if ratio < 1.0 - max_regress {
                    failed = true;
                    let drop = (1.0 - ratio) * 100.0;
                    let tol = max_regress * 100.0;
                    lines.push(format!(
                        "FAIL: scaling_curve[hosts={h:.0}].events_per_sec regressed {drop:.1}% \
                         (baseline {b:.1} → fresh {n:.1}, tolerance {tol:.0}%)"
                    ));
                } else {
                    let pct = (ratio - 1.0) * 100.0;
                    lines.push(format!(
                        "ok:   scaling_curve[hosts={h:.0}].events_per_sec {b:.1} → {n:.1} \
                         ({pct:+.1}%)"
                    ));
                }
            }
            _ => {
                failed = true;
                lines.push(format!(
                    "FAIL: scaling_curve[hosts={h:.0}].events_per_sec missing or non-positive \
                     (baseline {base:?}, fresh {new:?})"
                ));
            }
        }
    }
    failed
}

/// Compare `fresh` against `baseline`; a gated metric fails when
/// `fresh < baseline * (1 - max_regress)`.
pub fn evaluate(baseline: &Json, fresh: &Json, max_regress: f64) -> GateReport {
    let mut lines = Vec::new();
    if !(0.0..1.0).contains(&max_regress) {
        // >= 1.0 would silently disarm the gate (no ratio can fail);
        // < 0 would fail every run. Both are operator error — e.g.
        // passing 25 for 25% — and must be loud.
        let msg = format!(
            "FAIL: max_regress {max_regress} out of range [0, 1) — pass a fraction \
             (0.25 means a 25% drop fails)"
        );
        return GateReport { verdict: GateVerdict::Fail, lines: vec![msg] };
    }
    if !is_measured(fresh) {
        let msg = "FAIL: fresh snapshot has measured != true — the bench harness did not \
                   produce it (gate refuses to pass vacuously)";
        return GateReport { verdict: GateVerdict::Fail, lines: vec![msg.into()] };
    }
    if !is_measured(baseline) {
        let msg = "SKIP: committed baseline has measured != true (complexity-projection \
                   placeholder); commit a harness-generated BENCH_sim.json to arm the gate";
        return GateReport { verdict: GateVerdict::Skip, lines: vec![msg.into()] };
    }
    // Rates are only comparable when both snapshots measured the same
    // workload shape (a 10k-request 3-sample baseline vs a 2k-request
    // 1-sample smoke run diverges systematically, not from any code
    // change). A knob mismatch is a setup problem, not a regression —
    // skip loudly instead of failing or passing vacuously.
    // `samples` matters because events_per_sec is the BEST wall time
    // over the samples — best-of-3 is systematically faster than CI's
    // single-sample smoke run.
    const WORKLOAD_KNOBS: [&str; 4] = [
        "single_thread.trace_requests",
        "single_thread.samples",
        "routing_microbench.requests",
        "routing_microbench.instances",
    ];
    for knob in WORKLOAD_KNOBS {
        let b = get_path(baseline, knob).and_then(Json::as_f64);
        let n = get_path(fresh, knob).and_then(Json::as_f64);
        if b != n {
            let msg = format!(
                "SKIP: {knob} differs (baseline {b:?}, fresh {n:?}) — the snapshots \
                 measured different workloads; regenerate the baseline with the same \
                 bench knobs (commit CI's own BENCH_sim artifact)"
            );
            return GateReport { verdict: GateVerdict::Skip, lines: vec![msg] };
        }
    }
    let mut verdict = GateVerdict::Pass;
    for path in GATED_METRICS {
        let base = get_path(baseline, path).and_then(Json::as_f64);
        let new = get_path(fresh, path).and_then(Json::as_f64);
        match (base, new) {
            (Some(b), Some(n)) if b > 0.0 => {
                let ratio = n / b;
                if ratio < 1.0 - max_regress {
                    verdict = GateVerdict::Fail;
                    let drop = (1.0 - ratio) * 100.0;
                    let tol = max_regress * 100.0;
                    lines.push(format!(
                        "FAIL: {path} regressed {drop:.1}% (baseline {b:.1} → fresh {n:.1}, \
                         tolerance {tol:.0}%)"
                    ));
                } else {
                    let pct = (ratio - 1.0) * 100.0;
                    lines.push(format!("ok:   {path} {b:.1} → {n:.1} ({pct:+.1}%)"));
                }
            }
            _ => {
                verdict = GateVerdict::Fail;
                lines.push(format!(
                    "FAIL: {path} missing or non-positive in a measured snapshot \
                     (baseline {base:?}, fresh {new:?})"
                ));
            }
        }
    }
    if gate_scaling_curve(baseline, fresh, max_regress, &mut lines) {
        verdict = GateVerdict::Fail;
    }
    for path in INFO_METRICS {
        if let (Some(b), Some(n)) = (
            get_path(baseline, path).and_then(Json::as_f64),
            get_path(fresh, path).and_then(Json::as_f64),
        ) {
            lines.push(format!("info: {path} {b:.2} → {n:.2} (not gated)"));
        }
    }
    GateReport { verdict, lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot_with_requests(measured: bool, eps: f64, speedup: f64, requests: u64) -> Json {
        Json::parse(&format!(
            r#"{{"measured": {measured},
                 "single_thread": {{"events_per_sec": {eps}, "trace_requests": {requests},
                                    "samples": 1}},
                 "routing_microbench":
                   {{"speedup": {speedup}, "requests": 4000, "instances": 256}},
                 "sweep": {{"speedup": 3.5}}}}"#
        ))
        .unwrap()
    }

    fn snapshot(measured: bool, eps: f64, speedup: f64) -> Json {
        snapshot_with_requests(measured, eps, speedup, 2000)
    }

    #[test]
    fn passes_within_tolerance() {
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 900.0, 4.5), 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
        assert_eq!(r.exit_code(), 0);
        assert!(r.lines.iter().any(|l| l.contains("not gated")));
    }

    #[test]
    fn fails_on_events_per_sec_regression() {
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 700.0, 5.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Fail);
        assert_eq!(r.exit_code(), 1);
        assert!(r.lines.iter().any(|l| l.contains("events_per_sec")));
    }

    #[test]
    fn fails_on_routing_speedup_regression() {
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 1000.0, 3.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Fail);
        assert!(r.lines.iter().any(|l| l.contains("routing_microbench.speedup")));
    }

    #[test]
    fn improvement_passes() {
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 1500.0, 8.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
    }

    #[test]
    fn out_of_range_tolerance_fails_instead_of_disarming() {
        // 25 (meaning "25%") would otherwise make every ratio pass.
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 10.0, 0.1), 25.0);
        assert_eq!(r.verdict, GateVerdict::Fail);
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &snapshot(true, 1000.0, 5.0), -0.1);
        assert_eq!(r.verdict, GateVerdict::Fail);
    }

    #[test]
    fn mismatched_workload_knobs_skip_instead_of_comparing() {
        let baseline = snapshot_with_requests(true, 1000.0, 5.0, 10_000);
        let fresh = snapshot_with_requests(true, 100.0, 5.0, 2000);
        let r = evaluate(&baseline, &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Skip);
        assert!(r.lines[0].contains("trace_requests"));
    }

    #[test]
    fn placeholder_baseline_skips() {
        let r = evaluate(&snapshot(false, 0.0, 0.0), &snapshot(true, 1000.0, 5.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Skip);
        assert_eq!(r.exit_code(), 0);
    }

    #[test]
    fn unmeasured_fresh_fails_even_with_placeholder_baseline() {
        let r = evaluate(&snapshot(false, 0.0, 0.0), &snapshot(false, 1000.0, 5.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Fail);
    }

    #[test]
    fn measured_baseline_with_missing_metric_fails() {
        let base = Json::parse(r#"{"measured": true, "single_thread": {}}"#).unwrap();
        let r = evaluate(&base, &snapshot(true, 1000.0, 5.0), 0.25);
        assert_eq!(r.verdict, GateVerdict::Fail);
    }

    /// Splice a schema-v3 scaling curve into a headline-passing snapshot.
    fn with_curve(mut doc: Json, points: &[(u64, u64, u64, f64)]) -> Json {
        let rows = points
            .iter()
            .map(|&(hosts, instances, requests, eps)| {
                let mut p = Json::obj();
                p.set("hosts", hosts)
                    .set("instances", instances)
                    .set("requests", requests)
                    .set("events", 1_000_000u64)
                    .set("wall_s", 1.0)
                    .set("events_per_sec", eps);
                p
            })
            .collect();
        let mut curve = Json::obj();
        curve
            .set("qps_per_instance", 0.25)
            .set("horizon_s", 60.0)
            .set("points", Json::Arr(rows));
        doc.set("scaling_curve", curve);
        doc
    }

    #[test]
    fn v2_baseline_without_curve_stays_green() {
        let fresh = with_curve(snapshot(true, 1000.0, 5.0), &[(32, 256, 4000, 9e5)]);
        let r = evaluate(&snapshot(true, 1000.0, 5.0), &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
        assert!(r.lines.iter().any(|l| l.contains("scaling_curve absent")));
    }

    #[test]
    fn curve_point_regression_fails_per_fleet_size() {
        let base = with_curve(
            snapshot(true, 1000.0, 5.0),
            &[(32, 256, 4000, 1e6), (1250, 10_000, 150_000, 5e5)],
        );
        let fresh = with_curve(
            snapshot(true, 1000.0, 5.0),
            &[(32, 256, 4000, 1e6), (1250, 10_000, 150_000, 2e5)],
        );
        let r = evaluate(&base, &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Fail);
        assert!(r.lines.iter().any(|l| l.contains("scaling_curve[hosts=1250]")));
        assert!(r.lines.iter().any(|l| l.contains("ok:   scaling_curve[hosts=32]")));
    }

    #[test]
    fn curve_shape_mismatch_skips_that_point_only() {
        let base = with_curve(
            snapshot(true, 1000.0, 5.0),
            &[(32, 256, 4000, 1e6), (128, 1024, 16_000, 8e5)],
        );
        // hosts=128 re-measured with a different request count AND a huge
        // eps drop: the mismatch must skip, not fail; hosts=32 still gates.
        let fresh = with_curve(
            snapshot(true, 1000.0, 5.0),
            &[(32, 256, 4000, 1e6), (128, 1024, 99_000, 1e2)],
        );
        let r = evaluate(&base, &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
        assert!(r.lines.iter().any(|l| l.contains("different workload shape")));
    }

    #[test]
    fn curve_level_knob_mismatch_ungates_whole_curve() {
        let base = with_curve(snapshot(true, 1000.0, 5.0), &[(32, 256, 4000, 1e6)]);
        let mut fresh = snapshot(true, 1000.0, 5.0);
        let mut curve = Json::obj();
        curve
            .set("qps_per_instance", 0.25)
            .set("horizon_s", 3600.0)
            .set("points", Json::Arr(Vec::new()));
        fresh.set("scaling_curve", curve);
        let r = evaluate(&base, &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
        assert!(r.lines.iter().any(|l| l.contains("scaling_curve.horizon_s differs")));
    }

    #[test]
    fn curve_point_missing_from_fresh_skips_loudly() {
        let base = with_curve(
            snapshot(true, 1000.0, 5.0),
            &[(32, 256, 4000, 1e6), (512, 4096, 60_000, 6e5)],
        );
        let fresh = with_curve(snapshot(true, 1000.0, 5.0), &[(32, 256, 4000, 1e6)]);
        let r = evaluate(&base, &fresh, 0.25);
        assert_eq!(r.verdict, GateVerdict::Pass);
        assert!(r.lines.iter().any(|l| l.contains("hosts=512 absent")));
    }
}
