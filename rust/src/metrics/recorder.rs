//! Per-request and cluster-level metric recording: TTFT, TPOT,
//! throughput — the three quantities of Figure 14.
//!
//! Hot-path contract (see PERF.md): `on_arrival` / `on_first_token` /
//! `on_token` / `on_finish` are O(1) — records live in a dense `Vec` slab
//! keyed by request id (traces assign dense ids in [`crate::workload::
//! Trace::sort_and_renumber`]), TPS buckets are a `Vec` indexed by
//! simulated second,
//! and completed/token totals are maintained incrementally so the
//! end-of-run report never rescans the slab for them.

use crate::sim::clock::{SimDuration, SimTime};
use crate::util::stats::Summary;
use crate::workload::SloClass;

/// Lifecycle timestamps of one request.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestRecord {
    pub arrival: SimTime,
    /// First token emitted (prefill complete).
    pub first_token: Option<SimTime>,
    /// Completion time.
    pub finished: Option<SimTime>,
    pub input_len: u64,
    pub output_len: u64,
    /// Tokens generated so far.
    pub generated: u64,
    /// Seconds this request credited into the cluster TPS buckets, as
    /// (second, count) run-length pairs. Tokens arrive in time order,
    /// so appends are amortized O(1) (same-second tokens bump the last
    /// pair). This is what lets a crash-requeue re-registration unwind
    /// exactly the per-second credits of the lost run — without it the
    /// cluster `tps_buckets` kept phantom counts (the PR 6 caveat).
    pub tok_buckets: Vec<(u32, u32)>,
    /// SLO class, for the per-class report breakdown (defaults to
    /// `Interactive` — the only class classless traces carry).
    pub class: SloClass,
}

impl RequestRecord {
    pub fn ttft(&self) -> Option<SimDuration> {
        self.first_token.map(|t| t.since(self.arrival))
    }

    /// Time-per-output-token (excludes the first token, vLLM convention).
    pub fn tpot(&self) -> Option<SimDuration> {
        match (self.first_token, self.finished) {
            (Some(f), Some(d)) if self.generated > 1 => {
                Some(SimDuration((d.since(f)).0 / (self.generated - 1)))
            }
            _ => None,
        }
    }
}

/// Collects records for a whole experiment run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    /// Slab keyed by request id. Ids are expected to be dense (memory is
    /// O(max id)); sparse test ids merely leave `None` holes.
    records: Vec<Option<RequestRecord>>,
    /// Count of occupied slab slots.
    total: usize,
    /// Count of finished requests (incremental; O(1) reads).
    completed: usize,
    /// Total output tokens generated (throughput numerator).
    tokens: u64,
    /// Output-token completions bucketed per second (Fig. 13 TPS trend),
    /// indexed by whole simulated second.
    tps_buckets: Vec<u64>,
    pub horizon: SimTime,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    fn slot_mut(&mut self, id: u64) -> &mut Option<RequestRecord> {
        let idx = id as usize;
        if idx >= self.records.len() {
            self.records.resize(idx + 1, None);
        }
        &mut self.records[idx]
    }

    fn bump_bucket(&mut self, at: SimTime) {
        let idx = at.as_secs_f64() as usize;
        if idx >= self.tps_buckets.len() {
            self.tps_buckets.resize(idx + 1, 0);
        }
        self.tps_buckets[idx] += 1;
    }

    /// Log one token into the record's per-second credit ledger
    /// (mirrors the `bump_bucket` the caller performs).
    fn log_token(r: &mut RequestRecord, at: SimTime) {
        let sec = at.as_secs_f64() as u32;
        match r.tok_buckets.last_mut() {
            Some((s, c)) if *s == sec => *c += 1,
            _ => r.tok_buckets.push((sec, 1)),
        }
    }

    pub fn on_arrival(&mut self, id: u64, at: SimTime, input_len: u64, output_len: u64) {
        self.on_arrival_classed(id, at, input_len, output_len, SloClass::Interactive);
    }

    /// [`Recorder::on_arrival`] with an explicit SLO class (the cluster
    /// path; the class-free form exists for classless callers/tests).
    pub fn on_arrival_classed(
        &mut self,
        id: u64,
        at: SimTime,
        input_len: u64,
        output_len: u64,
        class: SloClass,
    ) {
        let record =
            RequestRecord { arrival: at, input_len, output_len, class, ..Default::default() };
        let slot = self.slot_mut(id);
        match slot.replace(record) {
            // Re-registering an id unwinds the old record's contributions
            // so the incremental totals stay exact — including the
            // per-second TPS credits (crash requeue replays generation
            // from scratch, so the lost run's buckets must vanish).
            Some(old) => {
                self.tokens -= old.generated;
                if old.finished.is_some() {
                    self.completed -= 1;
                }
                for &(sec, c) in &old.tok_buckets {
                    if let Some(b) = self.tps_buckets.get_mut(sec as usize) {
                        *b = b.saturating_sub(u64::from(c));
                    }
                }
            }
            None => self.total += 1,
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn on_first_token(&mut self, id: u64, at: SimTime) {
        let mut emitted = false;
        if let Some(r) = self.slot_mut(id).as_mut() {
            if r.first_token.is_none() {
                r.first_token = Some(at);
                r.generated = 1;
                Self::log_token(r, at);
                emitted = true;
            }
        }
        if emitted {
            self.tokens += 1;
            self.bump_bucket(at);
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn on_token(&mut self, id: u64, at: SimTime) {
        let mut emitted = false;
        if let Some(r) = self.slot_mut(id).as_mut() {
            r.generated += 1;
            Self::log_token(r, at);
            emitted = true;
        }
        if emitted {
            self.tokens += 1;
            self.bump_bucket(at);
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn on_finish(&mut self, id: u64, at: SimTime) {
        let mut newly_finished = false;
        if let Some(r) = self.slot_mut(id).as_mut() {
            if r.finished.is_none() {
                r.finished = Some(at);
                newly_finished = true;
            }
        }
        if newly_finished {
            self.completed += 1;
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn get(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(id as usize)?.as_ref()
    }

    /// All records with their ids, in id order.
    pub fn records(&self) -> impl Iterator<Item = (u64, &RequestRecord)> {
        self.records
            .iter()
            .enumerate()
            .filter_map(|(id, r)| r.as_ref().map(|r| (id as u64, r)))
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Output tokens per second over the run.
    pub fn throughput_tps(&self) -> f64 {
        let secs = self.horizon.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / secs
        }
    }

    /// TTFT summary in seconds over completed-prefill requests.
    pub fn ttft_summary(&self) -> Summary {
        let xs: Vec<f64> = self
            .records()
            .filter_map(|(_, r)| r.ttft())
            .map(|d| d.as_secs_f64())
            .collect();
        Summary::of(&xs)
    }

    /// TTFT summary in seconds restricted to one SLO class.
    pub fn ttft_summary_class(&self, class: SloClass) -> Summary {
        let xs: Vec<f64> = self
            .records()
            .filter(|(_, r)| r.class == class)
            .filter_map(|(_, r)| r.ttft())
            .map(|d| d.as_secs_f64())
            .collect();
        Summary::of(&xs)
    }

    /// Occupied records carrying `class`.
    pub fn class_total(&self, class: SloClass) -> usize {
        self.records().filter(|(_, r)| r.class == class).count()
    }

    /// TPOT summary in seconds.
    pub fn tpot_summary(&self) -> Summary {
        let xs: Vec<f64> = self
            .records()
            .filter_map(|(_, r)| r.tpot())
            .map(|d| d.as_secs_f64())
            .collect();
        Summary::of(&xs)
    }

    /// Fraction of requests meeting the paper's SLOs (TTFT<10 s,
    /// TPOT<100 ms).
    pub fn slo_attainment(&self, ttft_s: f64, tpot_s: f64) -> f64 {
        self.attainment_where(ttft_s, tpot_s, |_| true)
    }

    /// [`Recorder::slo_attainment`] restricted to one SLO class.
    pub fn slo_attainment_class(&self, class: SloClass, ttft_s: f64, tpot_s: f64) -> f64 {
        self.attainment_where(ttft_s, tpot_s, |r| r.class == class)
    }

    fn attainment_where(
        &self,
        ttft_s: f64,
        tpot_s: f64,
        keep: impl Fn(&RequestRecord) -> bool,
    ) -> f64 {
        let mut done = 0usize;
        let mut ok = 0usize;
        for (_, r) in self.records() {
            if r.finished.is_none() || !keep(r) {
                continue;
            }
            done += 1;
            let ttft_ok = r.ttft().map(|t| t.as_secs_f64() < ttft_s).unwrap_or(false);
            let tpot_ok = r.tpot().map(|t| t.as_secs_f64() < tpot_s).unwrap_or(true);
            if ttft_ok && tpot_ok {
                ok += 1;
            }
        }
        if done == 0 {
            return 0.0;
        }
        ok as f64 / done as f64
    }

    /// Tokens/s series bucketed per second (Figure 13); seconds with no
    /// completions are omitted, matching the sparse-map behaviour.
    pub fn tps_series(&self) -> Vec<(u64, u64)> {
        self.tps_buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, &c)| (s as u64, c))
            .collect()
    }

    /// Raw per-second token buckets (snapshot support — unlike
    /// [`Recorder::tps_series`], zero buckets are preserved so a
    /// restored recorder is field-identical).
    pub fn tps_buckets(&self) -> &[u64] {
        &self.tps_buckets
    }

    /// Rebuild a recorder from snapshot parts. The incremental totals
    /// (`total`, `completed`, `tokens`) are recomputed from the records —
    /// they are defined as those sums, so recomputation keeps a
    /// hand-edited snapshot from desynchronizing the O(1) reads.
    pub fn restore(
        rows: Vec<(u64, RequestRecord)>,
        tps_buckets: Vec<u64>,
        horizon: SimTime,
    ) -> Recorder {
        let mut rec = Recorder {
            records: Vec::new(),
            total: 0,
            completed: 0,
            tokens: 0,
            tps_buckets,
            horizon,
        };
        for (id, row) in rows {
            rec.total += 1;
            if row.finished.is_some() {
                rec.completed += 1;
            }
            rec.tokens += row.generated;
            *rec.slot_mut(id) = Some(row);
        }
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn ttft_and_tpot() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 100, 4);
        rec.on_first_token(1, t(2.0));
        rec.on_token(1, t(2.1));
        rec.on_token(1, t(2.2));
        rec.on_token(1, t(2.3));
        rec.on_finish(1, t(2.3));
        let r = rec.get(1).unwrap();
        assert!((r.ttft().unwrap().as_secs_f64() - 2.0).abs() < 1e-9);
        assert!((r.tpot().unwrap().as_secs_f64() - 0.1).abs() < 1e-6);
        assert_eq!(rec.completed(), 1);
    }

    #[test]
    fn throughput_counts_all_tokens() {
        let mut rec = Recorder::new();
        for id in 0..10 {
            rec.on_arrival(id, t(0.0), 10, 2);
            rec.on_first_token(id, t(1.0));
            rec.on_token(id, t(2.0));
            rec.on_finish(id, t(2.0));
        }
        // 20 tokens over 2 s
        assert!((rec.throughput_tps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment_filters() {
        let mut rec = Recorder::new();
        // meets SLO
        rec.on_arrival(1, t(0.0), 10, 2);
        rec.on_first_token(1, t(1.0));
        rec.on_token(1, t(1.05));
        rec.on_finish(1, t(1.05));
        // violates TTFT
        rec.on_arrival(2, t(0.0), 10, 2);
        rec.on_first_token(2, t(20.0));
        rec.on_token(2, t(20.05));
        rec.on_finish(2, t(20.05));
        assert!((rec.slo_attainment(10.0, 0.1) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tps_series_buckets() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 1, 3);
        rec.on_first_token(1, t(0.5));
        rec.on_token(1, t(0.9));
        rec.on_token(1, t(1.1));
        let series = rec.tps_series();
        assert_eq!(series, vec![(0, 2), (1, 1)]);
    }

    #[test]
    fn incomplete_requests_have_no_tpot() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 10, 5);
        rec.on_first_token(1, t(1.0));
        assert!(rec.get(1).unwrap().tpot().is_none());
        assert_eq!(rec.completed(), 0);
        assert_eq!(rec.total(), 1);
    }

    #[test]
    fn class_breakdown_separates_summaries() {
        let mut rec = Recorder::new();
        // Fast interactive request, slow batch request.
        rec.on_arrival_classed(1, t(0.0), 10, 2, SloClass::Interactive);
        rec.on_first_token(1, t(1.0));
        rec.on_token(1, t(1.05));
        rec.on_finish(1, t(1.05));
        rec.on_arrival_classed(2, t(0.0), 10, 2, SloClass::Batch);
        rec.on_first_token(2, t(20.0));
        rec.on_token(2, t(20.05));
        rec.on_finish(2, t(20.05));
        assert_eq!(rec.class_total(SloClass::Interactive), 1);
        assert_eq!(rec.class_total(SloClass::Batch), 1);
        let int = rec.ttft_summary_class(SloClass::Interactive);
        let bat = rec.ttft_summary_class(SloClass::Batch);
        assert!((int.p50 - 1.0).abs() < 1e-9 && (bat.p50 - 20.0).abs() < 1e-9);
        // Global attainment blends the classes; the split isolates them.
        assert!((rec.slo_attainment(10.0, 0.1) - 0.5).abs() < 1e-9);
        assert!((rec.slo_attainment_class(SloClass::Interactive, 10.0, 0.1) - 1.0).abs() < 1e-9);
        assert!(rec.slo_attainment_class(SloClass::Batch, 10.0, 0.1).abs() < 1e-9);
        // The class-free entry point records Interactive.
        rec.on_arrival(3, t(0.0), 10, 2);
        assert_eq!(rec.class_total(SloClass::Interactive), 2);
    }

    #[test]
    fn sparse_ids_leave_holes_not_records() {
        let mut rec = Recorder::new();
        rec.on_arrival(7, t(0.0), 10, 2);
        assert_eq!(rec.total(), 1);
        assert!(rec.get(3).is_none());
        assert_eq!(rec.records().count(), 1);
    }

    #[test]
    fn incremental_totals_survive_rearrival() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 10, 2);
        rec.on_first_token(1, t(1.0));
        rec.on_token(1, t(1.1));
        rec.on_finish(1, t(1.1));
        assert_eq!(rec.completed(), 1);
        // Re-registering the id resets its contributions exactly.
        rec.on_arrival(1, t(2.0), 10, 2);
        assert_eq!(rec.completed(), 0);
        assert_eq!(rec.total(), 1);
        rec.on_first_token(1, t(3.0));
        rec.on_token(1, t(3.1));
        rec.on_finish(1, t(3.1));
        assert_eq!(rec.completed(), 1);
        // 2 tokens live (second pass) over horizon 3.1 s.
        assert!((rec.throughput_tps() - 2.0 / 3.1).abs() < 1e-9);
    }

    #[test]
    fn rearrival_unwinds_tps_buckets() {
        let mut rec = Recorder::new();
        // Credit tokens across three distinct seconds, then lose the
        // request to a crash (modeled as re-registration).
        rec.on_arrival(1, t(0.0), 10, 5);
        rec.on_first_token(1, t(0.5));
        rec.on_token(1, t(1.2));
        rec.on_token(1, t(1.4));
        rec.on_token(1, t(2.7));
        // An unrelated request shares second 1 — its credit must survive.
        rec.on_arrival(2, t(0.0), 10, 2);
        rec.on_first_token(2, t(1.0));
        assert_eq!(rec.tps_series(), vec![(0, 1), (1, 3), (2, 1)]);
        rec.on_arrival(1, t(3.0), 10, 5);
        // Only request 2's second-1 credit remains.
        assert_eq!(rec.tps_series(), vec![(1, 1)]);
        // Invariant: per-second credits always sum to the token total.
        let sum: u64 = rec.tps_buckets().iter().sum();
        assert_eq!(sum, 1);
        assert!((rec.throughput_tps() - 1.0 / 3.0).abs() < 1e-9);
        // The replayed run re-credits cleanly.
        rec.on_first_token(1, t(3.5));
        rec.on_token(1, t(4.1));
        assert_eq!(rec.tps_series(), vec![(1, 1), (3, 1), (4, 1)]);
        let sum: u64 = rec.tps_buckets().iter().sum();
        assert_eq!(sum, 3);
    }
}
