//! Per-request and cluster-level metric recording: TTFT, TPOT,
//! throughput — the three quantities of Figure 14.

use crate::sim::clock::{SimDuration, SimTime};
use crate::util::stats::Summary;
use std::collections::BTreeMap;

/// Lifecycle timestamps of one request.
#[derive(Clone, Debug, Default)]
pub struct RequestRecord {
    pub arrival: SimTime,
    /// First token emitted (prefill complete).
    pub first_token: Option<SimTime>,
    /// Completion time.
    pub finished: Option<SimTime>,
    pub input_len: u64,
    pub output_len: u64,
    /// Tokens generated so far.
    pub generated: u64,
}

impl RequestRecord {
    pub fn ttft(&self) -> Option<SimDuration> {
        self.first_token.map(|t| t.since(self.arrival))
    }

    /// Time-per-output-token (excludes the first token, vLLM convention).
    pub fn tpot(&self) -> Option<SimDuration> {
        match (self.first_token, self.finished) {
            (Some(f), Some(d)) if self.generated > 1 => {
                Some(SimDuration((d.since(f)).0 / (self.generated - 1)))
            }
            _ => None,
        }
    }
}

/// Collects records for a whole experiment run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    records: BTreeMap<u64, RequestRecord>,
    /// Output-token completions bucketed per second (Fig. 13 TPS trend).
    tps_buckets: BTreeMap<u64, u64>,
    pub horizon: SimTime,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn on_arrival(&mut self, id: u64, at: SimTime, input_len: u64, output_len: u64) {
        self.records.insert(
            id,
            RequestRecord { arrival: at, input_len, output_len, ..Default::default() },
        );
        self.horizon = self.horizon.max(at);
    }

    pub fn on_first_token(&mut self, id: u64, at: SimTime) {
        if let Some(r) = self.records.get_mut(&id) {
            if r.first_token.is_none() {
                r.first_token = Some(at);
                r.generated = 1;
                *self.tps_buckets.entry(at.as_secs_f64() as u64).or_insert(0) += 1;
            }
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn on_token(&mut self, id: u64, at: SimTime) {
        if let Some(r) = self.records.get_mut(&id) {
            r.generated += 1;
            *self.tps_buckets.entry(at.as_secs_f64() as u64).or_insert(0) += 1;
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn on_finish(&mut self, id: u64, at: SimTime) {
        if let Some(r) = self.records.get_mut(&id) {
            r.finished = Some(at);
        }
        self.horizon = self.horizon.max(at);
    }

    pub fn get(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(&id)
    }

    pub fn total(&self) -> usize {
        self.records.len()
    }

    pub fn completed(&self) -> usize {
        self.records.values().filter(|r| r.finished.is_some()).count()
    }

    /// Output tokens per second over the run.
    pub fn throughput_tps(&self) -> f64 {
        let tokens: u64 = self.records.values().map(|r| r.generated).sum();
        let secs = self.horizon.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            tokens as f64 / secs
        }
    }

    /// TTFT summary in seconds over completed-prefill requests.
    pub fn ttft_summary(&self) -> Summary {
        let xs: Vec<f64> = self
            .records
            .values()
            .filter_map(|r| r.ttft())
            .map(|d| d.as_secs_f64())
            .collect();
        Summary::of(&xs)
    }

    /// TPOT summary in seconds.
    pub fn tpot_summary(&self) -> Summary {
        let xs: Vec<f64> = self
            .records
            .values()
            .filter_map(|r| r.tpot())
            .map(|d| d.as_secs_f64())
            .collect();
        Summary::of(&xs)
    }

    /// Fraction of requests meeting the paper's SLOs (TTFT<10 s,
    /// TPOT<100 ms).
    pub fn slo_attainment(&self, ttft_s: f64, tpot_s: f64) -> f64 {
        let done: Vec<&RequestRecord> =
            self.records.values().filter(|r| r.finished.is_some()).collect();
        if done.is_empty() {
            return 0.0;
        }
        let ok = done
            .iter()
            .filter(|r| {
                r.ttft().map(|t| t.as_secs_f64() < ttft_s).unwrap_or(false)
                    && r.tpot().map(|t| t.as_secs_f64() < tpot_s).unwrap_or(true)
            })
            .count();
        ok as f64 / done.len() as f64
    }

    /// Tokens/s series bucketed per second (Figure 13).
    pub fn tps_series(&self) -> Vec<(u64, u64)> {
        self.tps_buckets.iter().map(|(&s, &c)| (s, c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn ttft_and_tpot() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 100, 4);
        rec.on_first_token(1, t(2.0));
        rec.on_token(1, t(2.1));
        rec.on_token(1, t(2.2));
        rec.on_token(1, t(2.3));
        rec.on_finish(1, t(2.3));
        let r = rec.get(1).unwrap();
        assert!((r.ttft().unwrap().as_secs_f64() - 2.0).abs() < 1e-9);
        assert!((r.tpot().unwrap().as_secs_f64() - 0.1).abs() < 1e-6);
        assert_eq!(rec.completed(), 1);
    }

    #[test]
    fn throughput_counts_all_tokens() {
        let mut rec = Recorder::new();
        for id in 0..10 {
            rec.on_arrival(id, t(0.0), 10, 2);
            rec.on_first_token(id, t(1.0));
            rec.on_token(id, t(2.0));
            rec.on_finish(id, t(2.0));
        }
        // 20 tokens over 2 s
        assert!((rec.throughput_tps() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment_filters() {
        let mut rec = Recorder::new();
        // meets SLO
        rec.on_arrival(1, t(0.0), 10, 2);
        rec.on_first_token(1, t(1.0));
        rec.on_token(1, t(1.05));
        rec.on_finish(1, t(1.05));
        // violates TTFT
        rec.on_arrival(2, t(0.0), 10, 2);
        rec.on_first_token(2, t(20.0));
        rec.on_token(2, t(20.05));
        rec.on_finish(2, t(20.05));
        assert!((rec.slo_attainment(10.0, 0.1) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tps_series_buckets() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 1, 3);
        rec.on_first_token(1, t(0.5));
        rec.on_token(1, t(0.9));
        rec.on_token(1, t(1.1));
        let series = rec.tps_series();
        assert_eq!(series, vec![(0, 2), (1, 1)]);
    }

    #[test]
    fn incomplete_requests_have_no_tpot() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, t(0.0), 10, 5);
        rec.on_first_token(1, t(1.0));
        assert!(rec.get(1).unwrap().tpot().is_none());
        assert_eq!(rec.completed(), 0);
        assert_eq!(rec.total(), 1);
    }
}
