//! Experiment report rendering: summary lines and JSON rows for
//! `target/repro/`.

use super::recorder::Recorder;
use crate::util::json::Json;
use crate::workload::SloClass;

/// Per-class TTFT/SLO breakdown. Present on a [`RunReport`] only when
/// the run actually served batch work — classless runs (every paper
/// figure) report exactly as they did before the field existed.
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub interactive_total: usize,
    pub interactive_ttft_p50_s: f64,
    pub interactive_ttft_p99_s: f64,
    pub interactive_slo: f64,
    pub batch_total: usize,
    pub batch_ttft_p50_s: f64,
    pub batch_ttft_p99_s: f64,
    pub batch_slo: f64,
}

/// Headline numbers of one run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    pub throughput_tps: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    pub completed: usize,
    pub total: usize,
    pub slo_attainment: f64,
    /// `None` unless the run served both SLO classes.
    pub classes: Option<ClassReport>,
}

impl RunReport {
    pub fn from_recorder(label: &str, rec: &Recorder) -> RunReport {
        let ttft_s = crate::config::calib::workload::SLO_TTFT_S;
        let tpot_s = crate::config::calib::workload::SLO_TPOT_S;
        let ttft = rec.ttft_summary();
        let tpot = rec.tpot_summary();
        let classes = if rec.class_total(SloClass::Batch) > 0 {
            let int = rec.ttft_summary_class(SloClass::Interactive);
            let bat = rec.ttft_summary_class(SloClass::Batch);
            Some(ClassReport {
                interactive_total: rec.class_total(SloClass::Interactive),
                interactive_ttft_p50_s: int.p50,
                interactive_ttft_p99_s: int.p99,
                interactive_slo: rec.slo_attainment_class(SloClass::Interactive, ttft_s, tpot_s),
                batch_total: rec.class_total(SloClass::Batch),
                batch_ttft_p50_s: bat.p50,
                batch_ttft_p99_s: bat.p99,
                batch_slo: rec.slo_attainment_class(SloClass::Batch, ttft_s, tpot_s),
            })
        } else {
            None
        };
        RunReport {
            label: label.to_string(),
            throughput_tps: rec.throughput_tps(),
            ttft_p50_s: ttft.p50,
            ttft_p99_s: ttft.p99,
            tpot_p50_s: tpot.p50,
            tpot_p99_s: tpot.p99,
            completed: rec.completed(),
            total: rec.total(),
            slo_attainment: rec.slo_attainment(ttft_s, tpot_s),
            classes,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", self.label.as_str())
            .set("throughput_tps", self.throughput_tps)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("tpot_p50_s", self.tpot_p50_s)
            .set("tpot_p99_s", self.tpot_p99_s)
            .set("completed", self.completed)
            .set("total", self.total)
            .set("slo_attainment", self.slo_attainment);
        // Absence-encoded: classless runs serialize exactly as before.
        if let Some(c) = &self.classes {
            let mut cj = Json::obj();
            cj.set("interactive_total", c.interactive_total)
                .set("interactive_ttft_p50_s", c.interactive_ttft_p50_s)
                .set("interactive_ttft_p99_s", c.interactive_ttft_p99_s)
                .set("interactive_slo", c.interactive_slo)
                .set("batch_total", c.batch_total)
                .set("batch_ttft_p50_s", c.batch_ttft_p50_s)
                .set("batch_ttft_p99_s", c.batch_ttft_p99_s)
                .set("batch_slo", c.batch_slo);
            o.set("classes", cj);
        }
        o
    }

    pub fn line(&self) -> String {
        format!(
            "{:<14} tput {:>8.1} tps   TTFT p50 {:>7.3}s p99 {:>7.3}s   TPOT p50 {:>6.1}ms p99 {:>6.1}ms   done {}/{}   SLO {:.1}%",
            self.label,
            self.throughput_tps,
            self.ttft_p50_s,
            self.ttft_p99_s,
            self.tpot_p50_s * 1e3,
            self.tpot_p99_s * 1e3,
            self.completed,
            self.total,
            self.slo_attainment * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimTime;

    #[test]
    fn report_roundtrip() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, SimTime::ZERO, 10, 2);
        rec.on_first_token(1, SimTime::from_secs_f64(1.0));
        rec.on_token(1, SimTime::from_secs_f64(1.1));
        rec.on_finish(1, SimTime::from_secs_f64(1.1));
        let rep = RunReport::from_recorder("test", &rec);
        assert_eq!(rep.completed, 1);
        let j = rep.to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("test"));
        assert!(rep.line().contains("test"));
        // Classless run: no per-class breakdown, no JSON key.
        assert!(rep.classes.is_none());
        assert!(j.get("classes").is_none());
    }

    #[test]
    fn classed_run_reports_per_class_percentiles() {
        let mut rec = Recorder::new();
        rec.on_arrival_classed(1, SimTime::ZERO, 10, 2, SloClass::Interactive);
        rec.on_first_token(1, SimTime::from_secs_f64(1.0));
        rec.on_finish(1, SimTime::from_secs_f64(1.0));
        rec.on_arrival_classed(2, SimTime::ZERO, 10, 2, SloClass::Batch);
        rec.on_first_token(2, SimTime::from_secs_f64(5.0));
        rec.on_finish(2, SimTime::from_secs_f64(5.0));
        let rep = RunReport::from_recorder("classed", &rec);
        let c = rep.classes.as_ref().expect("batch work forces the breakdown");
        assert_eq!((c.interactive_total, c.batch_total), (1, 1));
        assert!((c.interactive_ttft_p50_s - 1.0).abs() < 1e-9);
        assert!((c.batch_ttft_p50_s - 5.0).abs() < 1e-9);
        let j = rep.to_json();
        let cj = j.get("classes").expect("classes key present when classed");
        assert_eq!(cj.get("batch_total").and_then(|v| v.as_u64()), Some(1));
    }
}
