//! Experiment report rendering: summary lines and JSON rows for
//! `target/repro/`.

use super::recorder::Recorder;
use crate::util::json::Json;

/// Headline numbers of one run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    pub throughput_tps: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    pub completed: usize,
    pub total: usize,
    pub slo_attainment: f64,
}

impl RunReport {
    pub fn from_recorder(label: &str, rec: &Recorder) -> RunReport {
        let ttft = rec.ttft_summary();
        let tpot = rec.tpot_summary();
        RunReport {
            label: label.to_string(),
            throughput_tps: rec.throughput_tps(),
            ttft_p50_s: ttft.p50,
            ttft_p99_s: ttft.p99,
            tpot_p50_s: tpot.p50,
            tpot_p99_s: tpot.p99,
            completed: rec.completed(),
            total: rec.total(),
            slo_attainment: rec.slo_attainment(
                crate::config::calib::workload::SLO_TTFT_S,
                crate::config::calib::workload::SLO_TPOT_S,
            ),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", self.label.as_str())
            .set("throughput_tps", self.throughput_tps)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("tpot_p50_s", self.tpot_p50_s)
            .set("tpot_p99_s", self.tpot_p99_s)
            .set("completed", self.completed)
            .set("total", self.total)
            .set("slo_attainment", self.slo_attainment);
        o
    }

    pub fn line(&self) -> String {
        format!(
            "{:<14} tput {:>8.1} tps   TTFT p50 {:>7.3}s p99 {:>7.3}s   TPOT p50 {:>6.1}ms p99 {:>6.1}ms   done {}/{}   SLO {:.1}%",
            self.label,
            self.throughput_tps,
            self.ttft_p50_s,
            self.ttft_p99_s,
            self.tpot_p50_s * 1e3,
            self.tpot_p99_s * 1e3,
            self.completed,
            self.total,
            self.slo_attainment * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimTime;

    #[test]
    fn report_roundtrip() {
        let mut rec = Recorder::new();
        rec.on_arrival(1, SimTime::ZERO, 10, 2);
        rec.on_first_token(1, SimTime::from_secs_f64(1.0));
        rec.on_token(1, SimTime::from_secs_f64(1.1));
        rec.on_finish(1, SimTime::from_secs_f64(1.1));
        let rep = RunReport::from_recorder("test", &rec);
        assert_eq!(rep.completed, 1);
        let j = rep.to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("test"));
        assert!(rep.line().contains("test"));
    }
}
