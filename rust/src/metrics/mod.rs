//! Metrics: per-request TTFT/TPOT/throughput recording and report
//! rendering for the evaluation harness.

pub mod gate;
pub mod recorder;
pub mod report;

pub use gate::{GateReport, GateVerdict};
pub use recorder::{Recorder, RequestRecord};
pub use report::{ClassReport, RunReport};
