//! Metrics: per-request TTFT/TPOT/throughput recording and report
//! rendering for the evaluation harness.

pub mod recorder;
pub mod report;

pub use recorder::{Recorder, RequestRecord};
pub use report::RunReport;
