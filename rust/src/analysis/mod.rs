//! `gyges lint` — a dependency-free static analyser enforcing the
//! determinism contract the repo's byte-identity proofs rest on.
//!
//! The crate's equivalence guarantees (serial==parallel sweeps,
//! shard-merge, streamed replay, kill/resume snapshots, faulted-run
//! determinism, pipeline-vs-legacy lockstep) are only as strong as a
//! set of source-level invariants no general-purpose tool checks:
//! ordered collections in output paths, no wall-clock reads outside the
//! profiling allowlist, bit-exact f64 fingerprinting, registered
//! process globals, `SimError`-surfaced failures, snapshot key parity,
//! and a `[[test]]` table that actually lists every test file. This
//! module machine-checks all of them — see [`rules`] for the rule
//! catalogue (D01–D07) and PERF.md's "Determinism contract" section for
//! the historical bug each rule encodes.
//!
//! Usage: `gyges lint [--strict] [--json] [--root DIR]`. Exit codes:
//! 0 clean, 1 findings, 2 usage/IO error. `--strict` escalates
//! suppression-hygiene warnings (missing reason, unused suppression,
//! malformed marker) to errors; CI runs strict so the tree stays at
//! zero findings, not zero-errors-some-warnings.

pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::cli::Args;

pub use rules::{Finding, Severity};

/// Lint the repo rooted at `root` (the directory holding `Cargo.toml`
/// and `rust/`). Returns the canonical sorted finding list. Rules that
/// need a piece of the tree the root lacks degrade gracefully: D03 is
/// skipped without a `Cargo.toml`, D07 without `snapshot/state.rs` —
/// which is what lets the fixture corpora under
/// `rust/tests/lint_fixtures/` exercise one rule at a time.
pub fn run_lint(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    let mut files = Vec::new();
    let src_root = root.join("rust").join("src");
    if src_root.is_dir() {
        walk_rs(&src_root, &mut files)?;
    }
    files.sort();
    for path in &files {
        let rel = rel_path(root, path);
        let src =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        findings.extend(rules::SourceFile::new(&rel, &src).check());
    }
    let cargo = root.join("Cargo.toml");
    if cargo.is_file() {
        let src =
            fs::read_to_string(&cargo).map_err(|e| format!("read {}: {e}", cargo.display()))?;
        let manifest = rules::parse_manifest("Cargo.toml", &src);
        let test_files = list_test_files(root)?;
        let path_exists = |p: &str| root.join(p).is_file();
        let file_allows_d03 = |p: &str| match fs::read_to_string(root.join(p)) {
            Ok(text) => rules::SourceFile::new(p, &text).allows_anywhere("D03"),
            Err(_) => false,
        };
        findings.extend(rules::d03_check(manifest, &test_files, &path_exists, &file_allows_d03));
    }
    report::sort_findings(&mut findings);
    Ok(findings)
}

/// The `gyges lint` subcommand.
pub fn lint_cli(args: &Args) -> i32 {
    let root = PathBuf::from(args.get_or("root", "."));
    let strict = args.flag("strict");
    let json = args.flag("json");
    match run_lint(&root) {
        Err(e) => {
            eprintln!("gyges lint: {e}");
            2
        }
        Ok(findings) => {
            if json {
                println!("{}", report::render_json(&findings, strict));
            } else {
                print!("{}", report::render_text(&findings, strict));
            }
            report::exit_code(&findings, strict)
        }
    }
}

/// Recursively collect `.rs` files (sorted later; `read_dir` order is
/// platform-dependent and the report must be byte-stable).
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// The repo-relative `rust/tests/*.rs` list, NON-recursive by design:
/// only files directly in `rust/tests/` are candidate test targets, so
/// the lint fixture corpora nested under `rust/tests/lint_fixtures/`
/// never demand `[[test]]` entries of their own.
fn list_test_files(root: &Path) -> Result<Vec<String>, String> {
    let dir = root.join("rust").join("tests");
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    let entries = fs::read_dir(&dir).map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if !path.is_file() || !path.extension().map(|x| x == "rs").unwrap_or(false) {
            continue;
        }
        if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
            out.push(format!("rust/tests/{name}"));
        }
    }
    out.sort();
    Ok(out)
}

/// Forward-slashed path of `path` relative to `root` (the rule
/// registries match on `rust/src/...` literals, also on Windows).
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}
