//! Deterministic rendering of lint findings (text and JSON) plus the
//! exit-code policy.
//!
//! Findings are always emitted sorted by `(path, line, rule)` so two
//! runs over the same tree produce byte-identical reports — the linter
//! holds itself to the contract it enforces.

use crate::util::Json;

use super::rules::{Finding, Severity};

/// Sort findings into the canonical report order.
pub fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
}

/// Count `(errors, warnings)` under the given strictness. `--strict`
/// escalates every warning (suppression hygiene) to an error.
pub fn tally(findings: &[Finding], strict: bool) -> (usize, usize) {
    let mut errors = 0;
    let mut warnings = 0;
    for f in findings {
        match f.severity {
            Severity::Error => errors += 1,
            Severity::Warning if strict => errors += 1,
            Severity::Warning => warnings += 1,
        }
    }
    (errors, warnings)
}

/// Process exit code: 0 clean, 1 findings. (IO/usage errors are 2,
/// decided by the CLI wrapper.)
pub fn exit_code(findings: &[Finding], strict: bool) -> i32 {
    let (errors, _) = tally(findings, strict);
    if errors > 0 {
        1
    } else {
        0
    }
}

/// Human-readable report, one `path:line: severity[rule] msg` per line,
/// ending with a summary line.
pub fn render_text(findings: &[Finding], strict: bool) -> String {
    let mut out = String::new();
    for f in findings {
        let sev = effective_severity(f, strict);
        out.push_str(&format!("{}:{}: {sev}[{}] {}\n", f.path, f.line, f.rule, f.msg));
    }
    let (errors, warnings) = tally(findings, strict);
    out.push_str(&format!(
        "gyges lint: {errors} error(s), {warnings} warning(s){}\n",
        if strict { " [strict]" } else { "" }
    ));
    out
}

/// Machine-readable report (the CI artifact).
pub fn render_json(findings: &[Finding], strict: bool) -> Json {
    let (errors, warnings) = tally(findings, strict);
    let rows: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = Json::obj();
            o.set("rule", f.rule)
                .set("severity", effective_severity(f, strict).to_string())
                .set("path", f.path.as_str())
                .set("line", f.line)
                .set("msg", f.msg.as_str());
            o
        })
        .collect();
    let mut doc = Json::obj();
    doc.set("schema", "gyges-lint-v1")
        .set("strict", strict)
        .set("errors", errors as u64)
        .set("warnings", warnings as u64)
        .set("ok", errors == 0)
        .set("findings", Json::Arr(rows));
    doc
}

fn effective_severity(f: &Finding, strict: bool) -> Severity {
    if strict && f.severity == Severity::Warning {
        Severity::Error
    } else {
        f.severity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, sev: Severity, path: &str, line: u32) -> Finding {
        Finding { rule, severity: sev, path: path.to_string(), line, msg: "m".to_string() }
    }

    #[test]
    fn strict_escalates_warnings() {
        let fs = vec![finding("S02", Severity::Warning, "a.rs", 3)];
        assert_eq!(exit_code(&fs, false), 0);
        assert_eq!(exit_code(&fs, true), 1);
        assert_eq!(tally(&fs, true), (1, 0));
        assert!(render_text(&fs, true).contains("error[S02]"));
        assert!(render_text(&fs, false).contains("warning[S02]"));
    }

    #[test]
    fn sorted_and_summarised() {
        let mut fs = vec![
            finding("D06", Severity::Error, "b.rs", 9),
            finding("D01", Severity::Error, "a.rs", 2),
        ];
        sort_findings(&mut fs);
        let text = render_text(&fs, false);
        let a = text.find("a.rs:2").unwrap();
        let b = text.find("b.rs:9").unwrap();
        assert!(a < b);
        assert!(text.ends_with("gyges lint: 2 error(s), 0 warning(s)\n"));
    }

    #[test]
    fn json_shape() {
        let fs = vec![finding("D01", Severity::Error, "a.rs", 2)];
        let doc = render_json(&fs, false);
        assert_eq!(doc.get("schema").and_then(|j| j.as_str()), Some("gyges-lint-v1"));
        assert_eq!(doc.get("errors").and_then(|j| j.as_u64()), Some(1));
        let rows = doc.get("findings").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("rule").and_then(|j| j.as_str()), Some("D01"));
    }
}
