//! A lightweight, comment- and string-aware Rust tokenizer.
//!
//! This is NOT a full Rust lexer — it is exactly enough for the
//! determinism linter's rules ([`super::rules`]): identifiers,
//! lifetimes, string/char/numeric literals, and single-character
//! punctuation, each tagged with its 1-based source line. Comments are
//! lexed (including nesting for `/* */`) but kept in a *separate*
//! stream so rules never match inside them, while the suppression
//! scanner (`// gyges-lint: allow(...)`) can still read them.
//!
//! Handled literal forms: cooked strings with escapes, raw strings
//! (`r"…"`, `r#"…"#`, any hash depth), byte/C-string prefixes (`b`,
//! `br`, `c`, `cr`), byte chars (`b'x'`), char literals vs lifetimes
//! (`'x'` vs `'static`), and integer/float numerics with radix
//! prefixes, `_` separators, exponents, and type suffixes. Raw
//! identifiers (`r#match`) lex as plain identifiers.

/// One lexed token (comments excluded — see [`Comment`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    /// `'a`, `'static` — distinguished from char literals so `&'static
    /// str` never looks like a `static` item to rule D05.
    Lifetime(String),
    /// Cooked value with common escapes resolved (raw strings verbatim).
    Str(String),
    Char,
    Num {
        text: String,
        float: bool,
    },
    Punct(char),
}

/// A token plus the 1-based line it starts on.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// A comment, kept out of the token stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: u32,
    /// Comment text without delimiters (`//`, `/* */`), untrimmed.
    pub text: String,
    /// True when no code token precedes the comment on its start line —
    /// a standalone suppression covers the line below instead.
    pub standalone: bool,
}

/// Tokenize `src`, returning code tokens and comments separately.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    Lexer { b: src.as_bytes(), i: 0, line: 1, last_code_line: 0 }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    /// Line of the most recent code token (for `Comment::standalone`).
    last_code_line: u32,
}

impl Lexer<'_> {
    fn run(mut self) -> (Vec<Token>, Vec<Comment>) {
        let mut toks = Vec::new();
        let mut comments = Vec::new();
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => {
                    let start = self.line;
                    let standalone = self.last_code_line != self.line;
                    self.i += 2;
                    let text = self.take_until_newline();
                    comments.push(Comment { line: start, text, standalone });
                }
                b'/' if self.peek(1) == Some(b'*') => {
                    let start = self.line;
                    let standalone = self.last_code_line != self.line;
                    let text = self.block_comment();
                    comments.push(Comment { line: start, text, standalone });
                }
                b'"' => {
                    let line = self.line;
                    let s = self.cooked_string();
                    self.emit(&mut toks, Tok::Str(s), line);
                }
                b'\'' => {
                    let line = self.line;
                    let t = self.char_or_lifetime();
                    self.emit(&mut toks, t, line);
                }
                b'0'..=b'9' => {
                    let line = self.line;
                    let t = self.number();
                    self.emit(&mut toks, t, line);
                }
                c if c == b'_' || c.is_ascii_alphabetic() => {
                    let line = self.line;
                    let t = self.ident_or_prefixed_literal();
                    self.emit(&mut toks, t, line);
                }
                c => {
                    let line = self.line;
                    self.i += 1;
                    self.emit(&mut toks, Tok::Punct(c as char), line);
                }
            }
        }
        (toks, comments)
    }

    fn emit(&mut self, toks: &mut Vec<Token>, tok: Tok, line: u32) {
        self.last_code_line = self.line;
        toks.push(Token { tok, line });
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn take_until_newline(&mut self) -> String {
        let start = self.i;
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        String::from_utf8_lossy(&self.b[start..self.i]).into_owned()
    }

    /// `/* … */` with nesting, cursor on the opening `/`.
    fn block_comment(&mut self) -> String {
        self.i += 2;
        let start = self.i;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            if self.b[self.i] == b'\n' {
                self.line += 1;
                self.i += 1;
            } else if self.b[self.i] == b'/' && self.peek(1) == Some(b'*') {
                depth += 1;
                self.i += 2;
            } else if self.b[self.i] == b'*' && self.peek(1) == Some(b'/') {
                depth -= 1;
                self.i += 2;
            } else {
                self.i += 1;
            }
        }
        let end = if depth == 0 { self.i - 2 } else { self.i };
        String::from_utf8_lossy(&self.b[start..end]).into_owned()
    }

    /// Cooked string, cursor on the opening quote. Resolves the escapes
    /// the linter's key-parity rule can meet in practice; unknown
    /// escapes keep the escaped character verbatim.
    fn cooked_string(&mut self) -> String {
        self.i += 1;
        let mut out = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    out.push('\n');
                    self.i += 1;
                }
                b'\\' => {
                    self.i += 1;
                    match self.b.get(self.i).copied() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'0') => out.push('\0'),
                        Some(b'\n') => self.line += 1, // line-continuation
                        Some(c) => out.push(c as char),
                        None => {}
                    }
                    self.i += 1;
                }
                c => {
                    out.push(c as char);
                    self.i += 1;
                }
            }
        }
        out
    }

    /// Raw string body, cursor just past `r` and any prefix letters;
    /// `hashes` is the number of `#` before the opening quote.
    fn raw_string(&mut self, hashes: usize) -> String {
        self.i += hashes + 1; // the #s and the opening quote
        let start = self.i;
        while self.i < self.b.len() {
            if self.b[self.i] == b'\n' {
                self.line += 1;
            } else if self.b[self.i] == b'"' {
                let tail = &self.b[self.i + 1..];
                if tail.len() >= hashes && tail[..hashes].iter().all(|&h| h == b'#') {
                    let end = self.i;
                    self.i += 1 + hashes;
                    return String::from_utf8_lossy(&self.b[start..end]).into_owned();
                }
            }
            self.i += 1;
        }
        String::from_utf8_lossy(&self.b[start..]).into_owned()
    }

    /// `'x'` / `'\n'` vs `'static`, cursor on the quote.
    fn char_or_lifetime(&mut self) -> Tok {
        self.i += 1;
        match self.b.get(self.i).copied() {
            Some(b'\\') => {
                // Escaped char literal: skip the escape, find the close.
                self.i += 2;
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    self.i += 1;
                }
                self.i += 1;
                Tok::Char
            }
            Some(c) if c == b'_' || c.is_ascii_alphanumeric() => {
                let start = self.i;
                while self
                    .peek(0)
                    .map(|c| c == b'_' || c.is_ascii_alphanumeric())
                    .unwrap_or(false)
                {
                    self.i += 1;
                }
                if self.peek(0) == Some(b'\'') {
                    self.i += 1;
                    Tok::Char
                } else {
                    let name = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
                    Tok::Lifetime(name)
                }
            }
            Some(_) => {
                // Punctuation char literal like '{'.
                self.i += 1;
                if self.peek(0) == Some(b'\'') {
                    self.i += 1;
                }
                Tok::Char
            }
            None => Tok::Char,
        }
    }

    fn number(&mut self) -> Tok {
        let start = self.i;
        let mut float = false;
        let radix_prefixed = self.b[self.i] == b'0'
            && matches!(self.peek(1), Some(b'x' | b'X' | b'o' | b'O' | b'b' | b'B'));
        if radix_prefixed {
            self.i += 2;
            while self
                .peek(0)
                .map(|c| c == b'_' || c.is_ascii_alphanumeric())
                .unwrap_or(false)
            {
                self.i += 1;
            }
        } else {
            self.digits();
            if self.peek(0) == Some(b'.')
                && self.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false)
            {
                float = true;
                self.i += 1;
                self.digits();
            }
            if matches!(self.peek(0), Some(b'e' | b'E')) {
                let sign = matches!(self.peek(1), Some(b'+' | b'-'));
                let digit_at = if sign { 2 } else { 1 };
                if self.peek(digit_at).map(|c| c.is_ascii_digit()).unwrap_or(false) {
                    float = true;
                    self.i += 1 + usize::from(sign);
                    self.digits();
                }
            }
            // Type suffix (u64, f64, usize, …).
            let suffix_start = self.i;
            while self
                .peek(0)
                .map(|c| c == b'_' || c.is_ascii_alphanumeric())
                .unwrap_or(false)
            {
                self.i += 1;
            }
            let suffix = &self.b[suffix_start..self.i];
            if suffix.starts_with(b"f32") || suffix.starts_with(b"f64") {
                float = true;
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        Tok::Num { text, float }
    }

    fn digits(&mut self) {
        while self.peek(0).map(|c| c == b'_' || c.is_ascii_digit()).unwrap_or(false) {
            self.i += 1;
        }
    }

    fn ident_or_prefixed_literal(&mut self) -> Tok {
        let start = self.i;
        while self
            .peek(0)
            .map(|c| c == b'_' || c.is_ascii_alphanumeric())
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let name = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        let raw_capable = matches!(name.as_str(), "r" | "br" | "cr");
        let cooked_capable = matches!(name.as_str(), "b" | "c");
        match self.peek(0) {
            Some(b'"') if raw_capable => Tok::Str(self.raw_string(0)),
            Some(b'"') if cooked_capable => Tok::Str(self.cooked_string()),
            Some(b'\'') if name == "b" => self.char_or_lifetime(),
            Some(b'#') if raw_capable || name == "r" => {
                let mut hashes = 0;
                while self.peek(hashes) == Some(b'#') {
                    hashes += 1;
                }
                if self.peek(hashes) == Some(b'"') {
                    Tok::Str(self.raw_string(hashes))
                } else if name == "r" && hashes == 1 {
                    // Raw identifier r#ident: re-lex the ident part.
                    self.i += 1;
                    let istart = self.i;
                    while self
                        .peek(0)
                        .map(|c| c == b'_' || c.is_ascii_alphanumeric())
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    Tok::Ident(String::from_utf8_lossy(&self.b[istart..self.i]).into_owned())
                } else {
                    Tok::Ident(name)
                }
            }
            _ => Tok::Ident(name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_are_not_code() {
        let src = r##"
            // HashMap in a comment
            /* Instant::now in /* a nested */ block */
            let s = "HashMap::new()";
            let r = r#"SystemTime "quoted""#;
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|i| i == "HashMap" || i == "Instant" || i == "SystemTime"));
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("HashMap"));
        assert!(comments[1].text.contains("nested"));
        let strs: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, vec!["HashMap::new()".to_string(), "SystemTime \"quoted\"".into()]);
    }

    #[test]
    fn lifetimes_are_not_statics_or_chars() {
        let (toks, _) = lex("fn f() -> &'static str { 'x' } 'a: loop {}");
        let lifetimes: Vec<_> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Lifetime(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(lifetimes, vec!["static", "a"]);
        assert_eq!(toks.iter().filter(|t| t.tok == Tok::Char).count(), 1);
        assert!(!toks.iter().any(|t| t.tok == Tok::Ident("static".into())));
    }

    #[test]
    fn numbers_classify_floats() {
        let (toks, _) = lex("0xFE 1_000 1.5 2e3 2.0e-3 7f64 3u64 v.0.to_bits() 0..10");
        let nums: Vec<(String, bool)> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num { text, float } => Some((text.clone(), *float)),
                _ => None,
            })
            .collect();
        assert_eq!(
            nums,
            vec![
                ("0xFE".to_string(), false),
                ("1_000".into(), false),
                ("1.5".into(), true),
                ("2e3".into(), true),
                ("2.0e-3".into(), true),
                ("7f64".into(), true),
                ("3u64".into(), false),
                ("0".into(), false),
                ("0".into(), false),
                ("10".into(), false),
            ]
        );
    }

    #[test]
    fn string_escapes_resolve_for_key_parity() {
        let (toks, _) = lex(r#"set("a\"b\\c")"#);
        assert!(toks.iter().any(|t| t.tok == Tok::Str("a\"b\\c".into())));
    }

    #[test]
    fn lines_and_standalone_flags() {
        let src = "let a = 1; // trailing\n// standalone\nlet b = 2;\n";
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        assert!(!comments[0].standalone);
        assert_eq!(comments[0].line, 1);
        assert!(comments[1].standalone);
        assert_eq!(comments[1].line, 2);
        let b = toks.iter().find(|t| t.tok == Tok::Ident("b".into())).unwrap();
        assert_eq!(b.line, 3);
    }

    #[test]
    fn byte_and_raw_forms() {
        let (toks, _) = lex(r##"b"bytes" b'x' r#"raw # body"# r#match"##);
        assert!(toks.iter().any(|t| t.tok == Tok::Str("bytes".into())));
        assert!(toks.iter().any(|t| t.tok == Tok::Str("raw # body".into())));
        assert!(toks.iter().any(|t| t.tok == Tok::Ident("match".into())));
        assert_eq!(toks.iter().filter(|t| t.tok == Tok::Char).count(), 1);
    }
}
