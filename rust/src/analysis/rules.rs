//! The determinism-contract rules (D01–D07) and the suppression engine.
//!
//! Every rule encodes an invariant the repo's byte-identity proofs
//! (serial==parallel sweeps, shard-merge, streamed replay, kill/resume,
//! faulted-run determinism, pipeline-vs-legacy lockstep) silently rely
//! on, each grounded in a real past bug or a PERF.md contract:
//!
//! - **D01** no `HashMap`/`HashSet` in determinism-critical dirs —
//!   iteration order leaks into output bytes; use `BTreeMap`/`BTreeSet`.
//! - **D02** `Instant`/`SystemTime` only in the profiling/stats/serve
//!   allowlist — a wall-clock read anywhere else breaks replayability.
//! - **D03** the Cargo.toml `[[test]]` table and `rust/tests/*.rs` agree
//!   in BOTH directions (tests live outside `./tests`, so Cargo
//!   autodiscovers nothing: an unlisted file silently never compiles —
//!   exactly how `faults.rs`/`queue_equivalence.rs` went dark for two
//!   PRs). Dangling `[[bench]]`/`[[example]]` paths are checked too.
//! - **D04** f64 values reaching the fingerprint functions
//!   (`config_fingerprint`, `fingerprint_into`, `job_list_hash`) hash
//!   their exact bit patterns via `.to_bits()` — formatting or implicit
//!   widening would alias distinct configs.
//! - **D05** process-global mutable statics only at registered sites —
//!   an unregistered global silently bypasses snapshot/resume.
//! - **D06** `.unwrap()`/`.expect()` banned in `sim/` + `coordinator/`
//!   non-test code — error paths must surface through `SimError`.
//! - **D07** snapshot write/read key parity in `snapshot/state.rs` —
//!   a key written by `.set(...)` but never read back (or required on
//!   restore but never written) is one-sided schema drift.
//!
//! Findings are suppressed inline with
//! `// gyges-lint: allow(D0x[, D0y]) <reason>` — trailing on the
//! offending line or standalone on the line directly above it. The
//! reason is mandatory (S01) and unused suppressions are flagged (S02);
//! both are warnings that `--strict` escalates to errors.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use super::lexer::{lex, Tok, Token};

/// Directories where rule D01 (no hash collections) applies.
pub const D01_DIRS: [&str; 6] = [
    "rust/src/sim/",
    "rust/src/coordinator/",
    "rust/src/snapshot/",
    "rust/src/experiments/",
    "rust/src/workload/",
    "rust/src/cache/",
];

/// Collection types D01 rejects.
pub const D01_TYPES: [&str; 2] = ["HashMap", "HashSet"];

/// Files allowed to read wall clocks (D02): the opt-in profiling arm,
/// the stats helpers that feed it, and the real-model serving path
/// (which measures actual hardware, not simulated time).
pub const D02_ALLOW: [&str; 3] = [
    "rust/src/coordinator/cluster.rs",
    "rust/src/util/stats.rs",
    "rust/src/serve/mod.rs",
];

/// Function names whose bodies rule D04 audits.
pub const D04_FNS: [&str; 3] = ["config_fingerprint", "fingerprint_into", "job_list_hash"];

/// f64 config/workload knobs that may appear inside a fingerprint
/// function only as `<knob>.to_bits()`.
pub const D04_KNOBS: [&str; 16] = [
    "scale_down_threshold",
    "slo_interactive_deadline_s",
    "slo_batch_deadline_s",
    "min_dwell_s",
    "backlog_retry_cooldown_s",
    "retry_backoff_base_s",
    "qps",
    "segment_s",
    "horizon_s",
    "quiet_rate",
    "burst_rate",
    "quiet_mean_s",
    "burst_mean_s",
    "interactive_frac",
    "reserve_cap",
    "long_hold_s",
];

/// The registered process-global mutable statics (D05). Each entry is
/// `(file, item name)`; the rationale for every registration lives in
/// PERF.md's "Determinism contract" section.
pub const D05_REGISTRY: [(&str, &str); 4] = [
    ("rust/src/sim/event.rs", "DEFAULT_BACKEND"),
    ("rust/src/sim/engine.rs", "COEFFS"),
    ("rust/src/coordinator/scheduler.rs", "LEGACY_ROUTING"),
    ("rust/src/util/logging.rs", "MAX_LEVEL"),
];

/// Directories where rule D06 (no unwrap/expect) applies.
pub const D06_DIRS: [&str; 2] = ["rust/src/sim/", "rust/src/coordinator/"];

/// The one file rule D07 (snapshot key parity) audits.
pub const D07_FILE: &str = "rust/src/snapshot/state.rs";

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// One lint finding, attached to a repo-relative path and 1-based line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

/// A parsed `gyges-lint: allow(...)` comment.
struct Suppression {
    codes: Vec<String>,
    /// Line whose findings this suppression covers.
    covers: u32,
    /// Line of the comment itself (for S02 reporting).
    line: u32,
    used: bool,
}

/// Parse the body of a suppression marker (text after the comment
/// delimiter). Returns `(codes, has_reason)`, or None if malformed.
fn parse_marker(text: &str) -> Option<(Vec<String>, bool)> {
    let rest = text.trim().strip_prefix("gyges-lint:")?.trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let codes: Vec<String> = rest[..close]
        .split(',')
        .map(|c| c.trim().to_string())
        .filter(|c| !c.is_empty())
        .collect();
    if codes.is_empty() {
        return None;
    }
    let has_reason = !rest[close + 1..].trim().is_empty();
    Some((codes, has_reason))
}

/// Shared suppression book-keeping for one file (Rust source or TOML).
struct SuppressionSet {
    rel: String,
    sups: Vec<Suppression>,
    hygiene: Vec<Finding>,
}

impl SuppressionSet {
    fn new(rel: &str) -> Self {
        SuppressionSet { rel: rel.to_string(), sups: Vec::new(), hygiene: Vec::new() }
    }

    /// Record one comment. `standalone` comments cover the next line;
    /// trailing comments cover their own line.
    fn add_comment(&mut self, line: u32, standalone: bool, text: &str) {
        if !text.trim_start().starts_with("gyges-lint") {
            return;
        }
        match parse_marker(text) {
            Some((codes, has_reason)) => {
                if !has_reason {
                    self.hygiene.push(Finding {
                        rule: "S01",
                        severity: Severity::Warning,
                        path: self.rel.clone(),
                        line,
                        msg: "suppression without a reason \
                              (write `gyges-lint: allow(<rule>) <why>`)"
                            .to_string(),
                    });
                }
                let covers = if standalone { line + 1 } else { line };
                self.sups.push(Suppression { codes, covers, line, used: false });
            }
            None => self.hygiene.push(Finding {
                rule: "S03",
                severity: Severity::Warning,
                path: self.rel.clone(),
                line,
                msg: "malformed gyges-lint comment \
                      (expected `gyges-lint: allow(D0x[, ...]) <reason>`)"
                    .to_string(),
            }),
        }
    }

    /// True if a finding for `rule` on `line` is suppressed; marks the
    /// matching suppression(s) used.
    fn suppress(&mut self, line: u32, rule: &str) -> bool {
        let mut hit = false;
        for s in &mut self.sups {
            if s.covers == line && s.codes.iter().any(|c| c == rule) {
                s.used = true;
                hit = true;
            }
        }
        hit
    }

    /// Filter raw findings through the suppressions, then append the
    /// hygiene findings (S01/S03 from parsing, S02 for unused).
    fn finish(mut self, raw: Vec<Finding>) -> Vec<Finding> {
        let mut out = Vec::new();
        for f in raw {
            if !self.suppress(f.line, f.rule) {
                out.push(f);
            }
        }
        for s in &self.sups {
            if !s.used {
                out.push(Finding {
                    rule: "S02",
                    severity: Severity::Warning,
                    path: self.rel.clone(),
                    line: s.line,
                    msg: format!("unused suppression for {}", s.codes.join(", ")),
                });
            }
        }
        out.extend(self.hygiene);
        out
    }
}

/// One analysed Rust source file: lexed tokens, `#[cfg(test)]` spans,
/// and its suppression comments.
pub struct SourceFile {
    rel: String,
    toks: Vec<Token>,
    test_spans: Vec<(u32, u32)>,
    sups: SuppressionSet,
}

impl SourceFile {
    pub fn new(rel: &str, src: &str) -> Self {
        let (toks, comments) = lex(src);
        let mut sups = SuppressionSet::new(rel);
        for c in &comments {
            sups.add_comment(c.line, c.standalone, &c.text);
        }
        let test_spans = test_spans(&toks);
        SourceFile { rel: rel.to_string(), toks, test_spans, sups }
    }

    /// True when the file carries any `allow(rule)` marker at all —
    /// used for file-scoped D03 suppression on orphan test files.
    pub fn allows_anywhere(&self, rule: &str) -> bool {
        self.sups.sups.iter().any(|s| s.codes.iter().any(|c| c == rule))
    }

    /// Run every per-file rule and resolve suppressions.
    pub fn check(self) -> Vec<Finding> {
        let mut raw = Vec::new();
        self.d01(&mut raw);
        self.d02(&mut raw);
        self.d04(&mut raw);
        self.d05(&mut raw);
        self.d06(&mut raw);
        if self.rel == D07_FILE {
            self.d07(&mut raw);
        }
        self.sups.finish(raw)
    }

    fn finding(&self, rule: &'static str, line: u32, msg: String) -> Finding {
        Finding { rule, severity: Severity::Error, path: self.rel.clone(), line, msg }
    }

    fn in_tests(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| line >= a && line <= b)
    }

    fn d01(&self, out: &mut Vec<Finding>) {
        if !D01_DIRS.iter().any(|d| self.rel.starts_with(d)) {
            return;
        }
        for t in &self.toks {
            if let Tok::Ident(name) = &t.tok {
                if D01_TYPES.contains(&name.as_str()) {
                    out.push(self.finding(
                        "D01",
                        t.line,
                        format!(
                            "{name} in a determinism-critical dir (iteration order leaks \
                             into output bytes); use BTreeMap/BTreeSet"
                        ),
                    ));
                }
            }
        }
    }

    fn d02(&self, out: &mut Vec<Finding>) {
        if D02_ALLOW.contains(&self.rel.as_str()) {
            return;
        }
        for t in &self.toks {
            if let Tok::Ident(name) = &t.tok {
                if name == "Instant" || name == "SystemTime" {
                    out.push(self.finding(
                        "D02",
                        t.line,
                        format!(
                            "{name} outside the wall-clock allowlist; simulated runs must \
                             be replayable (allowlist: {})",
                            D02_ALLOW.join(", ")
                        ),
                    ));
                }
            }
        }
    }

    fn d04(&self, out: &mut Vec<Finding>) {
        let toks = &self.toks;
        let mut i = 0;
        while i + 1 < toks.len() {
            let is_fn = matches!(&toks[i].tok, Tok::Ident(s) if s == "fn");
            let audited =
                matches!(&toks[i + 1].tok, Tok::Ident(s) if D04_FNS.contains(&s.as_str()));
            if !(is_fn && audited) {
                i += 1;
                continue;
            }
            // Body = first `{` after the signature, brace-balanced.
            let mut j = i + 2;
            while j < toks.len() && !matches!(toks[j].tok, Tok::Punct('{')) {
                j += 1;
            }
            let start = j;
            let mut depth = 0usize;
            while j < toks.len() {
                match toks[j].tok {
                    Tok::Punct('{') => depth += 1,
                    Tok::Punct('}') => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            let end = j.min(toks.len());
            self.d04_body(&toks[start..end], out);
            i = end + 1;
        }
    }

    fn d04_body(&self, body: &[Token], out: &mut Vec<Finding>) {
        let to_bits_at = |from: usize| {
            matches!(body.get(from), Some(t) if t.tok == Tok::Punct('.'))
                && matches!(body.get(from + 1), Some(t)
                    if matches!(&t.tok, Tok::Ident(s) if s == "to_bits"))
        };
        for (k, t) in body.iter().enumerate() {
            match &t.tok {
                Tok::Ident(s) if s == "as_secs_f64" => {
                    let ok = matches!(body.get(k + 1), Some(t) if t.tok == Tok::Punct('('))
                        && matches!(body.get(k + 2), Some(t) if t.tok == Tok::Punct(')'))
                        && to_bits_at(k + 3);
                    if !ok {
                        out.push(self.finding(
                            "D04",
                            t.line,
                            "as_secs_f64() reaches a fingerprint without .to_bits(); \
                             hash exact bit patterns"
                                .to_string(),
                        ));
                    }
                }
                Tok::Ident(s) if D04_KNOBS.contains(&s.as_str()) => {
                    if !to_bits_at(k + 1) {
                        out.push(self.finding(
                            "D04",
                            t.line,
                            format!("f64 knob `{s}` reaches a fingerprint without .to_bits()"),
                        ));
                    }
                }
                Tok::Num { text, float: true } => {
                    if !to_bits_at(k + 1) {
                        out.push(self.finding(
                            "D04",
                            t.line,
                            format!(
                                "float literal {text} in a fingerprint fn; hash exact bit \
                                 patterns via .to_bits()"
                            ),
                        ));
                    }
                }
                _ => {}
            }
        }
    }

    fn d05(&self, out: &mut Vec<Finding>) {
        let toks = &self.toks;
        for (i, t) in toks.iter().enumerate() {
            if !matches!(&t.tok, Tok::Ident(s) if s == "static") {
                continue;
            }
            // Item name: next ident, skipping `mut`. (`&'static` lexes
            // as a Lifetime token, so it never reaches this point.)
            let mut name = None;
            let mut j = i + 1;
            while let Some(n) = toks.get(j) {
                match &n.tok {
                    Tok::Ident(s) if s == "mut" => j += 1,
                    Tok::Ident(s) => {
                        name = Some(s.clone());
                        break;
                    }
                    _ => break,
                }
            }
            let Some(name) = name else { continue };
            let registered =
                D05_REGISTRY.iter().any(|&(p, n)| p == self.rel && n == name);
            if !registered {
                out.push(self.finding(
                    "D05",
                    t.line,
                    format!(
                        "unregistered process-global `static {name}` (globals bypass \
                         snapshot/resume; register it in analysis::rules::D05_REGISTRY \
                         and document it in PERF.md)"
                    ),
                ));
            }
        }
    }

    fn d06(&self, out: &mut Vec<Finding>) {
        if !D06_DIRS.iter().any(|d| self.rel.starts_with(d)) {
            return;
        }
        for i in 1..self.toks.len() {
            let t = &self.toks[i];
            let name = match &t.tok {
                Tok::Ident(s) if s == "unwrap" || s == "expect" => s,
                _ => continue,
            };
            if self.toks[i - 1].tok != Tok::Punct('.') || self.in_tests(t.line) {
                continue;
            }
            out.push(self.finding(
                "D06",
                t.line,
                format!(
                    ".{name}() in non-test sim/coordinator code; surface the error \
                     through SimError"
                ),
            ));
        }
    }

    fn d07(&self, out: &mut Vec<Finding>) {
        // Writes: first string literal after a `set(` call. Reads: first
        // string-literal argument of any other call (`get`, `req_*`, and
        // the restore helper closures like `num(...)`/`times(...)`).
        let mut writes: BTreeMap<String, u32> = BTreeMap::new();
        let mut reads: BTreeSet<String> = BTreeSet::new();
        let mut required: Vec<(String, u32)> = Vec::new();
        let toks = &self.toks;
        for i in 0..toks.len() {
            let name = match &toks[i].tok {
                Tok::Ident(s) => s,
                _ => continue,
            };
            if self.in_tests(toks[i].line) {
                continue;
            }
            if !matches!(toks.get(i + 1), Some(t) if t.tok == Tok::Punct('(')) {
                continue;
            }
            let key = match toks.get(i + 2) {
                Some(t) => match &t.tok {
                    Tok::Str(s) => s.clone(),
                    _ => continue,
                },
                None => continue,
            };
            if name == "set" {
                writes.entry(key).or_insert(toks[i].line);
            } else {
                if name.starts_with("req_") {
                    required.push((key.clone(), toks[i].line));
                }
                reads.insert(key);
            }
        }
        for (key, line) in &writes {
            if !reads.contains(key) {
                out.push(self.finding(
                    "D07",
                    *line,
                    format!(
                        "snapshot key {key:?} is written but never read on restore \
                         (one-sided schema drift)"
                    ),
                ));
            }
        }
        for (key, line) in required {
            if !writes.contains_key(&key) {
                out.push(self.finding(
                    "D07",
                    line,
                    format!("restore requires snapshot key {key:?} that is never written"),
                ));
            }
        }
    }
}

/// `#[cfg(test)]` item spans as inclusive `(start, end)` line ranges.
/// The span runs from the attribute to the matching close brace of the
/// next braced item (or to a `;` for brace-less items).
fn test_spans(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i + 6 < toks.len() {
        let is_attr = toks[i].tok == Tok::Punct('#')
            && toks[i + 1].tok == Tok::Punct('[')
            && matches!(&toks[i + 2].tok, Tok::Ident(s) if s == "cfg")
            && toks[i + 3].tok == Tok::Punct('(')
            && matches!(&toks[i + 4].tok, Tok::Ident(s) if s == "test")
            && toks[i + 5].tok == Tok::Punct(')')
            && toks[i + 6].tok == Tok::Punct(']');
        if !is_attr {
            i += 1;
            continue;
        }
        let start = toks[i].line;
        let mut end = toks[i + 6].line;
        let mut depth = 0usize;
        let mut j = i + 7;
        while j < toks.len() {
            match toks[j].tok {
                Tok::Punct('{') => depth += 1,
                Tok::Punct('}') if depth > 0 => {
                    depth -= 1;
                    if depth == 0 {
                        end = toks[j].line;
                        break;
                    }
                }
                Tok::Punct(';') if depth == 0 => {
                    end = toks[j].line;
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        if j >= toks.len() {
            end = toks.last().map(|t| t.line).unwrap_or(start);
        }
        spans.push((start, end));
        i = j + 1;
    }
    spans
}

// ---------------------------------------------------------------------
// D03: Cargo.toml [[test]] table vs rust/tests/*.rs, both directions
// ---------------------------------------------------------------------

/// One `[[test]]`/`[[bench]]`/`[[example]]` entry from Cargo.toml.
pub struct TargetEntry {
    pub kind: String,
    pub name: String,
    pub path: String,
    /// Line of the `[[kind]]` header (fallback finding anchor).
    pub line: u32,
    /// Line of the `path = ...` assignment (preferred finding anchor).
    pub path_line: u32,
}

/// Parsed Cargo.toml: target entries plus its suppression comments.
pub struct Manifest {
    pub entries: Vec<TargetEntry>,
    sups: SuppressionSet,
}

/// Minimal TOML scan: array-of-table headers and `name`/`path` string
/// assignments, plus `# gyges-lint: allow(...)` comments (a `#` inside
/// a quoted string does not start a comment).
pub fn parse_manifest(rel: &str, src: &str) -> Manifest {
    let mut entries: Vec<TargetEntry> = Vec::new();
    let mut sups = SuppressionSet::new(rel);
    let mut in_target = false;
    for (idx, raw) in src.lines().enumerate() {
        let line = idx as u32 + 1;
        let (code, comment) = split_toml_comment(raw);
        if let Some(text) = comment {
            sups.add_comment(line, code.trim().is_empty(), text);
        }
        let code = code.trim();
        if code.starts_with('[') {
            in_target = false;
            if let Some(h) = code.strip_prefix("[[").and_then(|h| h.strip_suffix("]]")) {
                let kind = h.trim();
                if matches!(kind, "test" | "bench" | "example") {
                    in_target = true;
                    entries.push(TargetEntry {
                        kind: kind.to_string(),
                        name: String::new(),
                        path: String::new(),
                        line,
                        path_line: line,
                    });
                }
            }
            continue;
        }
        if !in_target {
            continue;
        }
        if let Some((k, v)) = code.split_once('=') {
            let v = v.trim().trim_matches('"').to_string();
            if let Some(e) = entries.last_mut() {
                match k.trim() {
                    "name" => e.name = v,
                    "path" => {
                        e.path = v;
                        e.path_line = line;
                    }
                    _ => {}
                }
            }
        }
    }
    Manifest { entries, sups }
}

/// Split one TOML line into (code, comment text after `#`).
fn split_toml_comment(line: &str) -> (&str, Option<&str>) {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return (&line[..i], Some(&line[i + 1..])),
            _ => {}
        }
    }
    (line, None)
}

/// Rule D03 over a parsed manifest. `test_files` are the repo-relative
/// `rust/tests/*.rs` paths actually on disk (sorted); `path_exists`
/// answers for any manifest path; `file_allows_d03` reports whether an
/// orphan test file carries its own `allow(D03)` marker.
pub fn d03_check(
    manifest: Manifest,
    test_files: &[String],
    path_exists: &dyn Fn(&str) -> bool,
    file_allows_d03: &dyn Fn(&str) -> bool,
) -> Vec<Finding> {
    let mut raw = Vec::new();
    let rel = manifest.sups.rel.clone();
    let listed: BTreeSet<&str> =
        manifest.entries.iter().filter(|e| e.kind == "test").map(|e| e.path.as_str()).collect();
    let mut orphan_findings = Vec::new();
    for f in test_files {
        if !listed.contains(f.as_str()) && !file_allows_d03(f) {
            orphan_findings.push(Finding {
                rule: "D03",
                severity: Severity::Error,
                path: f.clone(),
                line: 1,
                msg: format!(
                    "test file not registered in Cargo.toml's [[test]] table — it will \
                     silently never compile (add `[[test]] name = ... path = {f:?}`)"
                ),
            });
        }
    }
    for e in &manifest.entries {
        if e.path.is_empty() {
            raw.push(Finding {
                rule: "D03",
                severity: Severity::Error,
                path: rel.clone(),
                line: e.line,
                msg: format!(
                    "[[{}]] `{}` has no explicit path (targets live outside the Cargo \
                     default layout, so the path is mandatory)",
                    e.kind, e.name
                ),
            });
        } else if !path_exists(&e.path) {
            raw.push(Finding {
                rule: "D03",
                severity: Severity::Error,
                path: rel.clone(),
                line: e.path_line,
                msg: format!("[[{}]] `{}` points at missing path {:?}", e.kind, e.name, e.path),
            });
        }
    }
    let mut out = manifest.sups.finish(raw);
    out.extend(orphan_findings);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> Vec<Finding> {
        SourceFile::new(rel, src).check()
    }

    fn rules(fs: &[Finding]) -> Vec<&str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn d01_fires_only_in_critical_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules(&check("rust/src/sim/engine.rs", src)), vec!["D01"]);
        assert!(check("rust/src/util/stats.rs", src).is_empty());
    }

    #[test]
    fn d02_allowlist_and_comments() {
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(rules(&check("rust/src/metrics/mod.rs", src)), vec!["D02"]);
        assert!(check("rust/src/util/stats.rs", src).is_empty());
        assert!(check("rust/src/metrics/mod.rs", "// Instant::now in prose\n").is_empty());
    }

    #[test]
    fn d04_flags_bare_knobs_and_floats() {
        let src = "fn fingerprint_into(b: &mut Vec<u8>) {\n\
                   let x = self.qps as u64;\n\
                   let y = 0.5;\n\
                   let ok = self.horizon_s.to_bits();\n\
                   }\n";
        let f = check("rust/src/experiments/x.rs", src);
        assert_eq!(rules(&f), vec!["D04", "D04"]);
        let src_ok = "fn job_list_hash(j: &J) -> u64 {\n\
                      j.arrival.as_secs_f64().to_bits() ^ j.qps.to_bits() ^ 0xFFu64\n\
                      }\n";
        assert!(check("rust/src/experiments/x.rs", src_ok).is_empty());
    }

    #[test]
    fn d05_registry_and_lifetimes() {
        let src = "static NEW_GLOBAL: AtomicU8 = AtomicU8::new(0);\n";
        assert_eq!(rules(&check("rust/src/sim/engine.rs", src)), vec!["D05"]);
        let reg = "static COEFFS: OnceLock<(f64, f64)> = OnceLock::new();\n";
        assert!(check("rust/src/sim/engine.rs", reg).is_empty());
        assert!(check("rust/src/sim/engine.rs", "fn f() -> &'static str { \"x\" }\n").is_empty());
    }

    #[test]
    fn d06_skips_tests_and_unwrap_or() {
        let src = "fn f() { x.unwrap(); y.unwrap_or(0); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g() { z.expect(\"fine in tests\"); }\n\
                   }\n";
        let f = check("rust/src/coordinator/x.rs", src);
        assert_eq!(rules(&f), vec!["D06"]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn suppressions_trailing_standalone_unused() {
        let trailing = "fn f() { x.unwrap(); } // gyges-lint: allow(D06) invariant: nonempty\n";
        assert!(check("rust/src/sim/x.rs", trailing).is_empty());
        let standalone = "// gyges-lint: allow(D06) invariant: nonempty\nfn f() { x.unwrap(); }\n";
        assert!(check("rust/src/sim/x.rs", standalone).is_empty());
        let unused = "// gyges-lint: allow(D06) nothing here\nfn f() {}\n";
        assert_eq!(rules(&check("rust/src/sim/x.rs", unused)), vec!["S02"]);
        let no_reason = "fn f() { x.unwrap(); } // gyges-lint: allow(D06)\n";
        assert_eq!(rules(&check("rust/src/sim/x.rs", no_reason)), vec!["S01"]);
    }

    #[test]
    fn d07_key_parity_both_directions() {
        let src = "fn enc(o: &mut Json) { o.set(\"seen\", 1); o.set(\"lost\", 2); }\n\
                   fn dec(o: &Json) -> R { o.req_u64(\"seen\", \"ctx\")?; \
                   o.req_u64(\"ghost\", \"ctx\") }\n";
        let f = check(D07_FILE, src);
        assert_eq!(rules(&f), vec!["D07", "D07"]);
        assert!(f[0].msg.contains("lost") || f[1].msg.contains("lost"));
        assert!(f[0].msg.contains("ghost") || f[1].msg.contains("ghost"));
    }

    #[test]
    fn d03_both_directions_and_toml_suppression() {
        let toml = "[package]\nname = \"x\"\n\n\
                    [[test]]\nname = \"a\"\npath = \"rust/tests/a.rs\"\n\n\
                    [[test]]\nname = \"gone\"\npath = \"rust/tests/gone.rs\"\n";
        let m = parse_manifest("Cargo.toml", toml);
        let files = vec!["rust/tests/a.rs".to_string(), "rust/tests/orphan.rs".to_string()];
        let exists = |p: &str| p == "rust/tests/a.rs";
        let allows = |_: &str| false;
        let f = d03_check(m, &files, &exists, &allows);
        assert_eq!(rules(&f), vec!["D03", "D03"]);
        assert!(f.iter().any(|x| x.path == "Cargo.toml" && x.msg.contains("gone")));
        assert!(f.iter().any(|x| x.path == "rust/tests/orphan.rs"));
        // A TOML-side suppression covers the dangling entry.
        let toml2 = "[[test]]\nname = \"gone\"\n\
                     # gyges-lint: allow(D03) staged for next PR\n\
                     path = \"rust/tests/gone.rs\"\n";
        let m2 = parse_manifest("Cargo.toml", toml2);
        let f2 = d03_check(m2, &[], &|_| false, &|_| false);
        assert!(f2.is_empty(), "{f2:?}");
    }

    #[test]
    fn cfg_test_span_covers_nested_braces() {
        let src = "#[cfg(test)]\nmod tests {\n fn a() { if x { y.unwrap(); } }\n}\n\
                   fn out() { z.unwrap(); }\n";
        let f = check("rust/src/sim/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 5);
    }
}
