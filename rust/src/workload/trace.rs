//! Trace generation and replay: the request streams driving §6.2.4
//! (hybrid short/long) and §6.3 (production-like end-to-end).

use super::arrivals::{BurstyProcess, Poisson};
use super::dist::LengthModel;
use crate::config::calib::workload as calib;
use crate::sim::clock::SimTime;
use crate::util::prng::Prng;

/// Latency class of a request. Interactive traffic carries a tight
/// deadline and may preempt batch work under SLO-aware policies; batch
/// traffic tolerates queueing. Plain generators emit all-interactive
/// traces — only a classed [`ProductionStream`](super::ProductionStream)
/// mixes in batch work — so the class axis is invisible (byte-identical)
/// to every pre-existing workload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SloClass {
    #[default]
    Interactive,
    Batch,
}

impl SloClass {
    /// Stable identifier used by snapshots and segment files.
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    pub fn by_name(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// One request in a trace.
///
/// `prefix` is the request's shared-prefix path: an ordered list of
/// seeded prefix-block ids (each block standing for a fixed number of
/// prompt tokens) that the request shares with other requests carrying
/// the same leading blocks. Plain generators emit prefix-free traces —
/// only a [`ProductionStream`](super::ProductionStream) with a prefix
/// overlay populates it — so, like `class`, the axis is invisible
/// (byte-identical) to every pre-existing workload.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    pub id: u64,
    pub arrival: SimTime,
    pub input_len: u64,
    pub output_len: u64,
    pub class: SloClass,
    pub prefix: Vec<u64>,
}

impl TraceRequest {
    pub fn total_len(&self) -> u64 {
        self.input_len + self.output_len
    }
}

/// A time-ordered request trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Order requests by `(arrival, id)` WITHOUT touching ids.
    ///
    /// Ids are identity: re-sorting a trace whose ids are already
    /// meaningful (segment concatenations, replayed files, hand-built
    /// tests) must never rewrite them — the pre-PR-4 `sort` renumbered
    /// on every call, silently desynchronizing request ids from
    /// per-request recorder rows. Use [`Trace::sort_and_renumber`] when
    /// building a fresh trace whose placeholder ids still need dense
    /// assignment.
    pub fn sort(&mut self) {
        self.requests.sort_by_key(|r| (r.arrival, r.id));
    }

    /// Order by arrival and assign dense ids `0..n` in arrival order —
    /// the trace-construction finalizer (generators build requests with
    /// placeholder id 0, then call this exactly once).
    pub fn sort_and_renumber(&mut self) {
        self.sort();
        for (i, r) in self.requests.iter_mut().enumerate() {
            r.id = i as u64;
        }
    }

    /// §6.2.4 hybrid microbenchmark workload: 1K-token shorts at 60 qpm
    /// (Poisson) + 50K-token longs at ~1 qpm (bursty), over `horizon_s`.
    pub fn hybrid_paper(seed: u64, horizon_s: f64) -> Trace {
        let mut rng = Prng::new(seed);
        let horizon = SimTime::from_secs_f64(horizon_s);
        let mut requests = Vec::new();
        let shorts = Poisson::per_minute(calib::SHORT_QPM).arrivals(&mut rng, horizon);
        for t in shorts {
            let out = 80 + rng.gen_range(0, 80); // ~10% of total length
            requests.push(TraceRequest {
                id: 0,
                arrival: t,
                input_len: calib::SHORT_INPUT_LEN,
                output_len: out,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let longs = BurstyProcess::paper_long_requests().arrivals(&mut rng, horizon);
        for t in longs {
            let out = 256 + rng.gen_range(0, 256);
            requests.push(TraceRequest {
                id: 0,
                arrival: t,
                input_len: calib::LONG_INPUT_LEN,
                output_len: out,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let mut tr = Trace { requests };
        tr.sort_and_renumber();
        tr
    }

    /// Saturating variant of the §6.2.4 hybrid workload: short-request
    /// decode demand is pushed near the degraded-cluster capacity so that
    /// scheduler-induced transformations show up in throughput (the
    /// operating point of Figure 12). Shorts: 1K in / 400 out at 4 qps;
    /// longs: 50K in, bursty ~1/min.
    pub fn hybrid_intense(seed: u64, horizon_s: f64) -> Trace {
        let mut rng = Prng::new(seed);
        let horizon = SimTime::from_secs_f64(horizon_s);
        let mut requests = Vec::new();
        let shorts = Poisson { rate: 4.0 }.arrivals(&mut rng, horizon);
        for t in shorts {
            let out = 350 + rng.gen_range(0, 100);
            requests.push(TraceRequest {
                id: 0,
                arrival: t,
                input_len: calib::SHORT_INPUT_LEN,
                output_len: out,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let longs = BurstyProcess::paper_long_requests().arrivals(&mut rng, horizon);
        for t in longs {
            let out = 256 + rng.gen_range(0, 256);
            requests.push(TraceRequest {
                id: 0,
                arrival: t,
                input_len: calib::LONG_INPUT_LEN,
                output_len: out,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let mut tr = Trace { requests };
        tr.sort_and_renumber();
        tr
    }

    /// §6.3 production-like trace: lengths from [`LengthModel`], Poisson
    /// arrivals at `qps`, over `horizon_s`.
    pub fn production(seed: u64, qps: f64, horizon_s: f64) -> Trace {
        let mut rng = Prng::new(seed);
        let horizon = SimTime::from_secs_f64(horizon_s);
        let model = LengthModel::production();
        let arrivals = Poisson { rate: qps }.arrivals(&mut rng, horizon);
        let mut requests = Vec::new();
        for t in arrivals {
            let input = model.sample_input(&mut rng);
            let output = model.sample_output(&mut rng, input);
            requests.push(TraceRequest {
                id: 0,
                arrival: t,
                input_len: input,
                output_len: output,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let mut tr = Trace { requests };
        tr.sort_and_renumber();
        tr
    }

    /// Total tokens (in + out) in the trace.
    pub fn total_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.total_len()).sum()
    }

    /// Count of requests whose input exceeds `threshold`.
    pub fn long_count(&self, threshold: u64) -> usize {
        self.requests.iter().filter(|r| r.input_len > threshold).count()
    }

    /// Serialize to a simple CSV (id,arrival_s,input,output). The SLO
    /// class and prefix path are NOT persisted here — the CSV format
    /// predates both and stays 4 columns; classed/prefixed workloads
    /// live in segment JSONL (see `workload::source`), where both
    /// round-trip.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("id,arrival_s,input_len,output_len\n");
        for r in &self.requests {
            s.push_str(&format!(
                "{},{:.9},{},{}\n",
                r.id,
                r.arrival.as_secs_f64(),
                r.input_len,
                r.output_len
            ));
        }
        s
    }

    /// Parse the CSV format produced by [`Trace::to_csv`].
    pub fn from_csv(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        for (i, line) in text.lines().enumerate().skip(1) {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 4 {
                return Err(format!("line {}: expected 4 columns", i + 1));
            }
            requests.push(TraceRequest {
                id: cols[0].parse().map_err(|e| format!("line {}: {e}", i + 1))?,
                arrival: SimTime::from_secs_f64(
                    cols[1].parse().map_err(|e| format!("line {}: {e}", i + 1))?,
                ),
                input_len: cols[2].parse().map_err(|e| format!("line {}: {e}", i + 1))?,
                output_len: cols[3].trim().parse().map_err(|e| format!("line {}: {e}", i + 1))?,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        Ok(Trace { requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_trace_rates() {
        let t = Trace::hybrid_paper(7, 3600.0);
        let shorts = t.requests.iter().filter(|r| r.input_len == 1000).count();
        let longs = t.requests.iter().filter(|r| r.input_len == 50_000).count();
        // 60 qpm × 60 min ≈ 3600 shorts; ~1 qpm × 60 ≈ 60 longs (bursty).
        assert!((3000..4200).contains(&shorts), "shorts {shorts}");
        assert!((15..200).contains(&longs), "longs {longs}");
    }

    #[test]
    fn traces_are_sorted_with_dense_ids() {
        let t = Trace::hybrid_paper(8, 600.0);
        for (i, w) in t.requests.windows(2).enumerate() {
            assert!(w[0].arrival <= w[1].arrival, "unsorted at {i}");
        }
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn production_trace_has_tail() {
        let t = Trace::production(9, 2.0, 3600.0);
        assert!(t.len() > 6000);
        assert!(t.long_count(10_000) > 0, "no long requests in tail");
        let frac = t.long_count(10_000) as f64 / t.len() as f64;
        assert!(frac < 0.1, "tail too fat: {frac}");
    }

    #[test]
    fn csv_roundtrip() {
        let t = Trace::production(10, 1.0, 120.0);
        let csv = t.to_csv();
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(t.requests.len(), back.requests.len());
        assert_eq!(t.requests[0], back.requests[0]);
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(Trace::from_csv("header\n1,2,3\n").is_err());
        assert!(Trace::from_csv("header\na,b,c,d\n").is_err());
    }

    #[test]
    fn sort_preserves_assigned_ids() {
        // Regression (PR 4): `sort` used to renumber `r.id = i` on every
        // call, so re-sorting a trace with meaningful ids silently
        // rewrote them.
        let mut t = Trace::default();
        for (id, at) in [(7u64, 3.0), (2, 1.0), (9, 2.0)] {
            t.requests.push(TraceRequest {
                id,
                arrival: SimTime::from_secs_f64(at),
                input_len: 10,
                output_len: 1,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        t.sort();
        let ids: Vec<u64> = t.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 9, 7], "sort must order by arrival, never renumber");
        t.sort();
        let again: Vec<u64> = t.requests.iter().map(|r| r.id).collect();
        assert_eq!(again, vec![2, 9, 7], "sort must be idempotent on ids");
    }

    #[test]
    fn concatenated_segments_keep_globally_unique_ids() {
        // Segment-concatenated replay: splitting a trace into windows and
        // re-sorting the concatenation must preserve the original ids.
        let full = Trace::production(21, 2.0, 90.0);
        let cut = SimTime::from_secs_f64(45.0);
        let (a, b): (Vec<TraceRequest>, Vec<TraceRequest>) =
            full.requests.iter().cloned().partition(|r| r.arrival < cut);
        let mut glued = Trace { requests: b };
        glued.requests.extend(a);
        glued.sort();
        assert_eq!(glued.requests, full.requests, "ids must survive re-sorting");
    }

    #[test]
    fn slo_class_names_roundtrip() {
        for c in [SloClass::Interactive, SloClass::Batch] {
            assert_eq!(SloClass::by_name(c.name()), Some(c));
        }
        assert_eq!(SloClass::by_name("bogus"), None);
        assert_eq!(SloClass::default(), SloClass::Interactive);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Trace::hybrid_paper(42, 600.0);
        let b = Trace::hybrid_paper(42, 600.0);
        assert_eq!(a.requests, b.requests);
        let c = Trace::hybrid_paper(43, 600.0);
        assert_ne!(a.requests, c.requests);
    }
}
