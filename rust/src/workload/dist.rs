//! Request length distributions (paper Figure 2a).
//!
//! Production input lengths are heavy-tailed: the bulk of requests is
//! short (∼1K tokens) while a thin Pareto tail reaches 100K+. Output
//! length contributes only ~10.3% of the total sequence (§5).

use crate::config::calib::workload as calib;
use crate::util::prng::Prng;

/// A fitted input/output length model.
#[derive(Clone, Debug)]
pub struct LengthModel {
    /// Log-normal body: mu/sigma of ln(input_len).
    pub body_mu: f64,
    pub body_sigma: f64,
    /// Probability a request comes from the long tail.
    pub tail_prob: f64,
    /// Pareto tail: scale (tokens) and shape.
    pub tail_scale: f64,
    pub tail_alpha: f64,
    /// Output length as a fraction of total sequence (mean).
    pub output_fraction: f64,
    /// Hard cap (tokenizer/window limit).
    pub max_len: u64,
}

impl LengthModel {
    /// Parameters fit to the published distribution shape: median ≈ 700
    /// tokens, ~3% of requests beyond 10K, tail reaching ≥100K.
    pub fn production() -> LengthModel {
        LengthModel {
            body_mu: 6.55, // ln ≈ 700
            body_sigma: 0.9,
            tail_prob: 0.03,
            tail_scale: 8_000.0,
            tail_alpha: 1.1,
            output_fraction: calib::OUTPUT_FRACTION,
            max_len: 120_000,
        }
    }

    /// Sample an input length.
    pub fn sample_input(&self, rng: &mut Prng) -> u64 {
        let x = if rng.chance(self.tail_prob) {
            rng.pareto(self.tail_scale, self.tail_alpha)
        } else {
            rng.lognormal(self.body_mu, self.body_sigma)
        };
        (x as u64).clamp(16, self.max_len)
    }

    /// Sample an output length for a given input length, keeping the
    /// output ≈ `output_fraction` of total on average.
    pub fn sample_output(&self, rng: &mut Prng, input_len: u64) -> u64 {
        // output = f/(1-f) × input on average, jittered log-normally.
        let mean = self.output_fraction / (1.0 - self.output_fraction) * input_len as f64;
        let jitter = rng.lognormal(0.0, 0.5);
        ((mean * jitter) as u64).clamp(8, 4096)
    }

    /// Empirical CCDF of input lengths over `n` samples (Figure 2a data).
    pub fn ccdf(&self, seed: u64, n: usize, thresholds: &[u64]) -> Vec<(u64, f64)> {
        let mut rng = Prng::new(seed);
        let samples: Vec<u64> = (0..n).map(|_| self.sample_input(&mut rng)).collect();
        thresholds
            .iter()
            .map(|&t| {
                let above = samples.iter().filter(|&&s| s >= t).count();
                (t, above as f64 / n as f64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_requests_dominate() {
        let m = LengthModel::production();
        let mut rng = Prng::new(1);
        let n = 50_000;
        let short = (0..n)
            .filter(|_| m.sample_input(&mut rng) < 4000)
            .count();
        assert!(short as f64 / n as f64 > 0.85, "short fraction {}", short as f64 / n as f64);
    }

    #[test]
    fn long_tail_exists() {
        let m = LengthModel::production();
        let ccdf = m.ccdf(2, 100_000, &[10_000, 50_000, 100_000]);
        assert!(ccdf[0].1 > 0.005, "≥10K share {}", ccdf[0].1);
        assert!(ccdf[1].1 > 0.0005, "≥50K share {}", ccdf[1].1);
        assert!(ccdf[0].1 < 0.10);
    }

    #[test]
    fn ccdf_monotone() {
        let m = LengthModel::production();
        let ccdf = m.ccdf(3, 20_000, &[100, 1000, 10_000, 100_000]);
        for w in ccdf.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn output_fraction_near_paper() {
        // §5: output contributes ~10.3% of total length.
        let m = LengthModel::production();
        let mut rng = Prng::new(4);
        let mut tot_in = 0u64;
        let mut tot_out = 0u64;
        for _ in 0..50_000 {
            let i = m.sample_input(&mut rng);
            let o = m.sample_output(&mut rng, i);
            tot_in += i;
            tot_out += o;
        }
        let f = tot_out as f64 / (tot_in + tot_out) as f64;
        assert!((f - 0.103).abs() < 0.06, "output fraction {f}");
    }

    #[test]
    fn lengths_within_caps() {
        let m = LengthModel::production();
        let mut rng = Prng::new(5);
        for _ in 0..10_000 {
            let i = m.sample_input(&mut rng);
            assert!((16..=m.max_len).contains(&i));
        }
    }
}
