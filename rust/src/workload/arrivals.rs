//! Arrival processes (paper Figure 2b): Poisson short-request background
//! plus bursty, sporadic long-request traffic.

use crate::sim::clock::{SimDuration, SimTime};
use crate::util::prng::Prng;

/// A homogeneous Poisson process.
#[derive(Clone, Debug)]
pub struct Poisson {
    /// Rate in events per second.
    pub rate: f64,
}

impl Poisson {
    pub fn per_minute(qpm: f64) -> Poisson {
        Poisson { rate: qpm / 60.0 }
    }

    /// Next inter-arrival gap.
    pub fn gap(&self, rng: &mut Prng) -> SimDuration {
        SimDuration::from_secs_f64(rng.exp(self.rate))
    }

    /// All arrival times within `[0, horizon)`.
    pub fn arrivals(&self, rng: &mut Prng, horizon: SimTime) -> Vec<SimTime> {
        let mut out = Vec::new();
        let mut t = SimTime::ZERO + self.gap(rng);
        while t < horizon {
            out.push(t);
            t = t + self.gap(rng);
        }
        out
    }
}

/// A Markov-modulated (bursty) process: alternates quiet and burst phases,
/// matching the sporadic long-request pattern of Figure 2b.
#[derive(Clone, Debug)]
pub struct BurstyProcess {
    /// Base rate during quiet phases (events/s).
    pub quiet_rate: f64,
    /// Rate during bursts.
    pub burst_rate: f64,
    /// Mean quiet-phase duration (s).
    pub quiet_mean_s: f64,
    /// Mean burst duration (s).
    pub burst_mean_s: f64,
}

impl BurstyProcess {
    /// Calibrated to the paper's §6.2.4 long-request load: ~1 query/min
    /// on average, arriving in clusters.
    pub fn paper_long_requests() -> BurstyProcess {
        BurstyProcess {
            quiet_rate: 1.0 / 240.0, // one per 4 min when quiet
            burst_rate: 1.0 / 15.0,  // one per 15 s inside a burst
            quiet_mean_s: 300.0,
            burst_mean_s: 90.0,
        }
    }

    /// Average event rate (events/s).
    pub fn mean_rate(&self) -> f64 {
        let (q, b) = (self.quiet_mean_s, self.burst_mean_s);
        (self.quiet_rate * q + self.burst_rate * b) / (q + b)
    }

    /// Arrival times within `[0, horizon)`.
    pub fn arrivals(&self, rng: &mut Prng, horizon: SimTime) -> Vec<SimTime> {
        let mut out = Vec::new();
        let mut t = 0.0f64;
        let hz = horizon.as_secs_f64();
        let mut in_burst = false;
        let mut phase_end = rng.exp(1.0 / self.quiet_mean_s);
        while t < hz {
            let rate = if in_burst { self.burst_rate } else { self.quiet_rate };
            let gap = rng.exp(rate);
            if t + gap < phase_end.min(hz) {
                t += gap;
                out.push(SimTime::from_secs_f64(t));
            } else {
                t = phase_end;
                in_burst = !in_burst;
                let mean = if in_burst { self.burst_mean_s } else { self.quiet_mean_s };
                phase_end = t + rng.exp(1.0 / mean);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_close() {
        let p = Poisson::per_minute(60.0); // 1/s
        let mut rng = Prng::new(1);
        let arr = p.arrivals(&mut rng, SimTime::from_secs_f64(10_000.0));
        let rate = arr.len() as f64 / 10_000.0;
        assert!((rate - 1.0).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn poisson_sorted() {
        let p = Poisson::per_minute(120.0);
        let mut rng = Prng::new(2);
        let arr = p.arrivals(&mut rng, SimTime::from_secs_f64(100.0));
        for w in arr.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn bursty_mean_rate_near_one_per_minute() {
        let b = BurstyProcess::paper_long_requests();
        let analytic = b.mean_rate() * 60.0;
        assert!((0.5..2.5).contains(&analytic), "analytic {analytic}/min");
        let mut rng = Prng::new(3);
        let arr = b.arrivals(&mut rng, SimTime::from_secs_f64(36_000.0)); // 10 h
        let per_min = arr.len() as f64 / 600.0;
        assert!((0.3..3.0).contains(&per_min), "measured {per_min}/min");
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Compare coefficient of variation of inter-arrival gaps.
        let b = BurstyProcess::paper_long_requests();
        let mut rng = Prng::new(4);
        let arr = b.arrivals(&mut rng, SimTime::from_secs_f64(200_000.0));
        let gaps: Vec<f64> = arr.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.2, "cv {cv} should exceed Poisson's 1.0");
    }
}
