//! Workload modelling (paper §3.2 / Figure 2): heavy-tailed length
//! distributions, Poisson + bursty arrival processes, and trace
//! generation/replay.

pub mod arrivals;
pub mod dist;
pub mod source;
pub mod trace;

pub use arrivals::{BurstyProcess, Poisson};
pub use dist::LengthModel;
pub use source::{
    prefix_for, ArrivalFeed, ChunkedTrace, FeedState, LongBursts, MaterializedSource, PrefixMix,
    ProductionStream, SegmentDir, SegmentFileSource, SloMix, SourceCursor, StreamSource,
    TraceSegment, TraceSource,
};
pub use trace::{SloClass, Trace, TraceRequest};
