//! Streaming trace sources: yield time-ordered arrival segments lazily so
//! multi-hour traces (the LoongServe/Shift-Parallelism regime of §6.3)
//! replay with O(segment) peak memory instead of one materialized `Vec`.
//!
//! A [`TraceSource`] produces contiguous [`TraceSegment`] windows
//! `[k·S, (k+1)·S)` in order. Three implementations:
//!
//! * [`MaterializedSource`] — a whole [`Trace`] as one segment (the
//!   classic replay path; `ClusterSim::new` wraps traces in this).
//! * [`ChunkedTrace`] — a materialized trace split into fixed windows
//!   (streamed replay of the *same* trace; the simulator's merge order is
//!   segmentation-independent, so results are byte-identical to whole-
//!   trace replay — enforced by `rust/tests/streaming.rs`).
//! * [`SegmentFileSource`] — JSONL segment files read lazily from a
//!   directory written by `gyges trace-gen` ([`SegmentDirWriter`]), with
//!   per-file FNV-1a integrity hashes and id-contiguity checks.
//! * [`StreamSource`] — segments generated on the fly from a seeded
//!   [`ProductionStream`] arrival process (per-segment RNG, so any
//!   segment regenerates from `seed + index` alone — resumable without
//!   replaying its predecessors).
//!
//! Invariants every source must uphold (validated by [`ArrivalFeed`]):
//! segment indices are sequential from 0, windows are contiguous and
//! non-overlapping (`start == previous end`, first window starts at 0),
//! and every request's arrival lies inside its segment's window in
//! non-decreasing order. File and stream sources additionally guarantee
//! globally unique, stable, contiguous request ids.

use super::dist::LengthModel;
use super::trace::{SloClass, Trace, TraceRequest};
use crate::sim::clock::{SimDuration, SimTime};
use crate::util::hash::{fnv1a, hex64};
use crate::util::json::Json;
use crate::util::prng::Prng;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};

/// Largest tick value the JSONL integer encoding roundtrips exactly
/// (`Json::Num` is an f64; `as_u64` rejects anything ≥ 9.0e15). 9e15 ns
/// is ~104 days of simulated time — far beyond any experiment horizon.
const MAX_EXACT_TICKS: u64 = 9_000_000_000_000_000;

/// THE canonical tick length of a requested `segment_s` window —
/// chunking, stream generation, manifests, and directory-parameter
/// checks all derive it here, so they can never drift apart.
pub fn segment_ticks(segment_s: f64) -> SimDuration {
    SimDuration::from_secs_f64(segment_s).max_of(SimDuration(1))
}

/// One contiguous window of arrivals: requests with
/// `start <= arrival < end`, time-ordered.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSegment {
    /// Sequential segment index (0-based).
    pub index: usize,
    /// Inclusive window start.
    pub start: SimTime,
    /// Exclusive window end.
    pub end: SimTime,
    pub requests: Vec<TraceRequest>,
}

/// A lazy producer of time-ordered, contiguous trace segments. `Send`
/// so a simulator (and its feed) can move across worker threads — the
/// branch explorer forks restored sims on one thread and runs them on
/// another.
pub trait TraceSource: Send {
    /// The next segment, `None` when exhausted, or `Err` on a structural
    /// failure (I/O error, tampered file, malformed rows). After an
    /// `Err` the source is considered dead; the simulator surfaces the
    /// message as `SimError::TraceSource` and stops feeding arrivals.
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>>;

    /// Serializable resume position (snapshot subsystem). A restored
    /// cursor must yield exactly the segments this source would have
    /// yielded from here on. Sources that cannot promise that (ad-hoc
    /// test doubles) keep the default and make the enclosing simulation
    /// un-snapshottable, never silently wrong.
    fn cursor(&self) -> Result<SourceCursor, String> {
        Err("this trace source does not support snapshotting".into())
    }
}

/// A [`TraceSource`] resume position, serializable into a snapshot. The
/// in-memory variants embed their remaining requests (the snapshot is
/// then self-contained, at O(remaining-trace) size); the lazy variants
/// are a few integers plus the regeneration key (directory path /
/// generating spec), keeping multi-hour snapshots O(segment).
#[derive(Clone, Debug, PartialEq)]
pub enum SourceCursor {
    /// Nothing left to yield (also covers a [`MaterializedSource`] whose
    /// single segment was already delivered).
    Exhausted,
    /// A [`MaterializedSource`] that has not yet delivered its segment.
    Materialized { requests: Vec<TraceRequest> },
    /// Mid-[`ChunkedTrace`]: the requests not yet windowed out.
    Chunked {
        requests: Vec<TraceRequest>,
        segment: SimDuration,
        horizon: SimTime,
        next_index: usize,
    },
    /// Mid-[`SegmentFileSource`]: reopen `dir` and continue at file
    /// index `next` (the manifest re-validates on open).
    Dir { dir: PathBuf, next: usize },
    /// Mid-[`StreamSource`]: segment `next` regenerates from
    /// `(spec.seed, next)` alone; `next_id` continues the dense id
    /// sequence. The bursty-longs phase state needs no field of its
    /// own — phase boundaries are re-derived from the seed (see
    /// [`ProductionStream::longs`]).
    Stream { spec: ProductionStream, next: usize, next_id: u64 },
}

/// Yields nothing: the restored form of [`SourceCursor::Exhausted`].
struct EmptySource;

impl TraceSource for EmptySource {
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
        None
    }

    fn cursor(&self) -> Result<SourceCursor, String> {
        Ok(SourceCursor::Exhausted)
    }
}

impl SourceCursor {
    /// Rebuild the source this cursor describes.
    pub fn into_source(self) -> Result<Box<dyn TraceSource>, String> {
        Ok(match self {
            SourceCursor::Exhausted => Box::new(EmptySource),
            SourceCursor::Materialized { requests } => {
                Box::new(MaterializedSource::new(Trace { requests }))
            }
            SourceCursor::Chunked { requests, segment, horizon, next_index } => {
                Box::new(ChunkedTrace::from_parts(requests, segment, horizon, next_index))
            }
            SourceCursor::Dir { dir, next } => {
                let mut src = SegmentFileSource::open(&dir)?;
                if next > src.dir.files.len() {
                    return Err(format!(
                        "{}: snapshot cursor points at segment {next} but the directory holds \
                         only {} files",
                        dir.display(),
                        src.dir.files.len()
                    ));
                }
                src.next = next;
                Box::new(src)
            }
            SourceCursor::Stream { spec, next, next_id } => {
                Box::new(StreamSource::from_parts(spec, next, next_id))
            }
        })
    }

    /// Canonical JSON form (snapshot schema v1).
    pub fn to_json(&self) -> Json {
        let reqs = |rs: &[TraceRequest]| Json::Arr(rs.iter().map(request_to_json).collect());
        let mut o = Json::obj();
        match self {
            SourceCursor::Exhausted => {
                o.set("kind", "exhausted");
            }
            SourceCursor::Materialized { requests } => {
                o.set("kind", "materialized").set("requests", reqs(requests));
            }
            SourceCursor::Chunked { requests, segment, horizon, next_index } => {
                o.set("kind", "chunked")
                    .set("requests", reqs(requests))
                    .set("segment_ns", segment.0)
                    .set("horizon_ns", horizon.0)
                    .set("next_index", *next_index);
            }
            SourceCursor::Dir { dir, next } => {
                o.set("kind", "dir")
                    .set("dir", dir.to_string_lossy().as_ref())
                    .set("next", *next);
            }
            SourceCursor::Stream { spec, next, next_id } => {
                let mut s = Json::obj();
                s.set("seed", spec.seed)
                    .set("qps", spec.qps)
                    .set("segment_s", spec.segment_s)
                    .set("horizon_s", spec.horizon_s);
                if let Some(l) = &spec.longs {
                    let mut lj = Json::obj();
                    lj.set("quiet_rate", l.quiet_rate)
                        .set("burst_rate", l.burst_rate)
                        .set("quiet_mean_s", l.quiet_mean_s)
                        .set("burst_mean_s", l.burst_mean_s)
                        .set("input_len", l.input_len);
                    s.set("longs", lj);
                }
                if let Some(m) = &spec.slo {
                    let mut mj = Json::obj();
                    mj.set("interactive_frac", m.interactive_frac);
                    s.set("slo", mj);
                }
                if let Some(p) = &spec.prefix {
                    let mut pj = Json::obj();
                    pj.set("prompts", p.prompts)
                        .set("prompt_blocks", p.prompt_blocks)
                        .set("sessions", p.sessions)
                        .set("session_blocks", p.session_blocks)
                        .set("session_frac", p.session_frac);
                    s.set("prefix", pj);
                }
                o.set("kind", "stream").set("spec", s).set("next", *next).set("next_id", *next_id);
            }
        }
        o
    }

    /// Parse the [`SourceCursor::to_json`] form.
    pub fn from_json(j: &Json) -> Result<SourceCursor, String> {
        let kind = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or("source cursor: missing kind")?;
        let reqs = |key: &str| -> Result<Vec<TraceRequest>, String> {
            j.req_arr(key, "source cursor")?.iter().map(request_from_json).collect()
        };
        let num = |j: &Json, k: &str| j.req_u64(k, "source cursor");
        let float = |j: &Json, k: &str| j.req_f64(k, "source cursor");
        Ok(match kind {
            "exhausted" => SourceCursor::Exhausted,
            "materialized" => SourceCursor::Materialized { requests: reqs("requests")? },
            "chunked" => SourceCursor::Chunked {
                requests: reqs("requests")?,
                segment: SimDuration(num(j, "segment_ns")?),
                horizon: SimTime(num(j, "horizon_ns")?),
                next_index: num(j, "next_index")? as usize,
            },
            "dir" => SourceCursor::Dir {
                dir: PathBuf::from(
                    j.get("dir").and_then(|v| v.as_str()).ok_or("source cursor: bad dir")?,
                ),
                next: num(j, "next")? as usize,
            },
            "stream" => {
                let s = j.get("spec").ok_or("source cursor: missing spec")?;
                let longs = match s.get("longs") {
                    None | Some(Json::Null) => None,
                    Some(l) => Some(LongBursts {
                        quiet_rate: float(l, "quiet_rate")?,
                        burst_rate: float(l, "burst_rate")?,
                        quiet_mean_s: float(l, "quiet_mean_s")?,
                        burst_mean_s: float(l, "burst_mean_s")?,
                        input_len: num(l, "input_len")?,
                    }),
                };
                let slo = match s.get("slo") {
                    None | Some(Json::Null) => None,
                    Some(m) => Some(SloMix { interactive_frac: float(m, "interactive_frac")? }),
                };
                let prefix = match s.get("prefix") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(PrefixMix {
                        prompts: num(p, "prompts")?,
                        prompt_blocks: num(p, "prompt_blocks")?,
                        sessions: num(p, "sessions")?,
                        session_blocks: num(p, "session_blocks")?,
                        session_frac: float(p, "session_frac")?,
                    }),
                };
                SourceCursor::Stream {
                    spec: ProductionStream {
                        seed: num(s, "seed")?,
                        qps: float(s, "qps")?,
                        segment_s: float(s, "segment_s")?,
                        horizon_s: float(s, "horizon_s")?,
                        longs,
                        slo,
                        prefix,
                    },
                    next: num(j, "next")? as usize,
                    next_id: num(j, "next_id")?,
                }
            }
            other => return Err(format!("source cursor: unknown kind {other:?}")),
        })
    }
}

// ---------------------------------------------------------------------
// Whole-trace and chunked in-memory sources
// ---------------------------------------------------------------------

/// A whole materialized trace delivered as one segment.
pub struct MaterializedSource {
    trace: Option<Trace>,
}

impl MaterializedSource {
    pub fn new(trace: Trace) -> MaterializedSource {
        MaterializedSource { trace: Some(trace) }
    }
}

impl TraceSource for MaterializedSource {
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
        let trace = self.trace.take()?;
        let end = trace
            .requests
            .last()
            .map(|r| SimTime(r.arrival.0 + 1))
            .unwrap_or(SimTime::ZERO);
        Some(Ok(TraceSegment { index: 0, start: SimTime::ZERO, end, requests: trace.requests }))
    }

    fn cursor(&self) -> Result<SourceCursor, String> {
        Ok(match &self.trace {
            Some(t) => SourceCursor::Materialized { requests: t.requests.clone() },
            None => SourceCursor::Exhausted,
        })
    }
}

/// A materialized trace split into fixed `segment_s` windows. The trace
/// must be time-ordered (all generators and `Trace::sort` guarantee it).
pub struct ChunkedTrace {
    requests: VecDeque<TraceRequest>,
    segment: SimDuration,
    horizon: SimTime,
    next_index: usize,
}

impl ChunkedTrace {
    /// Split at `segment_s` windows covering every request (the horizon
    /// is the last arrival + 1 tick).
    pub fn new(trace: Trace, segment_s: f64) -> ChunkedTrace {
        let horizon = trace
            .requests
            .last()
            .map(|r| SimTime(r.arrival.0 + 1))
            .unwrap_or(SimTime::ZERO);
        Self::with_horizon_time(trace, segment_s, horizon)
    }

    /// Split with an explicit horizon — windows keep coming (possibly
    /// empty) until the horizon is covered, so a horizon beyond the last
    /// arrival yields empty trailing segments.
    pub fn with_horizon(trace: Trace, segment_s: f64, horizon_s: f64) -> ChunkedTrace {
        Self::with_horizon_time(trace, segment_s, SimTime::from_secs_f64(horizon_s))
    }

    fn with_horizon_time(trace: Trace, segment_s: f64, horizon: SimTime) -> ChunkedTrace {
        // Never strand requests past a too-short horizon: extend it.
        let min_h = trace
            .requests
            .last()
            .map(|r| SimTime(r.arrival.0 + 1))
            .unwrap_or(SimTime::ZERO);
        let segment = segment_ticks(segment_s);
        ChunkedTrace {
            requests: VecDeque::from(trace.requests),
            segment,
            horizon: horizon.max(min_h),
            next_index: 0,
        }
    }

    /// Rebuild a mid-stream chunker from its [`SourceCursor::Chunked`]
    /// parts — the exact internal state, no horizon re-derivation.
    pub fn from_parts(
        requests: Vec<TraceRequest>,
        segment: SimDuration,
        horizon: SimTime,
        next_index: usize,
    ) -> ChunkedTrace {
        ChunkedTrace { requests: VecDeque::from(requests), segment, horizon, next_index }
    }
}

impl TraceSource for ChunkedTrace {
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
        let start = SimTime(self.next_index as u64 * self.segment.0);
        if start >= self.horizon && self.requests.is_empty() {
            return None;
        }
        let end = SimTime((start.0 + self.segment.0).min(self.horizon.0));
        let mut requests = Vec::new();
        while let Some(front) = self.requests.front() {
            if front.arrival.0 >= end.0 {
                break;
            }
            requests.push(self.requests.pop_front().unwrap());
        }
        let index = self.next_index;
        self.next_index += 1;
        Some(Ok(TraceSegment { index, start, end, requests }))
    }

    fn cursor(&self) -> Result<SourceCursor, String> {
        Ok(SourceCursor::Chunked {
            requests: self.requests.iter().cloned().collect(),
            segment: self.segment,
            horizon: self.horizon,
            next_index: self.next_index,
        })
    }
}

// ---------------------------------------------------------------------
// Seeded on-the-fly generation (ProductionStream)
// ---------------------------------------------------------------------

/// The bursty long-request overlay of the Figure-2b production process:
/// a Markov-modulated stream of `input_len`-token requests whose
/// quiet/burst phase boundaries are derived from the stream seed ALONE
/// (a dedicated phase RNG walked from t=0), so segment `k`'s phase
/// overlap — and therefore its long arrivals — is a pure function of
/// `(seed, k)` with no cross-segment generator state. Within each
/// phase-window overlap the Poisson clock restarts (memoryless, so the
/// restriction is still an exact Poisson process at the phase rate)
/// from the segment's own long-RNG, keeping every segment regenerable
/// without its predecessors.
#[derive(Clone, Debug, PartialEq)]
pub struct LongBursts {
    /// Long-arrival rate during quiet phases (events/s).
    pub quiet_rate: f64,
    /// Long-arrival rate inside bursts.
    pub burst_rate: f64,
    /// Mean quiet-phase duration (s).
    pub quiet_mean_s: f64,
    /// Mean burst duration (s).
    pub burst_mean_s: f64,
    /// Input tokens of every long request.
    pub input_len: u64,
}

impl LongBursts {
    /// The §6.2.4 calibration [`super::arrivals::BurstyProcess`] uses:
    /// ~1 long/min on average, arriving in clusters.
    pub fn paper() -> LongBursts {
        LongBursts {
            quiet_rate: 1.0 / 240.0,
            burst_rate: 1.0 / 15.0,
            quiet_mean_s: 300.0,
            burst_mean_s: 90.0,
            input_len: crate::config::calib::workload::LONG_INPUT_LEN,
        }
    }
}

/// Salt mixed into the stream seed for the phase-boundary RNG, so phase
/// draws never alias the per-segment arrival streams.
const LONG_PHASE_SALT: u64 = 0xB1A5_7B00_57ED_2B2B;

/// SLO-class mix of a production stream: each request is independently
/// interactive with probability `interactive_frac`, drawn by
/// [`class_for`]'s hash-Bernoulli over `(seed, id)` — pure, so any
/// segment (and any resumed cursor) re-derives the same classes with no
/// generator state crossing segment boundaries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloMix {
    /// Probability a request is [`SloClass::Interactive`]; the rest are
    /// batch-class.
    pub interactive_frac: f64,
}

/// Deterministic SLO-class draw for request `id` of stream `seed`.
pub fn class_for(seed: u64, id: u64, interactive_frac: f64) -> SloClass {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&seed.to_le_bytes());
    bytes[8..].copy_from_slice(&id.to_le_bytes());
    // Top 53 hash bits as a uniform draw in [0, 1) — exact in f64.
    let u = (fnv1a(&bytes) >> 11) as f64 / (1u64 << 53) as f64;
    if u < interactive_frac {
        SloClass::Interactive
    } else {
        SloClass::Batch
    }
}

/// Salts decorrelating the prefix overlay's hash sub-streams from each
/// other and from [`class_for`] / the arrival RNGs.
const PREFIX_DRAW_SALT: u64 = 0x5E55_1014_D4A3_77E1;
const PREFIX_SESSION_SALT: u64 = 0x5E55_1014_B10C_4AE5;
const PREFIX_DEPTH_SALT: u64 = 0x5E55_1014_DE97_0003;
const PREFIX_BLOCK_SALT: u64 = 0x5E55_1014_B70C_1D5A;

/// Shared-prefix overlay of a production stream: the session /
/// system-prompt structure dominating production traffic. Each request
/// independently joins a session with probability `session_frac`
/// (hash-Bernoulli over `(seed, id)`, like [`SloMix`]); a session
/// member's prefix path is its session's system-prompt blocks followed
/// by the first `depth` blocks of the session's conversation history
/// (depth drawn uniformly in `1..=session_blocks`), so two requests of
/// the same session share the prompt blocks plus their common history
/// prefix. Everything is a pure function of `(seed, id)` — segments
/// regenerate and cursors resume with no overlay state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixMix {
    /// Distinct system prompts (sessions map onto them round-robin).
    pub prompts: u64,
    /// Prefix blocks per system prompt.
    pub prompt_blocks: u64,
    /// Concurrent multi-turn sessions.
    pub sessions: u64,
    /// Maximum per-session conversation depth, in blocks.
    pub session_blocks: u64,
    /// Probability a request belongs to a session (the rest carry no
    /// prefix path at all).
    pub session_frac: f64,
}

impl PrefixMix {
    /// The fig-cache default: a few heavyweight system prompts, enough
    /// sessions that no single instance can hold them all, and an 80%
    /// participation rate.
    pub fn paper() -> PrefixMix {
        PrefixMix {
            prompts: 4,
            prompt_blocks: 16,
            sessions: 64,
            session_blocks: 24,
            session_frac: 0.8,
        }
    }
}

/// Uniform `[0, 1)` hash draw over `(seed, id)` (top 53 bits, exact in
/// f64) — the same construction [`class_for`] uses, salted per stream.
fn hash_uniform(seed: u64, id: u64) -> f64 {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&seed.to_le_bytes());
    bytes[8..].copy_from_slice(&id.to_le_bytes());
    (fnv1a(&bytes) >> 11) as f64 / (1u64 << 53) as f64
}

/// Seeded prefix-block id: 48 bits so every id round-trips exactly
/// through the JSONL f64 integer encoding (`Json::as_u64` rejects
/// ≥ 9e15) and through snapshot payloads.
fn prefix_block(seed: u64, kind: u8, entity: u64, j: u64) -> u64 {
    let mut bytes = [0u8; 25];
    bytes[..8].copy_from_slice(&(seed ^ PREFIX_BLOCK_SALT).to_le_bytes());
    bytes[8] = kind;
    bytes[9..17].copy_from_slice(&entity.to_le_bytes());
    bytes[17..25].copy_from_slice(&j.to_le_bytes());
    fnv1a(&bytes) >> 16
}

/// Deterministic prefix path for request `id` of stream `seed` — empty
/// for non-participants, otherwise prompt blocks ++ session-history
/// blocks.
pub fn prefix_for(seed: u64, id: u64, m: &PrefixMix) -> Vec<u64> {
    if m.prompt_blocks == 0 && m.session_blocks == 0 {
        return Vec::new();
    }
    if hash_uniform(seed ^ PREFIX_DRAW_SALT, id) >= m.session_frac {
        return Vec::new();
    }
    let mut sid = [0u8; 16];
    sid[..8].copy_from_slice(&(seed ^ PREFIX_SESSION_SALT).to_le_bytes());
    sid[8..].copy_from_slice(&id.to_le_bytes());
    let session = fnv1a(&sid) % m.sessions.max(1);
    let prompt = session % m.prompts.max(1);
    let depth = if m.session_blocks == 0 {
        0
    } else {
        let mut did = [0u8; 16];
        did[..8].copy_from_slice(&(seed ^ PREFIX_DEPTH_SALT).to_le_bytes());
        did[8..].copy_from_slice(&id.to_le_bytes());
        1 + fnv1a(&did) % m.session_blocks
    };
    let mut path = Vec::with_capacity((m.prompt_blocks + depth) as usize);
    for j in 0..m.prompt_blocks {
        path.push(prefix_block(seed, 1, prompt, j));
    }
    for j in 0..depth {
        path.push(prefix_block(seed, 2, session, j));
    }
    path
}

/// A seeded, segmented §6.3-style production workload: Poisson arrivals
/// at `qps` with [`LengthModel::production`] lengths, generated one
/// segment at a time from an RNG derived from `(seed, segment index)` —
/// optionally overlaid with the Figure-2b bursty long-request process
/// ([`LongBursts`], phase boundaries derived from the seed alone).
///
/// Because each segment's randomness depends only on `seed` and its
/// index (Poisson arrivals are memoryless, so restarting the
/// inter-arrival clock at each window boundary is still an exact
/// Poisson process), any segment regenerates without its predecessors —
/// `gyges trace-gen` resumes at an arbitrary index, and replay memory
/// is O(segment) end to end. Note `segment_s` is part of the workload
/// identity: a different segmentation is a different (equally valid)
/// draw of the same process.
#[derive(Clone, Debug, PartialEq)]
pub struct ProductionStream {
    pub seed: u64,
    /// Poisson arrival rate (requests/s).
    pub qps: f64,
    pub segment_s: f64,
    pub horizon_s: f64,
    /// Figure-2b bursty long-request overlay; `None` is the plain
    /// short-tailed production stream PR 4 shipped (fingerprints and
    /// existing segment directories are unchanged).
    pub longs: Option<LongBursts>,
    /// SLO-class mix; `None` leaves every request interactive-class (the
    /// pre-SLO stream — serialized forms and segment-file bytes are
    /// unchanged, since the interactive class encodes as absence).
    pub slo: Option<SloMix>,
    /// Shared-prefix overlay; `None` leaves every request prefix-free
    /// (the pre-cache stream — an empty prefix path encodes as absence,
    /// so serialized forms and segment-file bytes are unchanged).
    pub prefix: Option<PrefixMix>,
}

impl ProductionStream {
    /// Count of segments covering `[0, horizon)`.
    pub fn num_segments(&self) -> usize {
        let seg = segment_ticks(self.segment_s).0;
        let horizon = SimTime::from_secs_f64(self.horizon_s).0;
        horizon.div_ceil(seg) as usize
    }

    /// Window `[start, end)` of segment `k` in ticks.
    pub fn window(&self, k: usize) -> (SimTime, SimTime) {
        let seg = segment_ticks(self.segment_s).0;
        let horizon = SimTime::from_secs_f64(self.horizon_s).0;
        let start = (k as u64 * seg).min(horizon);
        (SimTime(start), SimTime((start + seg).min(horizon)))
    }

    fn segment_rng(&self, k: usize) -> Prng {
        // Golden-ratio mix keeps per-segment streams decorrelated; the
        // +1 keeps segment 0 distinct from the bare seed.
        Prng::new(self.seed ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    fn long_rng(&self, k: usize) -> Prng {
        // Independent per-segment stream for the long-request overlay.
        Prng::new(self.seed ^ LONG_PHASE_SALT ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Quiet/burst phase intervals `(start_s, end_s, in_burst)` of the
    /// long-request overlay that intersect `[from_s, to_s)`. Derived
    /// from the seed alone (the phase RNG is walked from t=0, exactly as
    /// arrival ids are re-derived on resume — O(#phases) per call, a few
    /// dozen per simulated hour), so any segment's overlap is pure in
    /// `(seed, window)` with no cross-segment state to carry or
    /// snapshot: the phase timeline IS the phase state.
    fn long_phases(&self, longs: &LongBursts, from_s: f64, to_s: f64) -> Vec<(f64, f64, bool)> {
        let mut rng = Prng::new(self.seed ^ LONG_PHASE_SALT);
        let mut out = Vec::new();
        let mut t = 0.0f64;
        let mut in_burst = false;
        let mut phase_end = rng.exp(1.0 / longs.quiet_mean_s);
        while t < to_s {
            if phase_end > from_s {
                out.push((t.max(from_s), phase_end.min(to_s), in_burst));
            }
            t = phase_end;
            in_burst = !in_burst;
            let mean = if in_burst { longs.burst_mean_s } else { longs.quiet_mean_s };
            phase_end = t + rng.exp(1.0 / mean);
        }
        out
    }

    /// Generate segment `k` with ids starting at `first_id`. Pure in
    /// `(self, k)` except for the id base — regenerating any `k` yields
    /// identical arrivals and lengths.
    pub fn gen_segment(&self, k: usize, first_id: u64) -> TraceSegment {
        let (start, end) = self.window(k);
        let mut rng = self.segment_rng(k);
        let model = LengthModel::production();
        let mut requests = Vec::new();
        let mut t = start.as_secs_f64();
        loop {
            t += rng.exp(self.qps);
            let at = SimTime::from_secs_f64(t);
            if at.0 >= end.0 {
                break;
            }
            let input = model.sample_input(&mut rng);
            let output = model.sample_output(&mut rng, input);
            requests.push(TraceRequest {
                id: 0,
                arrival: at.max(start),
                input_len: input,
                output_len: output,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        if let Some(longs) = &self.longs {
            // Overlay the bursty longs: for each phase piece overlapping
            // this window, restart the exponential clock at the piece
            // start from the segment's own long-RNG (memoryless, so the
            // piecewise restriction is still the exact modulated
            // process) — pure in (seed, k).
            let mut lrng = self.long_rng(k);
            let mut longs_in_window = Vec::new();
            let phases = self.long_phases(longs, start.as_secs_f64(), end.as_secs_f64());
            for (lo, hi, in_burst) in phases {
                let rate = if in_burst { longs.burst_rate } else { longs.quiet_rate };
                let mut t = lo;
                loop {
                    t += lrng.exp(rate);
                    if t >= hi {
                        break;
                    }
                    let at = SimTime::from_secs_f64(t).max(start);
                    if at.0 >= end.0 {
                        break;
                    }
                    let output = 256 + lrng.gen_range(0, 256);
                    longs_in_window.push(TraceRequest {
                        id: 0,
                        arrival: at,
                        input_len: longs.input_len,
                        output_len: output,
                        class: SloClass::Interactive,
                        prefix: Vec::new(),
                    });
                }
            }
            // Stable sort on arrival alone: shorts keep priority at an
            // exact-tie timestamp, and both sub-streams stay in their
            // own generation order.
            requests.extend(longs_in_window);
            requests.sort_by_key(|r| r.arrival);
        }
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = first_id + i as u64;
        }
        // Classes hash off the final id so they survive resume (any
        // regeneration with the right id base re-derives them exactly).
        if let Some(m) = &self.slo {
            for r in requests.iter_mut() {
                r.class = class_for(self.seed, r.id, m.interactive_frac);
            }
        }
        // Prefix paths hash off the final id too, for the same reason.
        if let Some(m) = &self.prefix {
            for r in requests.iter_mut() {
                r.prefix = prefix_for(self.seed, r.id, m);
            }
        }
        TraceSegment { index: k, start, end, requests }
    }

    /// First id of segment `k` (the request count of segments `0..k` —
    /// O(k) regeneration, done once when resuming mid-stream).
    pub fn first_id(&self, k: usize) -> u64 {
        (0..k).map(|j| self.gen_segment(j, 0).requests.len() as u64).sum()
    }

    /// Concatenate every segment into one materialized trace (the
    /// whole-trace reference the byte-identity tests replay).
    pub fn materialize(&self) -> Trace {
        let mut requests = Vec::new();
        let mut id = 0u64;
        for k in 0..self.num_segments() {
            let seg = self.gen_segment(k, id);
            id += seg.requests.len() as u64;
            requests.extend(seg.requests);
        }
        Trace { requests }
    }
}

/// [`TraceSource`] over a [`ProductionStream`]: generates segments on
/// demand, holding only the one being delivered.
pub struct StreamSource {
    spec: ProductionStream,
    next: usize,
    next_id: u64,
}

impl StreamSource {
    pub fn new(spec: ProductionStream) -> StreamSource {
        StreamSource { spec, next: 0, next_id: 0 }
    }

    /// Start mid-stream at segment `resume_from` (ids stay globally
    /// consistent: the id base is recomputed from the skipped segments).
    pub fn resume_at(spec: ProductionStream, resume_from: usize) -> StreamSource {
        let next_id = spec.first_id(resume_from);
        StreamSource { spec, next: resume_from, next_id }
    }

    /// Rebuild from a [`SourceCursor::Stream`] — `next_id` is taken
    /// verbatim (already derived once when the snapshot was captured).
    pub fn from_parts(spec: ProductionStream, next: usize, next_id: u64) -> StreamSource {
        StreamSource { spec, next, next_id }
    }
}

impl TraceSource for StreamSource {
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
        if self.next >= self.spec.num_segments() {
            return None;
        }
        let seg = self.spec.gen_segment(self.next, self.next_id);
        self.next += 1;
        self.next_id += seg.requests.len() as u64;
        Some(Ok(seg))
    }

    fn cursor(&self) -> Result<SourceCursor, String> {
        Ok(SourceCursor::Stream {
            spec: self.spec.clone(),
            next: self.next,
            next_id: self.next_id,
        })
    }
}

// ---------------------------------------------------------------------
// Segment files (JSONL + manifest)
// ---------------------------------------------------------------------

/// Manifest schema version of a segment directory.
pub const TRACE_SEGMENT_SCHEMA_VERSION: u64 = 1;

/// Per-file entry of a segment-directory manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentFileMeta {
    pub index: usize,
    pub start: SimTime,
    pub end: SimTime,
    /// First request id of this segment (ids are dense across segments).
    pub first_id: u64,
    pub count: usize,
    /// Hex FNV-1a of the segment file's exact bytes.
    pub payload_hash: String,
}

/// A validated trace-segment directory: `trace-manifest.json` plus one
/// `segment-XXXXX.jsonl` per window. The manifest carries the aggregate
/// trace shape (request count, tokens, last arrival) so sweep manifests
/// can fingerprint a streamed job without materializing its trace.
#[derive(Clone, Debug)]
pub struct SegmentDir {
    pub dir: PathBuf,
    /// Workload label (e.g. the sweep name this trace belongs to).
    pub label: String,
    /// Trace-group index within the sweep (fig12 has one per model).
    pub group: usize,
    pub horizon: SimTime,
    /// The REQUESTED window length the directory was generated with
    /// ([`segment_ticks`] of the caller's `segment_s`) — compared
    /// verbatim when a launcher checks whether an existing directory
    /// matches its parameters.
    pub segment: SimDuration,
    pub requests: u64,
    pub total_tokens: u64,
    pub last_arrival: SimTime,
    pub files: Vec<SegmentFileMeta>,
}

impl SegmentDir {
    pub fn manifest_path(dir: &Path) -> PathBuf {
        dir.join("trace-manifest.json")
    }

    pub fn segment_file_name(index: usize) -> String {
        format!("segment-{index:05}.jsonl")
    }

    pub fn to_json(&self) -> Json {
        let files: Vec<Json> = self
            .files
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.set("index", f.index)
                    .set("start_ns", f.start.0)
                    .set("end_ns", f.end.0)
                    .set("first_id", f.first_id)
                    .set("count", f.count)
                    .set("payload_hash", f.payload_hash.as_str());
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("schema_version", TRACE_SEGMENT_SCHEMA_VERSION)
            .set("kind", "trace-segments")
            .set("label", self.label.as_str())
            .set("group", self.group)
            .set("horizon_ns", self.horizon.0)
            .set("segment_ns", self.segment.0)
            .set("requests", self.requests)
            .set("total_tokens", self.total_tokens)
            .set("last_arrival_ns", self.last_arrival.0)
            .set("files", Json::Arr(files));
        o
    }

    /// Open and structurally validate a segment directory's manifest
    /// (windows contiguous from 0, ids dense, counts consistent).
    /// Segment payloads are validated lazily as they are read.
    pub fn open(dir: &Path) -> Result<SegmentDir, String> {
        let path = Self::manifest_path(dir);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        let ctx = path.display().to_string();
        let num = |k: &str| doc.req_u64(k, &ctx);
        let version = num("schema_version")?;
        if version != TRACE_SEGMENT_SCHEMA_VERSION {
            return Err(format!(
                "{}: schema_version {version} unsupported (this reads v{TRACE_SEGMENT_SCHEMA_VERSION})",
                path.display()
            ));
        }
        let label = doc.req_str("label", &ctx)?.to_string();
        let files_json = doc.req_arr("files", &ctx)?;
        let mut files = Vec::with_capacity(files_json.len());
        for f in files_json {
            let fnum = |k: &str| f.req_u64(k, &ctx);
            files.push(SegmentFileMeta {
                index: fnum("index")? as usize,
                start: SimTime(fnum("start_ns")?),
                end: SimTime(fnum("end_ns")?),
                first_id: fnum("first_id")?,
                count: fnum("count")? as usize,
                payload_hash: f.req_str("payload_hash", &ctx)?.to_string(),
            });
        }
        let out = SegmentDir {
            dir: dir.to_path_buf(),
            label,
            group: num("group")? as usize,
            horizon: SimTime(num("horizon_ns")?),
            segment: SimDuration(num("segment_ns")?),
            requests: num("requests")?,
            total_tokens: num("total_tokens")?,
            last_arrival: SimTime(num("last_arrival_ns")?),
            files,
        };
        out.validate().map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(out)
    }

    fn validate(&self) -> Result<(), String> {
        let mut next_id = 0u64;
        let mut prev_end = SimTime::ZERO;
        for (k, f) in self.files.iter().enumerate() {
            if f.index != k {
                return Err(format!("file {k} declares index {}", f.index));
            }
            if f.start != prev_end {
                return Err(format!(
                    "segment {k} starts at {} but the previous window ended at {}",
                    f.start.0, prev_end.0
                ));
            }
            if f.end < f.start {
                return Err(format!("segment {k} window ends before it starts"));
            }
            if f.first_id != next_id {
                return Err(format!(
                    "segment {k} first_id {} breaks id contiguity (expected {next_id})",
                    f.first_id
                ));
            }
            next_id += f.count as u64;
            prev_end = f.end;
        }
        if next_id != self.requests {
            return Err(format!(
                "file counts sum to {next_id} but manifest claims {} requests",
                self.requests
            ));
        }
        if prev_end != self.horizon {
            return Err(format!(
                "last window ends at {} but manifest horizon is {}",
                prev_end.0, self.horizon.0
            ));
        }
        Ok(())
    }
}

fn request_to_json(r: &TraceRequest) -> Json {
    let mut o = Json::obj();
    o.set("arrival_ns", r.arrival.0)
        .set("id", r.id)
        .set("input", r.input_len)
        .set("output", r.output_len);
    // Interactive encodes as absence, so classless streams keep their
    // pre-SLO segment-file bytes (and payload hashes) unchanged.
    if r.class == SloClass::Batch {
        o.set("class", r.class.name());
    }
    // An empty prefix path encodes as absence for the same reason:
    // prefix-free streams keep their historical bytes and hashes.
    if !r.prefix.is_empty() {
        o.set("prefix", Json::Arr(r.prefix.iter().map(|&b| Json::from(b)).collect()));
    }
    o
}

fn request_from_json(j: &Json) -> Result<TraceRequest, String> {
    let num = |k: &str| j.req_u64(k, "request");
    let class = match j.get("class") {
        None | Some(Json::Null) => SloClass::Interactive,
        Some(v) => {
            let s = v.as_str().ok_or("request: bad class")?;
            SloClass::by_name(s).ok_or_else(|| format!("request: unknown class {s:?}"))?
        }
    };
    let prefix = match j.get("prefix") {
        None | Some(Json::Null) => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or("request: bad prefix")?
            .iter()
            .map(|b| b.as_u64().ok_or_else(|| "request: bad prefix block".to_string()))
            .collect::<Result<Vec<u64>, String>>()?,
    };
    Ok(TraceRequest {
        id: num("id")?,
        arrival: SimTime(num("arrival_ns")?),
        input_len: num("input")?,
        output_len: num("output")?,
        class,
        prefix,
    })
}

/// Incremental segment-directory writer: accepts segments in index order
/// (holding only one at a time), then seals the manifest. `resume_from`
/// skips rewriting files below that index — their metadata is still
/// recomputed, so resuming produces a manifest identical to a full run.
pub struct SegmentDirWriter {
    dir: PathBuf,
    label: String,
    group: usize,
    resume_from: usize,
    files: Vec<SegmentFileMeta>,
    requests: u64,
    total_tokens: u64,
    last_arrival: SimTime,
    /// The REQUESTED window length ([`segment_ticks`] of the caller's
    /// `segment_s`), recorded verbatim in the manifest so parameter
    /// checks compare requested-vs-requested instead of re-deriving
    /// observed window sizes.
    segment: SimDuration,
}

impl SegmentDirWriter {
    pub fn create(
        dir: &Path,
        label: &str,
        group: usize,
        segment_s: f64,
        resume_from: usize,
    ) -> Result<SegmentDirWriter, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        Ok(SegmentDirWriter {
            dir: dir.to_path_buf(),
            label: label.to_string(),
            group,
            resume_from,
            files: Vec::new(),
            requests: 0,
            total_tokens: 0,
            last_arrival: SimTime::ZERO,
            segment: segment_ticks(segment_s),
        })
    }

    /// Serialize one segment. Segments must arrive in index order.
    pub fn write_segment(&mut self, seg: &TraceSegment) -> Result<(), String> {
        if seg.index != self.files.len() {
            return Err(format!(
                "segment {} written out of order (expected {})",
                seg.index,
                self.files.len()
            ));
        }
        let mut payload = String::new();
        for r in &seg.requests {
            if r.arrival.0 >= MAX_EXACT_TICKS {
                return Err(format!("arrival {} ns is beyond the exact JSON range", r.arrival.0));
            }
            payload.push_str(&request_to_json(r).to_string());
            payload.push('\n');
            self.total_tokens += r.total_len();
            self.last_arrival = self.last_arrival.max(r.arrival);
        }
        let name = SegmentDir::segment_file_name(seg.index);
        let path = self.dir.join(&name);
        if seg.index >= self.resume_from {
            std::fs::write(&path, &payload)
                .map_err(|e| format!("write {}: {e}", path.display()))?;
        } else {
            // Resume may only skip files that are really on disk with
            // exactly the bytes being skipped — otherwise the sealed
            // manifest would reference files that are missing (or
            // differ) and the breakage would surface only at replay,
            // far from its cause.
            let existing = std::fs::read(&path).map_err(|e| {
                format!(
                    "resume-from {} but {} is unreadable: {e}",
                    self.resume_from,
                    path.display()
                )
            })?;
            if existing != payload.as_bytes() {
                return Err(format!(
                    "{}: existing bytes differ from the regenerated segment — resume with \
                     the original seed/horizon/segment-s, or delete the directory",
                    path.display()
                ));
            }
        }
        let first_id = seg.requests.first().map(|r| r.id).unwrap_or(self.requests);
        self.files.push(SegmentFileMeta {
            index: seg.index,
            start: seg.start,
            end: seg.end,
            first_id,
            count: seg.requests.len(),
            payload_hash: hex64(fnv1a(payload.as_bytes())),
        });
        self.requests += seg.requests.len() as u64;
        Ok(())
    }

    /// Write the manifest and return the validated directory handle.
    pub fn finish(self) -> Result<SegmentDir, String> {
        let horizon = self.files.last().map(|f| f.end).unwrap_or(SimTime::ZERO);
        let out = SegmentDir {
            dir: self.dir.clone(),
            label: self.label,
            group: self.group,
            horizon,
            segment: self.segment,
            requests: self.requests,
            total_tokens: self.total_tokens,
            last_arrival: self.last_arrival,
            files: self.files,
        };
        out.validate()?;
        let path = SegmentDir::manifest_path(&self.dir);
        std::fs::write(&path, format!("{}\n", out.to_json()))
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        Ok(out)
    }
}

/// Drain `source` into segment files under `dir`. `segment_s` is the
/// requested window length the source was built with (recorded in the
/// manifest — see [`SegmentDirWriter`]).
pub fn write_segments(
    dir: &Path,
    label: &str,
    group: usize,
    segment_s: f64,
    source: &mut dyn TraceSource,
    resume_from: usize,
) -> Result<SegmentDir, String> {
    let mut w = SegmentDirWriter::create(dir, label, group, segment_s, resume_from)?;
    while let Some(seg) = source.next_segment() {
        w.write_segment(&seg?)?;
    }
    w.finish()
}

/// Lazy reader over a validated [`SegmentDir`]: loads one JSONL file per
/// [`TraceSource::next_segment`] call, verifying its payload hash, row
/// count, window, and id contiguity against the manifest.
pub struct SegmentFileSource {
    dir: SegmentDir,
    next: usize,
}

impl SegmentFileSource {
    pub fn new(dir: SegmentDir) -> SegmentFileSource {
        SegmentFileSource { dir, next: 0 }
    }

    /// Open `dir`'s manifest and build a source over it.
    pub fn open(dir: &Path) -> Result<SegmentFileSource, String> {
        Ok(SegmentFileSource::new(SegmentDir::open(dir)?))
    }

    fn read_one(&self, meta: &SegmentFileMeta) -> Result<TraceSegment, String> {
        let path = self.dir.dir.join(SegmentDir::segment_file_name(meta.index));
        let payload = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let actual = hex64(fnv1a(payload.as_bytes()));
        if actual != meta.payload_hash {
            return Err(format!(
                "{}: payload hash {actual} does not match manifest {} (file corrupted or \
                 edited after trace-gen)",
                path.display(),
                meta.payload_hash
            ));
        }
        let mut requests = Vec::with_capacity(meta.count);
        for (i, line) in payload.lines().enumerate() {
            let row = Json::parse(line).map_err(|e| format!("{} row {i}: {e}", path.display()))?;
            let r = request_from_json(&row)
                .map_err(|e| format!("{} row {i}: {e}", path.display()))?;
            let want_id = meta.first_id + i as u64;
            if r.id != want_id {
                return Err(format!(
                    "{} row {i}: id {} breaks contiguity (expected {want_id})",
                    path.display(),
                    r.id
                ));
            }
            requests.push(r);
        }
        if requests.len() != meta.count {
            return Err(format!(
                "{}: {} rows, manifest says {}",
                path.display(),
                requests.len(),
                meta.count
            ));
        }
        Ok(TraceSegment { index: meta.index, start: meta.start, end: meta.end, requests })
    }
}

impl TraceSource for SegmentFileSource {
    fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
        let meta = self.dir.files.get(self.next)?.clone();
        self.next += 1;
        Some(self.read_one(&meta))
    }

    fn cursor(&self) -> Result<SourceCursor, String> {
        Ok(SourceCursor::Dir { dir: self.dir.dir.clone(), next: self.next })
    }
}

// ---------------------------------------------------------------------
// Arrival feed (the simulator's cursor over a source)
// ---------------------------------------------------------------------

/// Pull-based cursor the event loop drains: peeks the next arrival time,
/// pops requests one at a time, and buffers at most one segment. Also
/// enforces the cross-segment invariants (sequential indices, contiguous
/// windows, in-window time-ordered arrivals); a violating or erroring
/// source stops the feed and surfaces its message.
pub struct ArrivalFeed {
    source: Box<dyn TraceSource>,
    buf: VecDeque<TraceRequest>,
    exhausted: bool,
    error: Option<String>,
    next_index: usize,
    window_end: SimTime,
    last_arrival: SimTime,
    peak_buffered: usize,
}

impl ArrivalFeed {
    pub fn new(source: Box<dyn TraceSource>) -> ArrivalFeed {
        ArrivalFeed {
            source,
            buf: VecDeque::new(),
            exhausted: false,
            error: None,
            next_index: 0,
            window_end: SimTime::ZERO,
            last_arrival: SimTime::ZERO,
            peak_buffered: 0,
        }
    }

    /// Whole-trace replay: the classic path, one segment. Stable-sorts
    /// by arrival only — the pre-streaming loop heap-ordered its
    /// pre-pushed arrivals FIFO at equal timestamps (i.e. insertion
    /// order), which a stable sort on the arrival key alone reproduces
    /// exactly, so an unsorted trace was (and stays) valid input with
    /// identical replay order; a no-op for the already-sorted traces
    /// every generator produces.
    pub fn from_trace(mut trace: Trace) -> ArrivalFeed {
        trace.requests.sort_by_key(|r| r.arrival);
        ArrivalFeed::new(Box::new(MaterializedSource::new(trace)))
    }

    fn accept(&mut self, seg: TraceSegment) -> Result<(), String> {
        if seg.index != self.next_index {
            return Err(format!(
                "segment index {} out of order (expected {})",
                seg.index, self.next_index
            ));
        }
        if seg.start != self.window_end {
            return Err(format!(
                "segment {} starts at {} ns but the previous window ended at {} ns \
                 (windows must be contiguous and non-overlapping)",
                seg.index, seg.start.0, self.window_end.0
            ));
        }
        if seg.end < seg.start {
            return Err(format!("segment {} window ends before it starts", seg.index));
        }
        let mut last = self.last_arrival;
        for r in &seg.requests {
            if r.arrival < seg.start || r.arrival >= seg.end {
                return Err(format!(
                    "segment {}: request {} arrival {} ns outside window [{}, {}) ns",
                    seg.index, r.id, r.arrival.0, seg.start.0, seg.end.0
                ));
            }
            if r.arrival < last {
                return Err(format!(
                    "segment {}: request {} arrives out of order",
                    seg.index, r.id
                ));
            }
            last = r.arrival;
        }
        self.last_arrival = last;
        self.window_end = seg.end;
        self.next_index += 1;
        self.buf.extend(seg.requests);
        self.peak_buffered = self.peak_buffered.max(self.buf.len());
        Ok(())
    }

    /// Refill until an arrival is buffered or the source ends/errors.
    fn pull(&mut self) {
        while self.buf.is_empty() && !self.exhausted {
            match self.source.next_segment() {
                None => self.exhausted = true,
                Some(Err(e)) => {
                    self.error = Some(e);
                    self.exhausted = true;
                }
                Some(Ok(seg)) => {
                    if let Err(e) = self.accept(seg) {
                        self.error = Some(e);
                        self.exhausted = true;
                    }
                }
            }
        }
    }

    /// Arrival time of the next request, if any remain.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.pull();
        self.buf.front().map(|r| r.arrival)
    }

    /// Take the next request.
    pub fn pop(&mut self) -> Option<TraceRequest> {
        self.pull();
        self.buf.pop_front()
    }

    /// Do any arrivals remain? Pulls until one is buffered (or the
    /// source ends), so the answer is exact — equivalent to the
    /// pre-streaming loop's "are arrivals still queued", independent of
    /// segmentation (empty segments are skipped, never miscounted).
    pub fn pending(&mut self) -> bool {
        self.pull();
        !self.buf.is_empty()
    }

    /// Structural failure raised by the source, if any.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// High-water mark of buffered requests — the memory-bound witness
    /// (whole-trace replay buffers everything; streamed replay at most
    /// one segment).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// Capture the feed's complete replay position: the unconsumed part
    /// of the buffered segment plus the cross-segment validation state
    /// and the source's own resume cursor. A failed feed refuses — the
    /// failure (tamper/IO) must be diagnosed, not checkpointed around.
    pub fn snapshot(&self) -> Result<FeedState, String> {
        if let Some(e) = &self.error {
            return Err(format!("cannot snapshot a failed arrival feed: {e}"));
        }
        Ok(FeedState {
            buf: self.buf.iter().cloned().collect(),
            exhausted: self.exhausted,
            next_index: self.next_index,
            window_end: self.window_end,
            last_arrival: self.last_arrival,
            peak_buffered: self.peak_buffered,
            cursor: self.source.cursor()?,
        })
    }

    /// Rebuild a feed from [`ArrivalFeed::snapshot`] state. The restored
    /// feed pulls exactly the segments the original would have pulled,
    /// so replay from here is byte-identical to never having paused.
    pub fn restore(state: FeedState) -> Result<ArrivalFeed, String> {
        Ok(ArrivalFeed {
            source: state.cursor.into_source()?,
            buf: VecDeque::from(state.buf),
            exhausted: state.exhausted,
            error: None,
            next_index: state.next_index,
            window_end: state.window_end,
            last_arrival: state.last_arrival,
            peak_buffered: state.peak_buffered,
        })
    }
}

/// Serializable [`ArrivalFeed`] state (snapshot schema v1).
#[derive(Clone, Debug, PartialEq)]
pub struct FeedState {
    /// Unconsumed requests of the currently-buffered segment(s).
    pub buf: Vec<TraceRequest>,
    pub exhausted: bool,
    pub next_index: usize,
    pub window_end: SimTime,
    pub last_arrival: SimTime,
    pub peak_buffered: usize,
    pub cursor: SourceCursor,
}

impl FeedState {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("buf", Json::Arr(self.buf.iter().map(request_to_json).collect()))
            .set("exhausted", self.exhausted)
            .set("next_index", self.next_index)
            .set("window_end_ns", self.window_end.0)
            .set("last_arrival_ns", self.last_arrival.0)
            .set("peak_buffered", self.peak_buffered)
            .set("cursor", self.cursor.to_json());
        o
    }

    pub fn from_json(j: &Json) -> Result<FeedState, String> {
        let num = |k: &str| j.req_u64(k, "feed state");
        Ok(FeedState {
            buf: j
                .req_arr("buf", "feed state")?
                .iter()
                .map(request_from_json)
                .collect::<Result<Vec<_>, _>>()?,
            exhausted: j.req_bool("exhausted", "feed state")?,
            next_index: num("next_index")? as usize,
            window_end: SimTime(num("window_end_ns")?),
            last_arrival: SimTime(num("last_arrival_ns")?),
            peak_buffered: num("peak_buffered")? as usize,
            cursor: SourceCursor::from_json(
                j.get("cursor").ok_or("feed state: missing cursor")?,
            )?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(source: &mut dyn TraceSource) -> Vec<TraceSegment> {
        let mut out = Vec::new();
        while let Some(seg) = source.next_segment() {
            out.push(seg.unwrap());
        }
        out
    }

    #[test]
    fn chunked_partitions_without_loss_or_reorder() {
        let trace = Trace::production(5, 3.0, 60.0);
        let mut chunked = ChunkedTrace::with_horizon(trace.clone(), 7.0, 60.0);
        let segs = collect(&mut chunked);
        assert!(segs.len() >= 8, "60 s / 7 s windows");
        let mut glued = Vec::new();
        let mut prev_end = SimTime::ZERO;
        for (k, s) in segs.iter().enumerate() {
            assert_eq!(s.index, k);
            assert_eq!(s.start, prev_end, "windows must be contiguous");
            prev_end = s.end;
            glued.extend(s.requests.clone());
        }
        assert_eq!(glued, trace.requests, "chunking must preserve order and ids");
    }

    #[test]
    fn chunked_emits_empty_trailing_segments() {
        let mut t = Trace::default();
        t.requests.push(TraceRequest {
            id: 0,
            arrival: SimTime::from_secs_f64(1.0),
            input_len: 10,
            output_len: 1,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
        let mut chunked = ChunkedTrace::with_horizon(t, 2.0, 10.0);
        let segs = collect(&mut chunked);
        assert_eq!(segs.len(), 5);
        assert_eq!(segs[0].requests.len(), 1);
        assert!(segs[1..].iter().all(|s| s.requests.is_empty()));
    }

    #[test]
    fn chunked_boundary_exactly_on_arrival_goes_to_later_window() {
        let mut t = Trace::default();
        for (id, at) in [(0u64, 4.999), (1, 5.0), (2, 5.001)] {
            t.requests.push(TraceRequest {
                id,
                arrival: SimTime::from_secs_f64(at),
                input_len: 10,
                output_len: 1,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        let mut chunked = ChunkedTrace::with_horizon(t, 5.0, 10.0);
        let segs = collect(&mut chunked);
        assert_eq!(segs[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(segs[1].requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn feed_rejects_overlapping_windows() {
        struct Bad(usize);
        impl TraceSource for Bad {
            fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
                let k = self.0;
                self.0 += 1;
                if k > 1 {
                    return None;
                }
                // Both segments claim [0, 10) — overlap.
                Some(Ok(TraceSegment {
                    index: k,
                    start: SimTime::ZERO,
                    end: SimTime(10),
                    requests: Vec::new(),
                }))
            }
        }
        let mut feed = ArrivalFeed::new(Box::new(Bad(0)));
        assert_eq!(feed.peek_time(), None);
        assert!(feed.error().unwrap().contains("contiguous"), "{:?}", feed.error());
    }

    #[test]
    fn feed_buffers_one_segment_at_a_time() {
        let trace = Trace::production(9, 4.0, 40.0);
        let total = trace.len();
        let mut per_window = 0usize;
        let mut chunked = ChunkedTrace::with_horizon(trace.clone(), 5.0, 40.0);
        while let Some(seg) = chunked.next_segment() {
            per_window = per_window.max(seg.unwrap().requests.len());
        }
        let mut feed =
            ArrivalFeed::new(Box::new(ChunkedTrace::with_horizon(trace.clone(), 5.0, 40.0)));
        let mut seen = 0;
        while feed.pop().is_some() {
            seen += 1;
        }
        assert_eq!(seen, total);
        assert!(feed.peak_buffered() <= per_window, "streamed feed must hold one window");
        let mut whole = ArrivalFeed::from_trace(trace);
        whole.peek_time();
        assert_eq!(whole.peak_buffered(), total, "whole-trace replay buffers everything");
    }

    #[test]
    fn stream_segments_regenerate_independently() {
        let spec =
            ProductionStream {
                seed: 11,
                qps: 2.0,
                segment_s: 15.0,
                horizon_s: 90.0,
                longs: None,
                slo: None,
                prefix: None,
            };
        assert_eq!(spec.num_segments(), 6);
        let full = spec.materialize();
        assert!(!full.is_empty());
        // Any segment regenerates identically without its predecessors.
        for k in [0usize, 3, 5] {
            let a = spec.gen_segment(k, 1000);
            let b = spec.gen_segment(k, 1000);
            assert_eq!(a, b);
        }
        // Resuming mid-stream continues the exact id sequence.
        let mut resumed = StreamSource::resume_at(spec.clone(), 4);
        let seg4 = resumed.next_segment().unwrap().unwrap();
        let want_first = spec.first_id(4);
        assert_eq!(seg4.requests.first().map(|r| r.id), Some(want_first));
        // Ids in the materialized trace are dense.
        for (i, r) in full.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn bursty_stream_segments_regenerate_independently() {
        let spec = ProductionStream {
            seed: 0x2B,
            qps: 2.0,
            segment_s: 60.0,
            horizon_s: 1800.0,
            longs: Some(LongBursts::paper()),
            slo: None,
            prefix: None,
        };
        let full = spec.materialize();
        let long_len = LongBursts::paper().input_len;
        let longs = full.requests.iter().filter(|r| r.input_len == long_len).count();
        assert!(longs > 0, "a 30-min bursty stream must contain long requests");
        // Any segment regenerates identically without its predecessors
        // (the phase timeline is re-derived from the seed alone).
        for k in [0usize, 7, 29] {
            assert_eq!(spec.gen_segment(k, 500), spec.gen_segment(k, 500));
        }
        // Streamed == materialized (dense ids, same rows).
        let mut src = StreamSource::new(spec.clone());
        let mut glued = Vec::new();
        while let Some(seg) = src.next_segment() {
            glued.extend(seg.unwrap().requests);
        }
        assert_eq!(glued, full.requests);
        // The overlay is part of the workload identity: plain and bursty
        // streams with the same seed are different draws.
        let plain = ProductionStream { longs: None, ..spec }.materialize();
        assert_ne!(plain.requests, full.requests);
    }

    #[test]
    fn slo_mix_is_deterministic_and_class_free_of_arrival_draws() {
        let spec = ProductionStream {
            seed: 11,
            qps: 2.0,
            segment_s: 15.0,
            horizon_s: 90.0,
            longs: None,
            slo: Some(SloMix { interactive_frac: 0.7 }),
            prefix: None,
        };
        let full = spec.materialize();
        let batch = full.requests.iter().filter(|r| r.class == SloClass::Batch).count();
        assert!(batch > 0, "a 0.7 mix over {} requests draws batch work", full.requests.len());
        assert!(batch < full.requests.len(), "and keeps interactive work too");
        // Classes hash off (seed, id): segments re-derive them exactly.
        for k in [0usize, 3, 5] {
            let first = spec.first_id(k);
            assert_eq!(spec.gen_segment(k, first), spec.gen_segment(k, first));
        }
        // The mix is an overlay on ids only — arrivals and lengths match
        // the classless stream row for row.
        let plain = ProductionStream { slo: None, ..spec.clone() }.materialize();
        assert_eq!(plain.requests.len(), full.requests.len());
        for (a, b) in plain.requests.iter().zip(full.requests.iter()) {
            assert_eq!((a.id, a.arrival, a.input_len, a.output_len),
                (b.id, b.arrival, b.input_len, b.output_len));
            assert_eq!(b.class, class_for(spec.seed, b.id, 0.7));
        }
        // Batch rows round-trip through segment JSONL; classless rows
        // keep their pre-SLO encoding (no "class" key).
        for r in &full.requests {
            assert_eq!(request_from_json(&request_to_json(r)).unwrap(), *r);
        }
        let plain_row = request_to_json(&plain.requests[0]).to_string();
        assert!(!plain_row.contains("class"), "interactive encodes as absence: {plain_row}");
    }

    #[test]
    fn feed_state_roundtrips_through_json() {
        let spec = ProductionStream {
            seed: 5,
            qps: 3.0,
            segment_s: 10.0,
            horizon_s: 60.0,
            longs: Some(LongBursts::paper()),
            slo: Some(SloMix { interactive_frac: 0.8 }),
            prefix: Some(PrefixMix::paper()),
        };
        let mut feed = ArrivalFeed::new(Box::new(StreamSource::new(spec)));
        // Consume into the middle of a segment.
        for _ in 0..7 {
            feed.pop();
        }
        let state = feed.snapshot().unwrap();
        let back = FeedState::from_json(&Json::parse(&state.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(state, back);
        // The restored feed yields exactly the remaining stream.
        let mut restored = ArrivalFeed::restore(back).unwrap();
        let mut a = Vec::new();
        while let Some(r) = feed.pop() {
            a.push(r);
        }
        let mut b = Vec::new();
        while let Some(r) = restored.pop() {
            b.push(r);
        }
        assert_eq!(a, b, "restored feed must continue the exact request stream");
    }

    #[test]
    fn segment_dir_roundtrips_and_detects_tampering() {
        let dir = std::env::temp_dir().join(format!("gyges-seg-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let trace = Trace::production(13, 2.0, 30.0);
        let mut chunked = ChunkedTrace::with_horizon(trace.clone(), 8.0, 30.0);
        let written = write_segments(&dir, "test", 0, 8.0, &mut chunked, 0).unwrap();
        assert_eq!(written.requests as usize, trace.len());
        assert_eq!(written.total_tokens, trace.total_tokens());

        // Read back: identical request stream.
        let mut source = SegmentFileSource::open(&dir).unwrap();
        let mut glued = Vec::new();
        for seg in collect(&mut source) {
            glued.extend(seg.requests);
        }
        assert_eq!(glued, trace.requests);

        // Tamper with one payload byte → hash mismatch surfaces.
        let victim = dir.join(SegmentDir::segment_file_name(1));
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[0] ^= 1;
        std::fs::write(&victim, &bytes).unwrap();
        let mut source = SegmentFileSource::open(&dir).unwrap();
        let mut saw_err = false;
        while let Some(seg) = source.next_segment() {
            if let Err(e) = seg {
                assert!(e.contains("payload hash"), "{e}");
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "tampered segment must be rejected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rewrites_the_tail_and_reproduces_the_manifest() {
        let dir_a = std::env::temp_dir().join(format!("gyges-resume-a-{}", std::process::id()));
        let dir_b = std::env::temp_dir().join(format!("gyges-resume-b-{}", std::process::id()));
        let dir_c = std::env::temp_dir().join(format!("gyges-resume-c-{}", std::process::id()));
        for d in [&dir_a, &dir_b, &dir_c] {
            let _ = std::fs::remove_dir_all(d);
        }
        let spec =
            ProductionStream {
                seed: 3,
                qps: 2.0,
                segment_s: 10.0,
                horizon_s: 50.0,
                longs: None,
                slo: None,
                prefix: None,
            };
        let full =
            write_segments(&dir_a, "p", 0, 10.0, &mut StreamSource::new(spec.clone()), 0).unwrap();
        // Simulate an interrupted run: dir_b holds only files 0..3.
        write_segments(&dir_b, "p", 0, 10.0, &mut StreamSource::new(spec.clone()), 0).unwrap();
        for k in 3..full.files.len() {
            std::fs::remove_file(dir_b.join(SegmentDir::segment_file_name(k))).unwrap();
        }
        std::fs::remove_file(SegmentDir::manifest_path(&dir_b)).unwrap();
        // Resume from index 3: the surviving prefix is verified in place,
        // the tail is rewritten, and the manifest is identical to a full
        // run's.
        let resumed =
            write_segments(&dir_b, "p", 0, 10.0, &mut StreamSource::new(spec.clone()), 3).unwrap();
        assert_eq!(full.to_json().to_string(), resumed.to_json().to_string());
        assert!(dir_b.join(SegmentDir::segment_file_name(3)).exists());
        // Resuming into an empty directory is refused: the manifest must
        // never reference files that were neither written nor found.
        let err =
            write_segments(&dir_c, "p", 0, 10.0, &mut StreamSource::new(spec), 3).unwrap_err();
        assert!(err.contains("unreadable"), "{err}");
        for d in [&dir_a, &dir_b, &dir_c] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}
