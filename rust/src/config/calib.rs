//! Calibration constants taken verbatim from the paper's text.
//!
//! The simulated substrate is calibrated against every concrete number the
//! paper publishes about its testbed, so reproduced experiments inherit
//! the testbed's scale (see DESIGN.md §5). Each constant cites its source.

/// Paper Table 1 (Qwen2.5-32B on 4×H20, 1K-token requests).
pub mod table1 {
    /// Maximal supported sequence length per deployment.
    pub const MAX_SEQ_TP1: u64 = 3_750;
    pub const MAX_SEQ_TP2: u64 = 41_250;
    pub const MAX_SEQ_TP4: u64 = 120_500;
    /// Single-instance throughput (tokens/s).
    pub const TPS_TP1: f64 = 448.0;
    pub const TPS_TP2: f64 = 670.0;
    pub const TPS_TP4: f64 = 767.0;
    /// Total throughput of the 4-GPU host.
    pub const TOTAL_TPS_4X_TP1: f64 = 1792.0;
    pub const TOTAL_TPS_2X_TP2: f64 = 1340.0;
    pub const TOTAL_TPS_TP4: f64 = 767.0;
}

/// §3.1: memory accounting for Qwen2.5-32B on H20.
pub mod memory {
    /// "runtime activations take 14.3 GB" (per GPU, decimal GB).
    pub const ACTIVATION_BYTES: u64 = 14_300_000_000;
    /// "model size ... 62.34 GB".
    pub const QWEN32B_WEIGHT_BYTES: u64 = 62_340_000_000;
    /// "with 4×(TP1), 64.9% GPU memory is used to maintain model weights".
    pub const TP1_WEIGHT_FRACTION: f64 = 0.649;
    /// "with TP4, only 16.2%".
    pub const TP4_WEIGHT_FRACTION: f64 = 0.162;
}

/// Challenge-2 / §6.2: transformation timing anchors (Qwen2.5-32B).
pub mod transform {
    /// Full KV move 4×(TP1)→TP4 takes 522 ms with 78 SMs…
    pub const KV_MOVE_MS_78SM: f64 = 522.0;
    /// …and 2240 ms with a single SM.
    pub const KV_MOVE_MS_1SM: f64 = 2240.0;
    /// Basic KV-transformation extra step time: 3.15–4 ms across models
    /// (§6.2.1; per-step overhead while transformation is in flight).
    pub const BASIC_KV_EXTRA_MS_LO: f64 = 3.15;
    pub const BASIC_KV_EXTRA_MS_HI: f64 = 4.0;
    /// Partial-swap weight transformation per layer: 611–696 ms (§6.2.2).
    pub const PARTIAL_SWAP_MS_LO: f64 = 611.0;
    pub const PARTIAL_SWAP_MS_HI: f64 = 696.0;
    /// Basic migrate+trim costs 12× extra memory and 2.6× extra time
    /// relative to in-place (§4.1.2).
    pub const TRIM_EXTRA_MEM_FACTOR: f64 = 12.0;
    pub const TRIM_EXTRA_TIME_FACTOR: f64 = 2.6;
    /// Header-centric layout: −91.6% memory, −86% time (abstract, §6.2.1).
    pub const HC_MEM_SAVING: f64 = 0.916;
    pub const HC_TIME_SAVING: f64 = 0.86;
    /// Gyges keeps extra memory below 70 MB during transformation (§6.2.1).
    pub const GYGES_PEAK_EXTRA_BYTES: u64 = 70_000_000;
    /// Seesaw migration is up to 41× more expensive (§3.3, §6.2.3).
    pub const SEESAW_COST_FACTOR: f64 = 41.0;
}

/// §5 / §6.2.4 workload + scheduler anchors.
pub mod workload {
    /// Short requests: 1K input tokens at 60 queries/minute.
    pub const SHORT_INPUT_LEN: u64 = 1_000;
    pub const SHORT_QPM: f64 = 60.0;
    /// Long requests: 50K input tokens at 1 query/minute.
    pub const LONG_INPUT_LEN: u64 = 50_000;
    pub const LONG_QPM: f64 = 1.0;
    /// Output contributes only 10.3% of total sequence length (§5).
    pub const OUTPUT_FRACTION: f64 = 0.103;
    /// SLOs (§3.1): TTFT < 10 s, TPOT < 100 ms.
    pub const SLO_TTFT_S: f64 = 10.0;
    pub const SLO_TPOT_S: f64 = 0.100;
    /// Scale-down load threshold (Algorithm 2). The paper does not publish
    /// the value; 0.5 keeps scale-down conservative.
    pub const SCALE_DOWN_LOAD_THRESHOLD: f64 = 0.5;
}

/// Baseline degradation anchors.
pub mod baselines {
    /// "KunServe and LoongServe cause 43.5% extra throughput degradation"
    /// (§3.3) — rooted in PP/SP activating 1/N GPUs per time slot (§2).
    pub const PP_SP_EXTRA_DEGRADATION: f64 = 0.435;
    /// Gyges end-to-end throughput gain range (abstract/§6.3).
    pub const E2E_GAIN_LO: f64 = 1.75;
    pub const E2E_GAIN_HI: f64 = 6.57;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_internally_consistent() {
        assert_eq!(table1::TOTAL_TPS_4X_TP1, 4.0 * table1::TPS_TP1);
        assert_eq!(table1::TOTAL_TPS_2X_TP2, 2.0 * table1::TPS_TP2);
        assert_eq!(table1::TOTAL_TPS_TP4, table1::TPS_TP4);
        // §1: "scaling from 4×(TP1) to TP4 can incur over 57% throughput loss"
        let loss = 1.0 - table1::TOTAL_TPS_TP4 / table1::TOTAL_TPS_4X_TP1;
        assert!(loss > 0.57, "loss={loss}");
    }

    #[test]
    fn weight_fractions_match_h20() {
        let h20 = 96.0 * 1024.0 * 1024.0 * 1024.0;
        let f1 = memory::QWEN32B_WEIGHT_BYTES as f64 / h20;
        let f4 = memory::QWEN32B_WEIGHT_BYTES as f64 / 4.0 / h20;
        assert!((f1 - memory::TP1_WEIGHT_FRACTION).abs() < 0.05, "{f1}");
        assert!((f4 - memory::TP4_WEIGHT_FRACTION).abs() < 0.05, "{f4}");
    }

    #[test]
    fn kv_move_sm_scaling_sane() {
        assert!(transform::KV_MOVE_MS_1SM > transform::KV_MOVE_MS_78SM);
    }
}
