//! Model architecture configurations.
//!
//! Shapes drive every memory/cost computation in the reproduction: Table 3
//! page counts, weight padding plans, KV-cache sizing, and the step-time
//! model. All listed models come from the paper (Tables 3 & 4) plus
//! `gyges-tiny`, the small real model served end-to-end through PJRT.


/// Activation function used by the MLP (affects whether a gate projection
/// exists: SwiGLU models carry `gate_proj` + `up_proj`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpKind {
    /// Two GEMMs: up (h→i), down (i→h). (Classic FFN, e.g. GPT-style.)
    Gelu,
    /// Three GEMMs: gate (h→i), up (h→i), down (i→h). (Llama/Qwen.)
    SwiGlu,
}

/// A transformer model's architecture (decoder-only).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub hidden_size: u64,
    pub inter_size: u64,
    pub num_layers: u64,
    pub num_heads: u64,
    pub num_kv_heads: u64,
    pub head_dim: u64,
    pub vocab_size: u64,
    /// Number of MoE experts (0 ⇒ dense).
    pub num_experts: u64,
    /// Bytes per weight/KV element (2 for BF16).
    pub dtype_bytes: u64,
    pub mlp: MlpKind,
}

impl ModelConfig {
    // ------------------------------------------------------------------
    // Weight sizes
    // ------------------------------------------------------------------

    /// Bytes of one MLP up-projection (h × i) weight tensor (per expert).
    pub fn up_proj_bytes(&self) -> u64 {
        self.hidden_size * self.inter_size * self.dtype_bytes
    }

    /// Bytes of one MLP down-projection (i × h) weight tensor (per expert).
    pub fn down_proj_bytes(&self) -> u64 {
        self.inter_size * self.hidden_size * self.dtype_bytes
    }

    /// Total MLP weight bytes in one layer (all experts, all projections).
    pub fn mlp_layer_bytes(&self) -> u64 {
        let per_expert = match self.mlp {
            MlpKind::Gelu => self.up_proj_bytes() + self.down_proj_bytes(),
            MlpKind::SwiGlu => 2 * self.up_proj_bytes() + self.down_proj_bytes(),
        };
        per_expert * self.num_experts.max(1)
    }

    /// Attention weight bytes in one layer (QKV + output projection).
    pub fn attn_layer_bytes(&self) -> u64 {
        let q = self.hidden_size * self.num_heads * self.head_dim;
        let kv = 2 * self.hidden_size * self.num_kv_heads * self.head_dim;
        let o = self.num_heads * self.head_dim * self.hidden_size;
        (q + kv + o) * self.dtype_bytes
    }

    /// Embedding + LM-head bytes (untied).
    pub fn embedding_bytes(&self) -> u64 {
        2 * self.vocab_size * self.hidden_size * self.dtype_bytes
    }

    /// Total model weight bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.num_layers * (self.mlp_layer_bytes() + self.attn_layer_bytes())
            + self.embedding_bytes()
    }

    /// Fraction of the total weights that the MLP constitutes. The paper
    /// reports ~88% for its models, motivating MLP-only transformation.
    pub fn mlp_weight_fraction(&self) -> f64 {
        (self.num_layers * self.mlp_layer_bytes()) as f64 / self.total_weight_bytes() as f64
    }

    /// Per-worker weight bytes under TP `tp` with Gyges' scheme:
    /// MLP weights are sharded, attention + embeddings are replicated
    /// ("keeping other weights duplicated for implementation simplicity",
    /// §4.2).
    pub fn worker_weight_bytes_gyges(&self, tp: u64) -> u64 {
        self.num_layers * (self.mlp_layer_bytes() / tp + self.attn_layer_bytes())
            + self.embedding_bytes()
    }

    /// Per-worker weight bytes under classic full TP sharding (attention
    /// heads and MLP both divided; embeddings replicated).
    pub fn worker_weight_bytes_full_tp(&self, tp: u64) -> u64 {
        self.num_layers * ((self.mlp_layer_bytes() + self.attn_layer_bytes()) / tp)
            + self.embedding_bytes()
    }

    // ------------------------------------------------------------------
    // KV cache sizes
    // ------------------------------------------------------------------

    /// KV-cache bytes for ONE token across all layers (whole model).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.num_layers * self.num_kv_heads * self.head_dim * self.dtype_bytes
    }

    /// KV bytes per token per layer.
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * self.num_kv_heads * self.head_dim * self.dtype_bytes
    }

    /// KV bytes per token per layer per head (the migration quantum).
    pub fn kv_bytes_per_token_layer_head(&self) -> u64 {
        2 * self.head_dim * self.dtype_bytes
    }

    // ------------------------------------------------------------------
    // Presets (Tables 3 & 4 of the paper)
    // ------------------------------------------------------------------

    pub fn qwen2_5_32b() -> ModelConfig {
        ModelConfig {
            name: "qwen2.5-32b",
            hidden_size: 5120,
            inter_size: 27648,
            num_layers: 64,
            num_heads: 40,
            num_kv_heads: 8,
            head_dim: 128,
            vocab_size: 152064,
            num_experts: 0,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn qwen3_32b() -> ModelConfig {
        ModelConfig {
            name: "qwen3-32b",
            hidden_size: 5120,
            inter_size: 25600,
            num_layers: 64,
            num_heads: 64,
            num_kv_heads: 8,
            head_dim: 128,
            vocab_size: 151936,
            num_experts: 0,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn llama2_7b() -> ModelConfig {
        ModelConfig {
            name: "llama2-7b",
            hidden_size: 4096,
            inter_size: 11008,
            num_layers: 32,
            num_heads: 32,
            num_kv_heads: 32,
            head_dim: 128,
            vocab_size: 32000,
            num_experts: 0,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn llama3_8b() -> ModelConfig {
        ModelConfig {
            name: "llama3-8b",
            hidden_size: 4096,
            inter_size: 14336,
            num_layers: 32,
            num_heads: 32,
            num_kv_heads: 8,
            head_dim: 128,
            vocab_size: 128256,
            num_experts: 0,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn llama3_1_70b() -> ModelConfig {
        ModelConfig {
            name: "llama3.1-70b",
            hidden_size: 8192,
            inter_size: 28672,
            num_layers: 80,
            num_heads: 64,
            num_kv_heads: 8,
            head_dim: 128,
            vocab_size: 128256,
            num_experts: 0,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn gpt_oss_120b() -> ModelConfig {
        ModelConfig {
            name: "gpt-oss-120b",
            hidden_size: 2880,
            inter_size: 2880,
            num_layers: 36,
            num_heads: 64,
            num_kv_heads: 8,
            head_dim: 64,
            vocab_size: 201088,
            num_experts: 128,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    pub fn gpt_oss_20b() -> ModelConfig {
        ModelConfig {
            name: "gpt-oss-20b",
            hidden_size: 2880,
            inter_size: 2880,
            num_layers: 24,
            num_heads: 64,
            num_kv_heads: 8,
            head_dim: 64,
            vocab_size: 201088,
            num_experts: 32,
            dtype_bytes: 2,
            mlp: MlpKind::SwiGlu,
        }
    }

    /// The small real model served end-to-end via PJRT in `examples/serve_e2e`.
    /// Shapes mirror python/compile/model.py and must stay in sync with it.
    pub fn gyges_tiny() -> ModelConfig {
        ModelConfig {
            name: "gyges-tiny",
            hidden_size: 256,
            inter_size: 1024,
            num_layers: 4,
            num_heads: 8,
            num_kv_heads: 8,
            head_dim: 32,
            vocab_size: 1024,
            num_experts: 0,
            dtype_bytes: 4, // f32 on the CPU PJRT path
            mlp: MlpKind::Gelu,
        }
    }

    /// Look a preset up by name (case-insensitive, '-'/'_'/'.' agnostic).
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        let norm: String = name
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect::<String>()
            .to_ascii_lowercase();
        let all = Self::all();
        all.into_iter().find(|m| {
            m.name
                .chars()
                .filter(|c| c.is_ascii_alphanumeric())
                .collect::<String>()
                .to_ascii_lowercase()
                == norm
        })
    }

    /// All presets.
    pub fn all() -> Vec<ModelConfig> {
        vec![
            Self::qwen2_5_32b(),
            Self::qwen3_32b(),
            Self::llama2_7b(),
            Self::llama3_8b(),
            Self::llama3_1_70b(),
            Self::gpt_oss_120b(),
            Self::gpt_oss_20b(),
            Self::gyges_tiny(),
        ]
    }

    /// The four evaluation models of Table 4.
    pub fn eval_set() -> Vec<ModelConfig> {
        vec![
            Self::llama2_7b(),
            Self::llama3_8b(),
            Self::qwen2_5_32b(),
            Self::qwen3_32b(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen32b_weight_size_matches_paper() {
        // Paper: Qwen2.5-32B BF16 weighs 62.34 GB.
        let m = ModelConfig::qwen2_5_32b();
        let gb = m.total_weight_bytes() as f64 / 1e9;
        assert!(
            (gb - 62.34).abs() < 3.5,
            "expected ~62.34 GB, got {gb:.2} GB"
        );
    }

    #[test]
    fn llama2_7b_weight_size_matches_paper() {
        // Paper Table 4: 15.67 GB.
        // Our shape math gives 13.5 GB (2 bytes × 6.7B params); the paper's
        // 15.67 GB likely includes optimizer/runtime extras — accept ±2.5.
        let m = ModelConfig::llama2_7b();
        let gb = m.total_weight_bytes() as f64 / 1e9;
        assert!((gb - 15.67).abs() < 2.5, "got {gb:.2} GB");
    }

    #[test]
    fn llama3_8b_weight_size_matches_paper() {
        // Paper Table 4: 16.66 GB.
        let m = ModelConfig::llama3_8b();
        let gb = m.total_weight_bytes() as f64 / 1e9;
        assert!((gb - 16.66).abs() < 2.0, "got {gb:.2} GB");
    }

    #[test]
    fn mlp_dominates_weights() {
        // Paper §4.2: MLP constitutes ~88% of total weights.
        for m in [ModelConfig::qwen2_5_32b(), ModelConfig::llama3_1_70b()] {
            let f = m.mlp_weight_fraction();
            assert!((0.70..0.95).contains(&f), "{}: mlp fraction {f}", m.name);
        }
    }

    #[test]
    fn worker_weights_shrink_with_tp() {
        let m = ModelConfig::qwen2_5_32b();
        let w1 = m.worker_weight_bytes_gyges(1);
        let w2 = m.worker_weight_bytes_gyges(2);
        let w4 = m.worker_weight_bytes_gyges(4);
        assert!(w1 > w2 && w2 > w4);
        // MLP-sharding only: w4 > w1/4 because attention stays replicated.
        assert!(w4 > w1 / 4);
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = ModelConfig::qwen2_5_32b();
        // 2 (K,V) × 64 layers × 8 kv_heads × 128 dim × 2 B = 524288 B
        assert_eq!(m.kv_bytes_per_token(), 2 * 64 * 8 * 128 * 2);
        assert_eq!(
            m.kv_bytes_per_token(),
            m.num_layers * m.kv_bytes_per_token_layer()
        );
        assert_eq!(
            m.kv_bytes_per_token_layer(),
            m.num_kv_heads * m.kv_bytes_per_token_layer_head()
        );
    }

    #[test]
    fn by_name_is_tolerant() {
        assert_eq!(
            ModelConfig::by_name("Qwen2.5-32B").unwrap().name,
            "qwen2.5-32b"
        );
        assert_eq!(
            ModelConfig::by_name("qwen2_5_32b").unwrap().name,
            "qwen2.5-32b"
        );
        assert!(ModelConfig::by_name("nonexistent-9000b").is_none());
    }

    #[test]
    fn tiny_model_is_small() {
        let m = ModelConfig::gyges_tiny();
        assert!(m.total_weight_bytes() < crate::util::bytes::GIB);
    }

    #[test]
    fn moe_models_scale_with_experts() {
        let big = ModelConfig::gpt_oss_120b();
        let small = ModelConfig::gpt_oss_20b();
        assert_eq!(
            big.mlp_layer_bytes() / big.num_experts,
            small.mlp_layer_bytes() / small.num_experts
        );
        assert!(big.mlp_layer_bytes() > small.mlp_layer_bytes());
    }
}
