//! GPU device specifications for the simulated substrate.
//!
//! Constants follow the paper's testbed (§6.1): H20 (96 GB) and A100
//! (40 GB) hosts with 8 GPUs each, NVLink intra-host. The absolute numbers
//! only set the scale; all reproduced results are ratios between
//! strategies that share a spec.

/// A GPU device type.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Device memory capacity in bytes.
    pub hbm_bytes: u64,
    /// Streaming-multiprocessor count (SM contention model for all-to-all).
    pub sm_count: u32,
    /// Dense BF16 throughput in FLOP/s.
    pub bf16_flops: f64,
    /// HBM bandwidth in bytes/s.
    pub hbm_bw: f64,
    /// Per-direction NVLink bandwidth in bytes/s (intra-host GPU↔GPU).
    pub nvlink_bw: f64,
    /// PCIe bandwidth to host memory in bytes/s (Seesaw's migration path).
    pub pcie_bw: f64,
}

impl GpuSpec {
    /// NVIDIA H20: 96 GB HBM3, 78 SMs, ~148 TFLOPs BF16, 4.0 TB/s HBM,
    /// 900 GB/s NVLink aggregate (450 GB/s per direction), PCIe gen5 x16.
    pub fn h20() -> GpuSpec {
        GpuSpec {
            name: "h20",
            hbm_bytes: 96 * crate::util::GIB,
            sm_count: 78,
            bf16_flops: 148e12,
            hbm_bw: 4.0e12,
            nvlink_bw: 450e9,
            pcie_bw: 55e9,
        }
    }

    /// NVIDIA A100 40 GB: 108 SMs, 312 TFLOPs BF16, 1.55 TB/s HBM,
    /// 600 GB/s NVLink aggregate (300 GB/s per direction), PCIe gen4 x16.
    pub fn a100_40g() -> GpuSpec {
        GpuSpec {
            name: "a100-40g",
            hbm_bytes: 40 * crate::util::GIB,
            sm_count: 108,
            bf16_flops: 312e12,
            hbm_bw: 1.55e12,
            nvlink_bw: 300e9,
            pcie_bw: 28e9,
        }
    }

    /// Look up by name.
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "h20" => Some(Self::h20()),
            "a100" | "a100-40g" | "a100_40g" => Some(Self::a100_40g()),
            _ => None,
        }
    }

    /// The GPU the paper pairs with this model (§6.1 Table 4): a single GPU
    /// must fit the whole model.
    pub fn for_model(model: &crate::config::ModelConfig) -> GpuSpec {
        if model.total_weight_bytes() > 30 * crate::util::GIB {
            Self::h20()
        } else {
            Self::a100_40g()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn capacities_match_paper() {
        assert_eq!(GpuSpec::h20().hbm_bytes, 96 * crate::util::GIB);
        assert_eq!(GpuSpec::a100_40g().hbm_bytes, 40 * crate::util::GIB);
        assert_eq!(GpuSpec::h20().sm_count, 78); // paper: "using 78 SMs"
    }

    #[test]
    fn model_gpu_pairing_matches_table4() {
        assert_eq!(GpuSpec::for_model(&ModelConfig::llama2_7b()).name, "a100-40g");
        assert_eq!(GpuSpec::for_model(&ModelConfig::llama3_8b()).name, "a100-40g");
        assert_eq!(GpuSpec::for_model(&ModelConfig::qwen2_5_32b()).name, "h20");
        assert_eq!(GpuSpec::for_model(&ModelConfig::qwen3_32b()).name, "h20");
    }

    #[test]
    fn by_name_lookup() {
        assert!(GpuSpec::by_name("H20").is_some());
        assert!(GpuSpec::by_name("a100").is_some());
        assert!(GpuSpec::by_name("tpu-v5e").is_none());
    }
}
