//! TOML-subset parser for cluster/experiment config files.
//!
//! Supports: `[section]` headers, `key = value` with string/int/float/bool
//! values, `#` comments, and `key = [v1, v2]` arrays of scalars. This is
//! all the launcher needs; the full TOML crate is unavailable offline.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key` → value. Keys before any `[section]`
/// live in the "" section.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unclosed [section]", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            map.insert(key, val);
        }
        Ok(Doc { map })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' begins a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Arr(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word → string (lenient, convenient for model names)
    Ok(Value::Str(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # cluster config
            name = "demo"
            [cluster]
            hosts = 2
            gpus_per_host = 8
            qps = 0.6            # load
            burst = true
            tps = [1, 2, 4]
            model = qwen2.5-32b
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "demo");
        assert_eq!(doc.i64_or("cluster.hosts", 0), 2);
        assert_eq!(doc.f64_or("cluster.qps", 0.0), 0.6);
        assert!(doc.bool_or("cluster.burst", false));
        assert_eq!(doc.str_or("cluster.model", ""), "qwen2.5-32b");
        match doc.get("cluster.tps").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            v => panic!("expected array, got {v:?}"),
        }
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = \"unterminated").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Doc::parse("k = \"a # b\"").unwrap();
        assert_eq!(doc.str_or("k", ""), "a # b");
    }

    #[test]
    fn defaults_apply() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.i64_or("missing", 9), 9);
    }
}
