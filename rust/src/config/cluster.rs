//! Cluster-level configuration: hosts, GPUs, TP choices, scheduler knobs.
//!
//! Loadable from a TOML-subset file (see [`crate::config::parse`]) or
//! constructed programmatically by examples/benches.

use super::gpu::GpuSpec;
use super::model::ModelConfig;
use super::parse::Doc;

/// Which scheduling policy drives the cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Gyges' transformation-aware scheduler (Algorithms 1 & 2).
    Gyges,
    RoundRobin,
    LeastLoadFirst,
}

impl Policy {
    pub fn by_name(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "gyges" => Some(Policy::Gyges),
            "rr" | "round-robin" | "roundrobin" => Some(Policy::RoundRobin),
            "llf" | "least-load" | "leastloadfirst" => Some(Policy::LeastLoadFirst),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Gyges => "gyges",
            Policy::RoundRobin => "rr",
            Policy::LeastLoadFirst => "llf",
        }
    }
}

/// Full policy identity: a base routing policy plus the composed
/// pipeline stages layered on top. This is THE policy-name registry —
/// CLI flags (`--policy`, `branch --policies`, `chaos`), sweep job
/// builders, snapshot fingerprints, and the scheduler pipeline all
/// parse and print through it, so a name round-trips everywhere:
/// `<base>[-cache][-slo][-admit]` (e.g. `gyges`, `rr-slo`,
/// `gyges-cache-slo`, `llf-slo-admit`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PolicyId {
    pub base: Policy,
    /// Prefix-cache-aware scoring: candidate scores are discounted by the
    /// fraction of the request's prefix path already resident in each
    /// instance's cache (and the simulator arms the cache model).
    pub cache: bool,
    /// SLO-class lanes: interactive requests drain the backlog first and
    /// may preempt queued batch prefills (preemption-by-requeue).
    pub slo: bool,
    /// Deadline-aware admission control: a request older than its
    /// class deadline is dropped at the decision stage under overload.
    pub admit: bool,
}

impl PolicyId {
    /// Parse a canonical `<base>[-cache][-slo][-admit]` policy name.
    /// Base aliases (`round-robin`, `least-load`, ...) are accepted;
    /// stage suffixes only in canonical order (`-cache` before `-slo`
    /// before `-admit`).
    pub fn parse(s: &str) -> Option<PolicyId> {
        let lower = s.to_ascii_lowercase();
        let mut rest = lower.as_str();
        let mut admit = false;
        let mut slo = false;
        let mut cache = false;
        if let Some(r) = rest.strip_suffix("-admit") {
            admit = true;
            rest = r;
        }
        if let Some(r) = rest.strip_suffix("-slo") {
            slo = true;
            rest = r;
        }
        if let Some(r) = rest.strip_suffix("-cache") {
            cache = true;
            rest = r;
        }
        Policy::by_name(rest).map(|base| PolicyId { base, cache, slo, admit })
    }

    /// Canonical name. Static so `RoutePolicy::name` (and through it the
    /// snapshot config fingerprint and sweep labels) can return it.
    pub fn name(&self) -> &'static str {
        match (self.base, self.cache, self.slo, self.admit) {
            (Policy::Gyges, false, false, false) => "gyges",
            (Policy::Gyges, false, true, false) => "gyges-slo",
            (Policy::Gyges, false, false, true) => "gyges-admit",
            (Policy::Gyges, false, true, true) => "gyges-slo-admit",
            (Policy::Gyges, true, false, false) => "gyges-cache",
            (Policy::Gyges, true, true, false) => "gyges-cache-slo",
            (Policy::Gyges, true, false, true) => "gyges-cache-admit",
            (Policy::Gyges, true, true, true) => "gyges-cache-slo-admit",
            (Policy::RoundRobin, false, false, false) => "rr",
            (Policy::RoundRobin, false, true, false) => "rr-slo",
            (Policy::RoundRobin, false, false, true) => "rr-admit",
            (Policy::RoundRobin, false, true, true) => "rr-slo-admit",
            (Policy::RoundRobin, true, false, false) => "rr-cache",
            (Policy::RoundRobin, true, true, false) => "rr-cache-slo",
            (Policy::RoundRobin, true, false, true) => "rr-cache-admit",
            (Policy::RoundRobin, true, true, true) => "rr-cache-slo-admit",
            (Policy::LeastLoadFirst, false, false, false) => "llf",
            (Policy::LeastLoadFirst, false, true, false) => "llf-slo",
            (Policy::LeastLoadFirst, false, false, true) => "llf-admit",
            (Policy::LeastLoadFirst, false, true, true) => "llf-slo-admit",
            (Policy::LeastLoadFirst, true, false, false) => "llf-cache",
            (Policy::LeastLoadFirst, true, true, false) => "llf-cache-slo",
            (Policy::LeastLoadFirst, true, false, true) => "llf-cache-admit",
            (Policy::LeastLoadFirst, true, true, true) => "llf-cache-slo-admit",
        }
    }

    /// A plain base policy with no composed stages.
    pub fn plain(&self) -> bool {
        !self.cache && !self.slo && !self.admit
    }
}

impl From<Policy> for PolicyId {
    fn from(base: Policy) -> PolicyId {
        PolicyId { base, cache: false, slo: false, admit: false }
    }
}

impl std::fmt::Display for PolicyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Full cluster + experiment configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    pub hosts: usize,
    pub gpus_per_host: usize,
    /// Allowed TP degrees, ascending (e.g. [1, 2, 4]).
    pub tp_choices: Vec<u64>,
    pub policy: PolicyId,
    /// Algorithm 2 scale-down load threshold.
    pub scale_down_threshold: f64,
    /// Deadline for interactive-class requests under `-admit` policies:
    /// a request still unplaced this many seconds after arrival is shed
    /// at the decision stage instead of retried. Seconds.
    pub slo_interactive_deadline_s: f64,
    /// Deadline for batch-class requests under `-admit` policies.
    pub slo_batch_deadline_s: f64,
    /// Minimum dwell time between transformations on one instance
    /// (oscillation damping), seconds.
    pub min_dwell_s: f64,
    /// Cooldown after a backlog drain pass that placed nothing: no retry
    /// pass runs until it elapses (a scheduled wakeup then retries), so
    /// deferrals are not re-routed on every finish/transform event under
    /// sustained overload. `0` disables the cooldown (retry on every
    /// finish, the pre-PR-2 behaviour).
    pub backlog_retry_cooldown_s: f64,
    /// Placement attempts before a deferred/requeued request is dropped
    /// (admission control under capacity loss). `0` retries forever — the
    /// legacy behaviour, and the default.
    pub retry_max_attempts: u32,
    /// First-retry backoff in seconds for a request that failed placement;
    /// doubles per attempt. `0` disables backoff (the default).
    pub retry_backoff_base_s: f64,
    /// Continuous-batching token budget per step per worker.
    pub max_batch_tokens: u64,
    /// Maximum concurrent decode slots per instance at TP1.
    pub max_batch_size: usize,
    /// Event-loop budget: a run that would process more simulation events
    /// than this terminates with a structured `SimError::EventCapExceeded`
    /// in its outcome instead of aborting the process.
    pub max_events: u64,
    pub seed: u64,
}

impl ClusterConfig {
    /// The paper's §6.2.4 setup: one 8-GPU host, 8×TP1 at start.
    pub fn paper_default(model: ModelConfig) -> ClusterConfig {
        let gpu = GpuSpec::for_model(&model);
        ClusterConfig {
            model,
            gpu,
            hosts: 1,
            gpus_per_host: 8,
            tp_choices: vec![1, 2, 4],
            policy: Policy::Gyges.into(),
            scale_down_threshold: super::calib::workload::SCALE_DOWN_LOAD_THRESHOLD,
            slo_interactive_deadline_s: 30.0,
            slo_batch_deadline_s: 240.0,
            min_dwell_s: 5.0,
            backlog_retry_cooldown_s: 0.05,
            retry_max_attempts: 0,
            retry_backoff_base_s: 0.0,
            max_batch_tokens: 8192,
            // Decode-batch cap at the Table-1 calibration point: the
            // paper's throughput anchors are measured under its
            // TTFT/TPOT SLOs, which bound the continuous batch. Raising
            // this beyond the calibration batch would let high-TP
            // instances escape their measured efficiency penalty.
            max_batch_size: 8,
            max_events: 200_000_000,
            seed: 0xE5EED,
        }
    }

    /// Total GPU count.
    pub fn total_gpus(&self) -> usize {
        self.hosts * self.gpus_per_host
    }

    /// Largest allowed TP degree.
    pub fn max_tp(&self) -> u64 {
        *self.tp_choices.last().unwrap_or(&1)
    }

    /// Next TP degree above `tp`, if any.
    pub fn next_tp_up(&self, tp: u64) -> Option<u64> {
        self.tp_choices.iter().copied().find(|&t| t > tp)
    }

    /// Next TP degree below `tp`, if any.
    pub fn next_tp_down(&self, tp: u64) -> Option<u64> {
        self.tp_choices.iter().rev().copied().find(|&t| t < tp)
    }

    /// Load from a TOML-subset document.
    pub fn from_doc(doc: &Doc) -> Result<ClusterConfig, String> {
        let model_name = doc.str_or("cluster.model", "qwen2.5-32b");
        let model = ModelConfig::by_name(&model_name)
            .ok_or_else(|| format!("unknown model {model_name:?}"))?;
        let mut cfg = ClusterConfig::paper_default(model);
        if let Some(v) = doc.get("cluster.gpu") {
            let name = v.as_str().unwrap_or("");
            cfg.gpu = GpuSpec::by_name(name).ok_or_else(|| format!("unknown gpu {name:?}"))?;
        }
        cfg.hosts = doc.i64_or("cluster.hosts", cfg.hosts as i64) as usize;
        cfg.gpus_per_host = doc.i64_or("cluster.gpus_per_host", cfg.gpus_per_host as i64) as usize;
        if let Some(p) = doc.get("scheduler.policy") {
            let name = p.as_str().unwrap_or("");
            cfg.policy =
                PolicyId::parse(name).ok_or_else(|| format!("unknown policy {name:?}"))?;
        }
        cfg.scale_down_threshold =
            doc.f64_or("scheduler.scale_down_threshold", cfg.scale_down_threshold);
        cfg.slo_interactive_deadline_s =
            doc.f64_or("scheduler.slo_interactive_deadline_s", cfg.slo_interactive_deadline_s);
        cfg.slo_batch_deadline_s =
            doc.f64_or("scheduler.slo_batch_deadline_s", cfg.slo_batch_deadline_s);
        cfg.min_dwell_s = doc.f64_or("scheduler.min_dwell_s", cfg.min_dwell_s);
        cfg.backlog_retry_cooldown_s =
            doc.f64_or("scheduler.backlog_retry_cooldown_s", cfg.backlog_retry_cooldown_s);
        cfg.retry_max_attempts =
            doc.i64_or("scheduler.retry_max_attempts", i64::from(cfg.retry_max_attempts)) as u32;
        cfg.retry_backoff_base_s =
            doc.f64_or("scheduler.retry_backoff_base_s", cfg.retry_backoff_base_s);
        cfg.max_batch_tokens = doc.i64_or("batch.max_tokens", cfg.max_batch_tokens as i64) as u64;
        cfg.max_batch_size = doc.i64_or("batch.max_size", cfg.max_batch_size as i64) as usize;
        cfg.max_events = doc.i64_or("sim.max_events", cfg.max_events as i64) as u64;
        cfg.seed = doc.i64_or("seed", cfg.seed as i64) as u64;
        if let Some(super::parse::Value::Arr(tps)) = doc.get("cluster.tp_choices") {
            let mut v: Vec<u64> = tps.iter().filter_map(|t| t.as_i64()).map(|t| t as u64).collect();
            v.sort_unstable();
            if !v.is_empty() {
                cfg.tp_choices = v;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<ClusterConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_doc(&Doc::parse(&text)?)
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.hosts == 0 || self.gpus_per_host == 0 {
            return Err("cluster must have at least one host and one GPU".into());
        }
        if self.tp_choices.is_empty() {
            return Err("tp_choices must be non-empty".into());
        }
        for &tp in &self.tp_choices {
            if tp == 0 || self.gpus_per_host as u64 % tp != 0 {
                return Err(format!("tp {tp} must divide gpus_per_host {}", self.gpus_per_host));
            }
            if self.model.num_kv_heads % tp != 0 && tp <= self.model.num_kv_heads {
                return Err(format!(
                    "tp {tp} must divide kv heads {}",
                    self.model.num_kv_heads
                ));
            }
        }
        let mut sorted = self.tp_choices.clone();
        sorted.sort_unstable();
        if sorted != self.tp_choices {
            return Err("tp_choices must be ascending".into());
        }
        if !(0.0..=1.0).contains(&self.scale_down_threshold) {
            return Err("scale_down_threshold must be in [0,1]".into());
        }
        if !self.slo_interactive_deadline_s.is_finite() || self.slo_interactive_deadline_s <= 0.0 {
            return Err("slo_interactive_deadline_s must be a finite positive number".into());
        }
        if !self.slo_batch_deadline_s.is_finite() || self.slo_batch_deadline_s <= 0.0 {
            return Err("slo_batch_deadline_s must be a finite positive number".into());
        }
        if !self.backlog_retry_cooldown_s.is_finite() || self.backlog_retry_cooldown_s < 0.0 {
            return Err("backlog_retry_cooldown_s must be a finite non-negative number".into());
        }
        if !self.retry_backoff_base_s.is_finite() || self.retry_backoff_base_s < 0.0 {
            return Err("retry_backoff_base_s must be a finite non-negative number".into());
        }
        if self.max_events == 0 {
            return Err("max_events must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        cfg.validate().unwrap();
        assert_eq!(cfg.total_gpus(), 8);
        assert_eq!(cfg.max_tp(), 4);
    }

    #[test]
    fn tp_navigation() {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        assert_eq!(cfg.next_tp_up(1), Some(2));
        assert_eq!(cfg.next_tp_up(2), Some(4));
        assert_eq!(cfg.next_tp_up(4), None);
        assert_eq!(cfg.next_tp_down(4), Some(2));
        assert_eq!(cfg.next_tp_down(1), None);
    }

    #[test]
    fn from_doc_overrides() {
        let doc = Doc::parse(
            r#"
            [cluster]
            model = llama3-8b
            hosts = 2
            gpus_per_host = 8
            tp_choices = [1, 2, 4]
            [scheduler]
            policy = "llf"
            scale_down_threshold = 0.3
            "#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.model.name, "llama3-8b");
        assert_eq!(cfg.hosts, 2);
        assert_eq!(cfg.policy, Policy::LeastLoadFirst.into());
        assert_eq!(cfg.gpu.name, "a100-40g"); // paired automatically
        assert!((cfg.scale_down_threshold - 0.3).abs() < 1e-12);
    }

    #[test]
    fn max_events_parsed_and_validated() {
        let doc = Doc::parse(
            r#"
            [sim]
            max_events = 1234
            "#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.max_events, 1234);
        let mut bad = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        bad.max_events = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn backlog_cooldown_parsed_and_validated() {
        let doc = Doc::parse(
            r#"
            [scheduler]
            backlog_retry_cooldown_s = 0.25
            "#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert!((cfg.backlog_retry_cooldown_s - 0.25).abs() < 1e-12);
        let mut bad = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        bad.backlog_retry_cooldown_s = -1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn retry_knobs_parsed_and_validated() {
        let doc = Doc::parse(
            r#"
            [scheduler]
            retry_max_attempts = 6
            retry_backoff_base_s = 0.2
            "#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.retry_max_attempts, 6);
        assert!((cfg.retry_backoff_base_s - 0.2).abs() < 1e-12);
        let mut bad = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        bad.retry_backoff_base_s = f64::NAN;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn invalid_tp_rejected() {
        let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        cfg.tp_choices = vec![3];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Gyges, Policy::RoundRobin, Policy::LeastLoadFirst] {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
    }

    #[test]
    fn policy_id_names_roundtrip() {
        for base in [Policy::Gyges, Policy::RoundRobin, Policy::LeastLoadFirst] {
            for cache in [false, true] {
                for slo in [false, true] {
                    for admit in [false, true] {
                        let id = PolicyId { base, cache, slo, admit };
                        assert_eq!(PolicyId::parse(id.name()), Some(id), "{}", id.name());
                        assert_eq!(format!("{id}"), id.name());
                    }
                }
            }
        }
        // Base aliases still parse, with and without stage suffixes.
        assert_eq!(PolicyId::parse("round-robin"), Some(Policy::RoundRobin.into()));
        assert_eq!(
            PolicyId::parse("least-load-slo-admit"),
            Some(PolicyId { base: Policy::LeastLoadFirst, cache: false, slo: true, admit: true })
        );
        assert_eq!(
            PolicyId::parse("gyges-cache-slo"),
            Some(PolicyId { base: Policy::Gyges, cache: true, slo: true, admit: false })
        );
        // Only the canonical suffix order is a name.
        assert_eq!(PolicyId::parse("gyges-admit-slo"), None);
        assert_eq!(PolicyId::parse("gyges-slo-cache"), None);
        assert_eq!(PolicyId::parse("bogus"), None);
    }

    #[test]
    fn slo_deadlines_parsed_and_validated() {
        let doc = Doc::parse(
            r#"
            [scheduler]
            policy = "gyges-slo-admit"
            slo_interactive_deadline_s = 12.5
            slo_batch_deadline_s = 99.0
            "#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.policy.name(), "gyges-slo-admit");
        assert!((cfg.slo_interactive_deadline_s - 12.5).abs() < 1e-12);
        assert!((cfg.slo_batch_deadline_s - 99.0).abs() < 1e-12);
        let mut bad = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        bad.slo_interactive_deadline_s = 0.0;
        assert!(bad.validate().is_err());
    }
}
