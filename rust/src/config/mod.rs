//! Configuration: model architectures, GPU specs, cluster/experiment
//! settings, paper calibration constants, and a TOML-subset parser.

pub mod calib;
pub mod cluster;
pub mod gpu;
pub mod model;
pub mod parse;

pub use cluster::{ClusterConfig, Policy, PolicyId};
pub use gpu::GpuSpec;
pub use model::{MlpKind, ModelConfig};
