//! Pluggable filter/score routing pipeline.
//!
//! Routing is decomposed into four stages (the scheduler/plugin/queue
//! split of cluster schedulers like kubernetriks, adapted to Gyges'
//! transformation-aware world):
//!
//! 1. **Candidates** — [`ClusterView::candidates`], the live-instance
//!    source (LoadIndex-backed inside the simulator; blocked-host
//!    masking applies to the merge-candidate accessors, see the
//!    `ClusterView` docs for why assignment candidates are unmasked).
//! 2. **Filters** — [`FilterPlugin`] chain; a candidate survives only if
//!    every filter keeps it.
//! 3. **Score** — one [`ScorePlugin`]; the surviving candidate with the
//!    minimal `(score, id)` wins (first-win ascending-id tie-break,
//!    byte-identical to the legacy first-win scans).
//! 4. **Decision** — maps the winner (or its absence) to a [`Route`]:
//!    `Assign`, `ScaleUp` (merge-group selection), `Defer`, `Drop`
//!    (admission control), or `Preempt` (SLO lanes).
//!
//! The three base policies (`gyges`/`rr`/`llf`) are expressed as stage
//! compositions in [`PipelinePolicy`], proven byte-identical to the
//! legacy implementations (lockstep property tests in-tree; JSONL `cmp`
//! in the `policy-pipeline-verify` CI job). Determinism contract for
//! every plugin: PERF.md §"Scheduler pipeline contract".
//!
//! Indexed acceleration: when the view carries a
//! [`LoadIndex`](super::scheduler::LoadIndex) (`view.load`), the gyges
//! short/long compositions delegate to its `pick_short`/`pick_long` —
//! the scan composition below is the *specification*, and the existing
//! index-vs-scan equivalence property tests prove decision identity.

use super::instance::Instance;
use super::request::ActiveRequest;
use super::scheduler::{
    default_scale_down, needed_tp, pick_merge_group, pick_merge_group_into, scale_up_fallback,
    ClusterView, PolicyState, Route, RoutePolicy, HIGH_TP_SHORT_PENALTY,
};
use crate::config::{Policy, PolicyId};
use crate::sim::clock::SimTime;
use crate::workload::SloClass;

/// Stage context threading policy state (the Gyges reserve) through the
/// filter chain without widening every plugin signature.
pub struct RouteCtx<'a> {
    /// Instances reserved as scale-up headroom (ascending ids).
    pub reserved: &'a [usize],
    /// Load cap applied to reserved instances for short traffic.
    pub reserve_cap: f64,
}

/// Context for compositions with no reserve (everything is kept).
pub const EMPTY_CTX: RouteCtx<'static> = RouteCtx { reserved: &[], reserve_cap: f64::INFINITY };

/// A per-candidate admission filter. MUST be deterministic and
/// side-effect-free: `keep` may read only `(req, inst, view, ctx)`.
pub trait FilterPlugin {
    fn name(&self) -> &'static str;
    fn keep(
        &self,
        req: &ActiveRequest,
        inst: &Instance,
        view: &ClusterView<'_>,
        ctx: &RouteCtx<'_>,
    ) -> bool;
}

/// A per-candidate scorer (lower is better). MUST be deterministic and
/// side-effect-free; ties resolve to the lowest instance id.
pub trait ScorePlugin {
    fn name(&self) -> &'static str;
    fn score(&self, req: &ActiveRequest, inst: &Instance, view: &ClusterView<'_>) -> f64;
}

/// Drop TP1 instances that are mid-transformation (their KV is in
/// flight); TP>1 instances keep serving while re-sharding.
pub struct SkipTransformingTp1;

impl FilterPlugin for SkipTransformingTp1 {
    fn name(&self) -> &'static str {
        "skip-transforming-tp1"
    }

    fn keep(
        &self,
        _: &ActiveRequest,
        inst: &Instance,
        _: &ClusterView<'_>,
        _: &RouteCtx<'_>,
    ) -> bool {
        !(inst.transforming.is_some() && inst.degree == 1)
    }
}

/// Drop any instance that is mid-transformation.
pub struct SkipTransforming;

impl FilterPlugin for SkipTransforming {
    fn name(&self) -> &'static str {
        "skip-transforming"
    }

    fn keep(
        &self,
        _: &ActiveRequest,
        inst: &Instance,
        _: &ClusterView<'_>,
        _: &RouteCtx<'_>,
    ) -> bool {
        inst.transforming.is_none()
    }
}

/// Keep only instances the request fits (sequence limit + projected KV).
pub struct Fits;

impl FilterPlugin for Fits {
    fn name(&self) -> &'static str {
        "fits"
    }

    fn keep(
        &self,
        req: &ActiveRequest,
        inst: &Instance,
        view: &ClusterView<'_>,
        _: &RouteCtx<'_>,
    ) -> bool {
        inst.fits(view.engine, req)
    }
}

/// Keep scale-up headroom: drop reserved instances already loaded past
/// the reserve cap (`check_reserve` in Algorithm 1).
pub struct ReserveHeadroom;

impl FilterPlugin for ReserveHeadroom {
    fn name(&self) -> &'static str {
        "reserve-headroom"
    }

    fn keep(
        &self,
        _: &ActiveRequest,
        inst: &Instance,
        view: &ClusterView<'_>,
        ctx: &RouteCtx<'_>,
    ) -> bool {
        !(inst.load(view.engine) > ctx.reserve_cap && ctx.reserved.contains(&inst.id))
    }
}

/// Keep only TP>1 instances (the long-request lane).
pub struct HighTpOnly;

impl FilterPlugin for HighTpOnly {
    fn name(&self) -> &'static str {
        "high-tp-only"
    }

    fn keep(
        &self,
        _: &ActiveRequest,
        inst: &Instance,
        _: &ClusterView<'_>,
        _: &RouteCtx<'_>,
    ) -> bool {
        inst.degree > 1
    }
}

/// Gyges short-request score: load plus the high-TP drain penalty
/// (Algorithm 2 "reduces the request rate to these instances").
pub struct GygesShortScore;

impl ScorePlugin for GygesShortScore {
    fn name(&self) -> &'static str {
        "gyges-short"
    }

    fn score(&self, _: &ActiveRequest, inst: &Instance, view: &ClusterView<'_>) -> f64 {
        inst.load(view.engine) + if inst.degree > 1 { HIGH_TP_SHORT_PENALTY } else { 0.0 }
    }
}

/// Plain fractional KV load.
pub struct PlainLoad;

impl ScorePlugin for PlainLoad {
    fn name(&self) -> &'static str {
        "load"
    }

    fn score(&self, _: &ActiveRequest, inst: &Instance, view: &ClusterView<'_>) -> f64 {
        inst.load(view.engine)
    }
}

/// Absolute committed tokens (LLF's capacity-fraction-oblivious metric).
/// Exact in f64 for any committed count below 2^53.
pub struct CommittedTokens;

impl ScorePlugin for CommittedTokens {
    fn name(&self) -> &'static str {
        "committed-tokens"
    }

    fn score(&self, _: &ActiveRequest, inst: &Instance, _: &ClusterView<'_>) -> f64 {
        inst.committed_tokens() as f64
    }
}

/// Discount a base score by prefix-cache affinity: the fraction of the
/// request's prefix path already resident on the instance, weighted by
/// [`CACHE_AFFINITY_WEIGHT`]. With no armed cache (or a prefix-free
/// request) every match fraction is 0 and the wrapper scores exactly as
/// its base — so cache-aware compositions degrade to their load-only
/// twins when the workload has no shared prefixes.
pub struct CacheAffinity<S>(pub S);

/// Weight of a full-path cache hit against fractional KV load, balancing
/// locality against load the way cache-aware routers (e.g. SGLang's) mix
/// the two signals. `0.5` means a 100 % prefix hit outweighs half a
/// capacity-unit of load — strong enough to steer repeat prompts to
/// their cache, weak enough that a saturated instance still sheds to an
/// idle one. `0.5 * match_fraction` is exact in f64 (halving is a pure
/// exponent shift), keeping the score arithmetic deterministic.
pub const CACHE_AFFINITY_WEIGHT: f64 = 0.5;

impl<S: ScorePlugin> ScorePlugin for CacheAffinity<S> {
    fn name(&self) -> &'static str {
        "cache-affinity"
    }

    fn score(&self, req: &ActiveRequest, inst: &Instance, view: &ClusterView<'_>) -> f64 {
        let frac = match view.cache {
            Some(c) => c.match_fraction(inst.id, &req.prefix),
            None => 0.0,
        };
        self.0.score(req, inst, view) - CACHE_AFFINITY_WEIGHT * frac
    }
}

/// Run the candidates → filters → score stages: the `(score, id)`-minimal
/// surviving candidate. First-win ascending-id iteration makes the
/// tie-break identical to the legacy strict-`<` scans.
pub fn select_best(
    req: &ActiveRequest,
    view: &ClusterView<'_>,
    ctx: &RouteCtx<'_>,
    filters: &[&dyn FilterPlugin],
    scorer: &dyn ScorePlugin,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for inst in view.candidates() {
        if !filters.iter().all(|f| f.keep(req, inst, view, ctx)) {
            continue;
        }
        let score = scorer.score(req, inst, view);
        let better = match best {
            None => true,
            Some((bs, bid)) => score < bs || (score == bs && inst.id < bid),
        };
        if better {
            best = Some((score, inst.id));
        }
    }
    best.map(|(_, id)| id)
}

/// Gyges base-policy state (Algorithms 1 & 2) carried by the pipeline:
/// the scale-up reserve and the anti-oscillation hysteresis.
struct GygesCore {
    reserved: Vec<usize>,
    reserve_cap: f64,
    last_long_seen: Option<SimTime>,
    long_hold_s: f64,
    /// Reused candidate buffer for reserve computation.
    scratch: Vec<usize>,
}

impl GygesCore {
    fn new(long_hold_s: f64) -> GygesCore {
        GygesCore {
            reserved: Vec::new(),
            reserve_cap: 0.55,
            last_long_seen: None,
            long_hold_s,
            scratch: Vec::new(),
        }
    }

    /// `update_reserve` in Algorithm 2: if no TP>1 instance exists,
    /// reserve the least-loaded mergeable TP1 group.
    fn update_reserve(&mut self, view: &ClusterView<'_>) {
        self.reserved.clear();
        if view.has_high_tp() {
            return;
        }
        let n = (view.cfg.max_tp() as usize).min(view.cfg.gpus_per_host);
        if pick_merge_group_into(view, n, &mut self.scratch) {
            self.reserved.extend_from_slice(&self.scratch);
            self.reserved.sort_unstable();
        }
    }

    /// Short lane: SkipTransformingTp1 → Fits → ReserveHeadroom filters,
    /// GygesShortScore (indexed fast path: `LoadIndex::pick_short`).
    ///
    /// `cache_aware` (the `-cache` stage) swaps the scorer for
    /// [`CacheAffinity`]`(GygesShortScore)` and takes the scan path —
    /// the LoadIndex buckets know nothing about per-request prefix
    /// affinity. With no armed cache or no prefix the discount is 0 and
    /// the scan is the proven-equivalent specification of `pick_short`,
    /// so `gyges-cache` routes exactly like `gyges` on prefix-free work.
    fn route_short(&self, req: &ActiveRequest, view: &ClusterView<'_>, cache_aware: bool) -> Route {
        let ctx = RouteCtx { reserved: &self.reserved, reserve_cap: self.reserve_cap };
        let filters: [&dyn FilterPlugin; 3] = [&SkipTransformingTp1, &Fits, &ReserveHeadroom];
        let picked = if cache_aware {
            select_best(req, view, &ctx, &filters, &CacheAffinity(GygesShortScore))
        } else {
            match view.load {
                Some(idx) => idx.pick_short(
                    view.instances,
                    view.engine,
                    req,
                    &self.reserved,
                    self.reserve_cap,
                ),
                None => select_best(req, view, &ctx, &filters, &GygesShortScore),
            }
        };
        match picked {
            Some(id) => Route::Assign(id),
            None => Route::Defer,
        }
    }

    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>, cache_aware: bool) -> Route {
        self.update_reserve(view);
        let tp1_max = view.engine.max_seq(1);
        let long = req.is_long(tp1_max);
        if long {
            self.last_long_seen = Some(view.now);
        }

        if long {
            // Long lane: HighTpOnly → SkipTransforming → Fits filters,
            // PlainLoad score (indexed fast path: `LoadIndex::pick_long`)
            // — prefer instances already at higher TP (Figure 13).
            let picked = match view.load {
                Some(idx) => idx.pick_long(view.instances, view.engine, req),
                None => select_best(
                    req,
                    view,
                    &EMPTY_CTX,
                    &[&HighTpOnly, &SkipTransforming, &Fits],
                    &PlainLoad,
                ),
            };
            if let Some(id) = picked {
                return Route::Assign(id);
            }
            // Decision stage: scale up at the degree the request needs.
            let Some(to_tp) = needed_tp(req, view) else {
                return Route::Defer;
            };
            if to_tp == 1 {
                // Long by classification but fits TP1 (edge case).
                return self.route_short(req, view, cache_aware);
            }
            // Prefer the reserved group (it was kept under-loaded).
            let reserved: Vec<usize> = self
                .reserved
                .iter()
                .copied()
                .filter(|&id| {
                    let i = &view.instances[id];
                    !i.retired && i.degree == 1 && i.transforming.is_none()
                })
                .collect();
            if reserved.len() >= to_tp as usize {
                let mut members = reserved;
                members.truncate(to_tp as usize);
                return Route::ScaleUp { members, to_tp };
            }
            if let Some(members) = pick_merge_group(view, to_tp as usize) {
                return Route::ScaleUp { members, to_tp };
            }
            return Route::Defer;
        }

        self.route_short(req, view, cache_aware)
    }

    fn should_scale_down(&self, inst: &Instance, view: &ClusterView<'_>) -> bool {
        // Hysteresis: while long traffic is (recently) active, keep the
        // high-TP instance so follow-up longs reuse it.
        if let Some(t) = self.last_long_seen {
            if view.now.since(t).as_secs_f64() < self.long_hold_s {
                return false;
            }
        }
        default_scale_down(inst, view)
    }
}

/// A routing policy assembled from pipeline stages, identified by a
/// [`PolicyId`]: one of three base compositions (`gyges`/`rr`/`llf`),
/// optionally wrapped by the SLO-lane stage (`-slo`: interactive
/// backlog priority + preemption-by-requeue of queued batch prefills)
/// and the admission-control stage (`-admit`: deadline-aware `Drop`).
pub struct PipelinePolicy {
    id: PolicyId,
    /// Present iff `id.base == Policy::Gyges`.
    gyges: Option<GygesCore>,
    /// Round-Robin rotation cursor.
    cursor: usize,
    /// Reused live-id buffer (RR scan fallback).
    scratch: Vec<usize>,
}

impl PipelinePolicy {
    pub fn new(id: PolicyId) -> PipelinePolicy {
        Self::with_long_hold(id, 45.0)
    }

    /// Composition with a custom Gyges anti-oscillation hold (ablation
    /// A3, sweep jobs with a `gyges_hold` override).
    pub fn with_long_hold(id: PolicyId, hold_s: f64) -> PipelinePolicy {
        let gyges = (id.base == Policy::Gyges).then(|| GygesCore::new(hold_s));
        PipelinePolicy { id, gyges, cursor: 0, scratch: Vec::new() }
    }

    /// Rebuild a composition from its snapshot state (any
    /// [`PolicyState`] — the plain legacy-kind variants restore to the
    /// equivalent plain composition).
    pub fn from_state(state: &PolicyState) -> PipelinePolicy {
        match state {
            PolicyState::Pipeline { cache, slo, admit, base } => {
                let mut p = PipelinePolicy::from_state(base);
                p.id.cache = *cache;
                p.id.slo = *slo;
                p.id.admit = *admit;
                p
            }
            PolicyState::Gyges { reserved, reserve_cap, last_long_seen, long_hold_s } => {
                PipelinePolicy {
                    id: Policy::Gyges.into(),
                    gyges: Some(GygesCore {
                        reserved: reserved.clone(),
                        reserve_cap: *reserve_cap,
                        last_long_seen: *last_long_seen,
                        long_hold_s: *long_hold_s,
                        scratch: Vec::new(),
                    }),
                    cursor: 0,
                    scratch: Vec::new(),
                }
            }
            PolicyState::RoundRobin { cursor } => PipelinePolicy {
                cursor: *cursor,
                ..PipelinePolicy::new(Policy::RoundRobin.into())
            },
            PolicyState::LeastLoad => PipelinePolicy::new(Policy::LeastLoadFirst.into()),
        }
    }

    /// RR decision stage: rotate over the live ring; a pick that can
    /// never hold the sequence "collaborates with neighbouring
    /// instances" to scale up (§6.2.4); capacity-only misses rotate on.
    fn route_rr(&mut self, req: &ActiveRequest, view: &ClusterView<'_>, live: &[usize]) -> Route {
        if live.is_empty() {
            return Route::Defer;
        }
        for k in 0..live.len() {
            let id = live[(self.cursor + k) % live.len()];
            let inst = &view.instances[id];
            if inst.transforming.is_some() {
                continue;
            }
            if inst.fits(view.engine, req) {
                self.cursor = (self.cursor + k + 1) % live.len();
                return Route::Assign(id);
            }
            if req.final_len() > inst.max_seq(view.engine) {
                self.cursor = (self.cursor + k + 1) % live.len();
                return scale_up_fallback(req, view);
            }
        }
        Route::Defer
    }

    /// `-cache` stage for the score-free bases (rr/llf): pick the
    /// fitting, non-transforming candidate with the best
    /// load-minus-affinity score, but only commit to it when it actually
    /// holds part of the request's prefix — a zero-hit winner falls
    /// through to the base composition, so `rr-cache`/`llf-cache` behave
    /// exactly like their bases until the cache warms up.
    fn cache_pick(&self, req: &ActiveRequest, view: &ClusterView<'_>) -> Option<usize> {
        let cache = view.cache?;
        if req.prefix.is_empty() {
            return None;
        }
        let id = select_best(
            req,
            view,
            &EMPTY_CTX,
            &[&SkipTransforming, &Fits],
            &CacheAffinity(PlainLoad),
        )?;
        (cache.match_fraction(id, &req.prefix) > 0.0).then_some(id)
    }

    /// Base composition dispatch (everything below the slo/admit stages).
    fn route_base(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        let cache_aware = self.id.cache && view.cache.is_some();
        match self.id.base {
            Policy::Gyges => {
                // gyges-lint: allow(D06) the constructor builds a gyges core for every gyges base
                let core = self.gyges.as_mut().expect("gyges core present for gyges base");
                core.route(req, view, cache_aware)
            }
            Policy::RoundRobin => {
                if cache_aware && !req.is_long(view.engine.max_seq(1)) {
                    if let Some(id) = self.cache_pick(req, view) {
                        return Route::Assign(id);
                    }
                }
                if let Some(idx) = view.load {
                    // The maintained live ring IS the candidate source.
                    return self.route_rr(req, view, idx.live_ids());
                }
                let mut live = std::mem::take(&mut self.scratch);
                live.clear();
                live.extend(view.candidates().map(|i| i.id));
                let route = self.route_rr(req, view, &live);
                self.scratch = live;
                route
            }
            Policy::LeastLoadFirst => {
                if cache_aware && !req.is_long(view.engine.max_seq(1)) {
                    if let Some(id) = self.cache_pick(req, view) {
                        return Route::Assign(id);
                    }
                }
                // SkipTransforming filter, CommittedTokens score — no
                // Fits filter: LLF is deliberately capacity-oblivious,
                // which is what forces Figure 13's extra scale-up.
                let picked =
                    select_best(req, view, &EMPTY_CTX, &[&SkipTransforming], &CommittedTokens);
                let Some(id) = picked else {
                    return Route::Defer;
                };
                let inst = &view.instances[id];
                if inst.fits(view.engine, req) {
                    return Route::Assign(id);
                }
                if req.final_len() > inst.max_seq(view.engine) {
                    return scale_up_fallback(req, view);
                }
                // Its pick is full: any fitting instance, else defer.
                for i in view.candidates() {
                    if i.transforming.is_none() && i.fits(view.engine, req) {
                        return Route::Assign(i.id);
                    }
                }
                Route::Defer
            }
        }
    }

    /// SLO-lane stage: a deferred *interactive* request may preempt
    /// queued batch prefills. Victim choice is optimistic (lowest-id
    /// live instance where evicting every evictable batch prefill would
    /// make the request fit); the simulator resolves it against exact
    /// pending state and degrades to `Defer` when the plan fails.
    fn find_preempt_victim(&self, req: &ActiveRequest, view: &ClusterView<'_>) -> Option<usize> {
        view.candidates()
            .find(|i| i.transforming.is_none() && i.preempt_could_fit(view.engine, req))
            .map(|i| i.id)
    }
}

impl RoutePolicy for PipelinePolicy {
    fn name(&self) -> &'static str {
        self.id.name()
    }

    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        // Admission stage first: a request past its class deadline is
        // shed before consuming a routing decision. Fresh arrivals are
        // always inside the deadline (now == arrival); crash-requeued
        // and backlogged requests keep their original arrival stamp, so
        // sustained overload converges to counted drops.
        if self.id.admit {
            let deadline = match req.class {
                SloClass::Interactive => view.cfg.slo_interactive_deadline_s,
                SloClass::Batch => view.cfg.slo_batch_deadline_s,
            };
            if view.now.since(req.arrival).as_secs_f64() > deadline {
                return Route::Drop;
            }
        }
        let route = self.route_base(req, view);
        if self.id.slo && req.class == SloClass::Interactive && route == Route::Defer {
            if let Some(victim) = self.find_preempt_victim(req, view) {
                return Route::Preempt { victim };
            }
        }
        route
    }

    fn should_scale_down(&mut self, inst: &Instance, view: &ClusterView<'_>) -> bool {
        match &self.gyges {
            Some(core) => core.should_scale_down(inst, view),
            None => default_scale_down(inst, view),
        }
    }

    fn wants_slo_lanes(&self) -> bool {
        self.id.slo
    }

    fn snapshot_state(&self) -> PolicyState {
        let base = match (&self.id.base, &self.gyges) {
            (Policy::Gyges, Some(core)) => PolicyState::Gyges {
                reserved: core.reserved.clone(),
                reserve_cap: core.reserve_cap,
                last_long_seen: core.last_long_seen,
                long_hold_s: core.long_hold_s,
            },
            (Policy::RoundRobin, _) => PolicyState::RoundRobin { cursor: self.cursor },
            (Policy::LeastLoadFirst, _) => PolicyState::LeastLoad,
            (Policy::Gyges, None) => unreachable!("gyges base always carries its core"),
        };
        if self.id.plain() {
            // Plain compositions snapshot as the legacy kinds, so
            // pre-pipeline snapshot bytes are unchanged and still load.
            base
        } else {
            PolicyState::Pipeline {
                cache: self.id.cache,
                slo: self.id.slo,
                admit: self.id.admit,
                base: Box::new(base),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ModelConfig};
    use crate::sim::EngineModel;

    fn setup() -> (ClusterConfig, EngineModel, Vec<Instance>) {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
        let instances: Vec<Instance> =
            (0..8).map(|i| Instance::new(i, 0, vec![i], 1)).collect();
        (cfg, engine, instances)
    }

    fn view<'a>(
        cfg: &'a ClusterConfig,
        engine: &'a EngineModel,
        instances: &'a [Instance],
    ) -> ClusterView<'a> {
        ClusterView {
            instances,
            engine,
            cfg,
            now: SimTime::from_secs_f64(100.0),
            tp1: None,
            load: None,
            blocked_hosts: None,
            cache: None,
        }
    }

    /// Every plain composition must agree with its legacy reference impl
    /// decision-by-decision on a mixed hand-built state.
    #[test]
    fn plain_compositions_match_legacy_decisions() {
        use super::super::scheduler::{GygesPolicy, LeastLoadPolicy, RoundRobinPolicy};
        let (cfg, engine, mut instances) = setup();
        for k in 0..4 {
            instances[0].admit(ActiveRequest::new(200 + k, SimTime::ZERO, 2500, 150));
        }
        instances[1].admit(ActiveRequest::new(300, SimTime::ZERO, 1200, 80));
        instances[7].retired = true;
        let v = view(&cfg, &engine, &instances);
        let reqs: Vec<ActiveRequest> = vec![
            ActiveRequest::new(1, SimTime::ZERO, 1000, 100),
            ActiveRequest::new(2, SimTime::ZERO, 50_000, 256),
            ActiveRequest::new(3, SimTime::ZERO, 20_000, 64),
            ActiveRequest::new(4, SimTime::ZERO, 900, 50),
        ];
        let mut pg = PipelinePolicy::new(Policy::Gyges.into());
        let mut lg = GygesPolicy::default();
        let mut pr = PipelinePolicy::new(Policy::RoundRobin.into());
        let mut lr = RoundRobinPolicy::default();
        let mut pl = PipelinePolicy::new(Policy::LeastLoadFirst.into());
        let mut ll = LeastLoadPolicy;
        for req in &reqs {
            assert_eq!(pg.route(req, &v), lg.route(req, &v), "gyges diverged on {}", req.id);
            assert_eq!(pr.route(req, &v), lr.route(req, &v), "rr diverged on {}", req.id);
            assert_eq!(pl.route(req, &v), ll.route(req, &v), "llf diverged on {}", req.id);
        }
        assert_eq!(pg.snapshot_state(), lg.snapshot_state(), "gyges state kinds must match");
        assert_eq!(pr.snapshot_state(), lr.snapshot_state(), "rr state kinds must match");
        assert_eq!(pl.snapshot_state(), ll.snapshot_state(), "llf state kinds must match");
    }

    #[test]
    fn admit_stage_drops_past_deadline() {
        let (cfg, engine, instances) = setup();
        let mut p = PipelinePolicy::new(PolicyId::parse("gyges-admit").unwrap());
        // Stale interactive request: arrival 100 s ago, deadline 30 s.
        let stale = ActiveRequest::new(1, SimTime::ZERO, 1000, 100);
        assert_eq!(p.route(&stale, &view(&cfg, &engine, &instances)), Route::Drop);
        // Fresh arrival (now == arrival) routes normally.
        let fresh = ActiveRequest::new(2, SimTime::from_secs_f64(100.0), 1000, 100);
        assert!(matches!(p.route(&fresh, &view(&cfg, &engine, &instances)), Route::Assign(_)));
        // Batch class gets the looser deadline.
        let batch = stale.clone().with_class(SloClass::Batch);
        assert!(matches!(
            p.route(&batch, &view(&cfg, &engine, &instances)),
            Route::Assign(_)
        ));
    }

    #[test]
    fn slo_stage_preempts_queued_batch_work() {
        let (cfg, engine, mut instances) = setup();
        // Fill every instance with queued batch prefills so nothing fits.
        for (k, inst) in instances.iter_mut().enumerate() {
            let mut id = 100 + 1000 * k as u64;
            while inst.fits(&engine, &ActiveRequest::new(id, SimTime::ZERO, 3000, 200)) {
                inst.admit(
                    ActiveRequest::new(id, SimTime::ZERO, 3000, 200).with_class(SloClass::Batch),
                );
                id += 1;
            }
        }
        let req = ActiveRequest::new(1, SimTime::from_secs_f64(100.0), 1000, 100);
        // Plain gyges defers; the slo stage preempts the first victim.
        let mut plain = PipelinePolicy::new(Policy::Gyges.into());
        assert_eq!(plain.route(&req, &view(&cfg, &engine, &instances)), Route::Defer);
        let mut slo = PipelinePolicy::new(PolicyId::parse("gyges-slo").unwrap());
        assert_eq!(
            slo.route(&req, &view(&cfg, &engine, &instances)),
            Route::Preempt { victim: 0 }
        );
        // Batch requests never preempt.
        let batch = req.clone().with_class(SloClass::Batch);
        assert_eq!(slo.route(&batch, &view(&cfg, &engine, &instances)), Route::Defer);
        assert!(slo.wants_slo_lanes() && !plain.wants_slo_lanes());
    }

    #[test]
    fn composed_state_roundtrips() {
        let (cfg, engine, instances) = setup();
        let mut p = PipelinePolicy::new(PolicyId::parse("gyges-slo-admit").unwrap());
        let req = ActiveRequest::new(1, SimTime::from_secs_f64(100.0), 50_000, 256);
        let _ = p.route(&req, &view(&cfg, &engine, &instances));
        let state = p.snapshot_state();
        match &state {
            PolicyState::Pipeline { cache: false, slo: true, admit: true, base } => {
                assert!(matches!(**base, PolicyState::Gyges { .. }));
            }
            other => panic!("expected pipeline state, got {other:?}"),
        }
        let restored = PipelinePolicy::from_state(&state);
        assert_eq!(restored.snapshot_state(), state);
        assert_eq!(restored.name(), "gyges-slo-admit");
        // Plain compositions keep the legacy state kinds.
        let plain = PipelinePolicy::new(Policy::RoundRobin.into());
        assert!(matches!(plain.snapshot_state(), PolicyState::RoundRobin { cursor: 0 }));
    }
}
