//! Serving-instance state machine: a TP (or baseline PP/SP) group of
//! workers with its KV pool, request queues, and transformation state.
//!
//! Hot-path contract (see PERF.md): `load`/`fits`/`next_step` are O(1) —
//! the committed-token and context-token sums the schedulers and the step
//! model need are maintained incrementally by the queue-mutation methods
//! below. Mutate `running`/`prefill_queue` only through those methods;
//! direct pushes desynchronise the aggregates (debug builds catch this
//! via [`Instance::debug_assert_consistent`]).
//!
//! The committed-token aggregate is also the input to the scheduler's
//! incremental `LoadIndex` bucketing: inside the simulator, any mutation
//! that changes `committed_tokens` (or `retired`/`degree`) must be
//! followed by `ClusterSim::reindex` so the instance's load bucket stays
//! current — the end-of-run debug rebuild check catches missed sites.

use super::request::{ActiveRequest, Phase};
use crate::config::calib::baselines;
use crate::workload::SloClass;
use crate::sim::clock::{SimDuration, SimTime};
use crate::sim::EngineModel;
use crate::transform::TransformExec;
use std::collections::VecDeque;

/// Parallelism family of an instance (TP for Gyges; PP/SP for the
/// KunServe/LoongServe baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelKind {
    Tp,
    /// Pipeline parallelism (KunServe-style dynamic PP).
    Pp,
    /// Sequence parallelism (LoongServe-style elastic SP).
    Sp,
}

impl ParallelKind {
    /// Stable identifier used by snapshots.
    pub fn name(&self) -> &'static str {
        match self {
            ParallelKind::Tp => "tp",
            ParallelKind::Pp => "pp",
            ParallelKind::Sp => "sp",
        }
    }

    pub fn by_name(s: &str) -> Option<ParallelKind> {
        match s {
            "tp" => Some(ParallelKind::Tp),
            "pp" => Some(ParallelKind::Pp),
            "sp" => Some(ParallelKind::Sp),
            _ => None,
        }
    }
}

/// An in-flight transformation on an instance.
#[derive(Debug)]
pub struct TransformState {
    pub exec: TransformExec,
    /// Set for blocking mechanisms (Seesaw): serving resumes at this time.
    pub blocked_until: Option<SimTime>,
}

/// One serving instance.
#[derive(Debug)]
pub struct Instance {
    pub id: usize,
    pub host: usize,
    /// Global GPU ids owned by this instance.
    pub workers: Vec<usize>,
    pub degree: u64,
    pub kind: ParallelKind,
    /// Requests currently decoding. The front `max_batch_size` entries are
    /// the active continuous batch; stepped survivors rotate to the back.
    /// Mutate through the queue methods, not directly.
    pub running: VecDeque<ActiveRequest>,
    /// Requests admitted but awaiting prefill.
    pub prefill_queue: VecDeque<ActiveRequest>,
    /// KV tokens currently stored (exact: grows by `input_len + 1` at
    /// prefill completion and by 1 per decoded token; shrinks by the
    /// request's full `context_len` at finish).
    pub kv_tokens: u64,
    /// Sum of `final_len` over running + prefill queues (incremental).
    committed_tokens: u64,
    /// Sum of `context_len` over running requests (incremental).
    ctx_tokens: u64,
    pub transforming: Option<TransformState>,
    pub last_transform: SimTime,
    /// True while a Step event is outstanding in the event queue.
    pub stepping: bool,
    /// Retired flag (merged into another instance).
    pub retired: bool,
}

impl Instance {
    pub fn new(id: usize, host: usize, workers: Vec<usize>, degree: u64) -> Instance {
        Instance {
            id,
            host,
            workers,
            degree,
            kind: ParallelKind::Tp,
            running: VecDeque::new(),
            prefill_queue: VecDeque::new(),
            kv_tokens: 0,
            committed_tokens: 0,
            ctx_tokens: 0,
            transforming: None,
            last_transform: SimTime::ZERO,
            stepping: false,
            retired: false,
        }
    }

    /// Rebuild an instance from snapshot parts. The incremental
    /// committed/context aggregates are recomputed from the queues —
    /// they are *defined* as those sums, so recomputation (not blind
    /// restoration) is what keeps a tampered snapshot from silently
    /// desynchronizing the O(1) hot paths.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        id: usize,
        host: usize,
        workers: Vec<usize>,
        degree: u64,
        kind: ParallelKind,
        running: VecDeque<ActiveRequest>,
        prefill_queue: VecDeque<ActiveRequest>,
        kv_tokens: u64,
        transforming: Option<TransformState>,
        last_transform: SimTime,
        stepping: bool,
        retired: bool,
    ) -> Instance {
        let committed_tokens = running
            .iter()
            .chain(prefill_queue.iter())
            .map(|r| r.final_len())
            .sum();
        let ctx_tokens = running.iter().map(|r| r.context_len()).sum();
        Instance {
            id,
            host,
            workers,
            degree,
            kind,
            running,
            prefill_queue,
            kv_tokens,
            committed_tokens,
            ctx_tokens,
            transforming,
            last_transform,
            stepping,
            retired,
        }
    }

    /// KV capacity in tokens for this instance under `engine`'s model.
    pub fn kv_capacity(&self, engine: &EngineModel) -> u64 {
        engine.kv_capacity_tokens(self.degree)
    }

    /// Maximum supported sequence length.
    pub fn max_seq(&self, engine: &EngineModel) -> u64 {
        engine.max_seq(self.degree)
    }

    /// Sum of `final_len` over all admitted requests (O(1)).
    pub fn committed_tokens(&self) -> u64 {
        self.committed_tokens
    }

    /// Load metric used by the schedulers: KV occupancy projected to
    /// completion of admitted requests (O(1)).
    pub fn load(&self, engine: &EngineModel) -> f64 {
        let cap = self.kv_capacity(engine).max(1);
        self.committed_tokens as f64 / cap as f64
    }

    /// Would admitting `req` fit (projected to completion)? O(1).
    pub fn fits(&self, engine: &EngineModel, req: &ActiveRequest) -> bool {
        if req.final_len() > self.max_seq(engine) {
            return false;
        }
        self.committed_tokens + req.final_len() <= self.kv_capacity(engine)
    }

    /// Any running/queued request that exceeds the next-lower degree's
    /// max sequence (Algorithm 2's `no_long_req` check)?
    pub fn has_long_req(&self, engine: &EngineModel, lower_tp: u64) -> bool {
        let lower_max = engine.max_seq(lower_tp);
        self.running
            .iter()
            .chain(self.prefill_queue.iter())
            .any(|r| r.final_len() > lower_max)
    }

    /// Admit a new request into the prefill queue.
    pub fn admit(&mut self, mut req: ActiveRequest) {
        req.phase = Phase::Prefill;
        self.committed_tokens += req.final_len();
        self.prefill_queue.push_back(req);
    }

    /// Re-enqueue a request that is already counted as prefilling on some
    /// instance (merge transfer): no phase change.
    pub fn enqueue_prefill(&mut self, req: ActiveRequest) {
        self.committed_tokens += req.final_len();
        self.prefill_queue.push_back(req);
    }

    /// Complete the prefill of `req_id`: the request leaves the prefill
    /// queue with its first token generated and its KV resident. The
    /// caller decides whether it finishes immediately or keeps decoding
    /// (via [`Instance::enqueue_running`] / [`Instance::release_kv`]).
    pub fn complete_prefill(&mut self, req_id: u64) -> Option<ActiveRequest> {
        let pos = self.prefill_queue.iter().position(|r| r.id == req_id)?;
        let mut req = self.prefill_queue.remove(pos)?;
        self.committed_tokens -= req.final_len();
        req.phase = Phase::Decode;
        req.generated = 1; // prefill emits the first token
        self.kv_tokens += req.input_len + 1;
        Some(req)
    }

    /// Could `req` fit here if every queued batch-class prefill were
    /// requeued? The `-slo` preemption *viability* check the pipeline's
    /// victim search uses — optimistic, because it cannot see which
    /// queued prefill already has its completion event in flight; the
    /// simulator re-validates with [`Instance::preempt_plan`] and
    /// degrades to `Defer` when the exact plan fails. O(queue).
    pub fn preempt_could_fit(&self, engine: &EngineModel, req: &ActiveRequest) -> bool {
        if req.final_len() > self.max_seq(engine) {
            return false;
        }
        let evictable: u64 = self
            .prefill_queue
            .iter()
            .filter(|r| r.class == SloClass::Batch)
            .map(|r| r.final_len())
            .sum();
        evictable > 0
            && self.committed_tokens - evictable + req.final_len() <= self.kv_capacity(engine)
    }

    /// Plan the minimal batch-prefill eviction that makes `req` fit:
    /// newest-queued first (they have waited least), skipping `inflight`
    /// (a prefill whose completion event is already scheduled cannot be
    /// unpicked). Queued prefills hold no KV — eviction only releases
    /// *committed* headroom. `Some(vec![])` when `req` already fits;
    /// `None` when even the full evictable set falls short.
    pub fn preempt_plan(
        &self,
        engine: &EngineModel,
        inflight: Option<u64>,
        req: &ActiveRequest,
    ) -> Option<Vec<u64>> {
        if req.final_len() > self.max_seq(engine) {
            return None;
        }
        let cap = self.kv_capacity(engine);
        let mut committed = self.committed_tokens;
        if committed + req.final_len() <= cap {
            return Some(Vec::new());
        }
        let mut plan = Vec::new();
        for r in self.prefill_queue.iter().rev() {
            if r.class != SloClass::Batch || Some(r.id) == inflight {
                continue;
            }
            committed -= r.final_len();
            plan.push(r.id);
            if committed + req.final_len() <= cap {
                return Some(plan);
            }
        }
        None
    }

    /// Remove the planned prefills and return them for requeueing (KV is
    /// untouched — queued prefills hold none; only the committed-token
    /// aggregate shrinks).
    pub fn evict_prefills(&mut self, ids: &[u64]) -> Vec<ActiveRequest> {
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            if let Some(pos) = self.prefill_queue.iter().position(|r| r.id == id) {
                // gyges-lint: allow(D06) position() just located this index in the same queue
                let req = self.prefill_queue.remove(pos).expect("position just found");
                self.committed_tokens -= req.final_len();
                out.push(req);
            }
        }
        out
    }

    /// Enqueue a decoding request whose KV is already accounted for
    /// (prefill completion or merge transfer).
    pub fn enqueue_running(&mut self, req: ActiveRequest) {
        self.committed_tokens += req.final_len();
        self.ctx_tokens += req.context_len();
        self.running.push_back(req);
    }

    /// Receive a decoding request from a split: KV materialises here.
    pub fn receive_running(&mut self, mut req: ActiveRequest) {
        req.phase = Phase::Decode;
        self.kv_tokens += req.context_len();
        self.enqueue_running(req);
    }

    /// Release the KV a finished request held (its full context).
    pub fn release_kv(&mut self, context_len: u64) {
        debug_assert!(
            self.kv_tokens >= context_len,
            "instance {}: releasing {context_len} KV tokens but only {} stored",
            self.id,
            self.kv_tokens
        );
        self.kv_tokens -= context_len;
    }

    /// Advance the continuous batch one decode step: the front
    /// `min(len, max_batch)` requests each generate a token; survivors
    /// rotate to the back of the queue (batching-window rotation — every
    /// running request makes progress across steps), finished requests
    /// are removed with exact KV/aggregate bookkeeping. Stepped ids are
    /// appended to `stepped`, finished ids to `finished`. O(batch).
    pub fn decode_advance(
        &mut self,
        max_batch: usize,
        stepped: &mut Vec<u64>,
        finished: &mut Vec<u64>,
    ) {
        let batch = self.running.len().min(max_batch);
        for _ in 0..batch {
            let Some(mut r) = self.running.pop_front() else { break };
            self.ctx_tokens -= r.context_len();
            self.committed_tokens -= r.final_len();
            r.generated += 1;
            self.kv_tokens += 1;
            stepped.push(r.id);
            if r.done() {
                self.release_kv(r.context_len());
                finished.push(r.id);
            } else {
                self.ctx_tokens += r.context_len();
                self.committed_tokens += r.final_len();
                self.running.push_back(r);
            }
        }
    }

    /// Drain all queued work (merge/split), returning
    /// `(running, prefill, kv_tokens)` and zeroing the aggregates.
    pub fn take_work(&mut self) -> (VecDeque<ActiveRequest>, VecDeque<ActiveRequest>, u64) {
        let running = std::mem::take(&mut self.running);
        let prefill = std::mem::take(&mut self.prefill_queue);
        let kv = std::mem::take(&mut self.kv_tokens);
        self.committed_tokens = 0;
        self.ctx_tokens = 0;
        (running, prefill, kv)
    }

    /// Pooled-buffer form of [`Instance::take_work`]: appends the drained
    /// requests front-to-back (same order `take_work`'s deques iterate)
    /// into caller-owned scratch vectors and returns the drained KV token
    /// count. The instance's own ring buffers keep their capacity, so a
    /// transform on a warm instance allocates nothing (PERF.md arena
    /// rules).
    pub fn drain_work_into(
        &mut self,
        running: &mut Vec<ActiveRequest>,
        prefill: &mut Vec<ActiveRequest>,
    ) -> u64 {
        running.extend(self.running.drain(..));
        prefill.extend(self.prefill_queue.drain(..));
        self.committed_tokens = 0;
        self.ctx_tokens = 0;
        std::mem::take(&mut self.kv_tokens)
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.prefill_queue.is_empty()
    }

    /// Total active requests.
    pub fn active_count(&self) -> usize {
        self.running.len() + self.prefill_queue.len()
    }

    /// Recompute the incremental aggregates from the queues and compare
    /// (debug builds only). An idle instance must hold zero KV tokens —
    /// the admission/finish bookkeeping is exact, not saturating.
    pub fn debug_assert_consistent(&self) {
        #[cfg(debug_assertions)]
        {
            let committed: u64 = self
                .running
                .iter()
                .map(|r| r.final_len())
                .chain(self.prefill_queue.iter().map(|r| r.final_len()))
                .sum();
            assert_eq!(
                committed, self.committed_tokens,
                "instance {}: committed-token aggregate drifted",
                self.id
            );
            let ctx: u64 = self.running.iter().map(|r| r.context_len()).sum();
            assert_eq!(
                ctx, self.ctx_tokens,
                "instance {}: context-token aggregate drifted",
                self.id
            );
            if self.is_idle() {
                assert_eq!(
                    self.kv_tokens, 0,
                    "instance {}: KV tokens must drain to zero when idle",
                    self.id
                );
            }
        }
    }

    /// Duration of the next serving step; also describes what it does.
    /// O(1): the decode average context uses the incremental sum.
    pub fn next_step(&self, engine: &EngineModel, max_batch: usize) -> Option<StepKind> {
        if self.retired {
            return None;
        }
        if let Some(req) = self.prefill_queue.front() {
            // Prefix-cache hits shorten the compute, never the KV bill:
            // the duration covers only the uncached suffix (at least one
            // token, so every prefill still takes a step), while capacity
            // accounting elsewhere keeps charging the full prompt. With no
            // hit the expression is exactly `input_len` — the cache-off
            // path stays bit-identical to the pre-cache model.
            let compute_len = if req.cached_tokens == 0 {
                req.input_len
            } else {
                req.input_len.saturating_sub(req.cached_tokens).max(1)
            };
            let t = self.step_scale(engine.prefill(self.degree, compute_len));
            return Some(StepKind::Prefill { req_id: req.id, duration: t });
        }
        if !self.running.is_empty() {
            let batch = self.running.len().min(max_batch) as u64;
            let avg_ctx = self.ctx_tokens / self.running.len() as u64;
            let t = self.step_scale(engine.decode_step(self.degree, batch, avg_ctx));
            return Some(StepKind::Decode { duration: t });
        }
        None
    }

    /// Apply the PP/SP efficiency penalty (§2 / §3.3: PP and SP activate a
    /// fraction of GPUs per time slot; measured as 43.5% extra throughput
    /// degradation) to a step duration.
    fn step_scale(&self, d: SimDuration) -> SimDuration {
        match self.kind {
            ParallelKind::Tp => d,
            ParallelKind::Pp | ParallelKind::Sp => {
                if self.degree > 1 {
                    d.scale(1.0 / (1.0 - baselines::PP_SP_EXTRA_DEGRADATION))
                } else {
                    d
                }
            }
        }
    }
}

/// What the next step of an instance does.
#[derive(Clone, Copy, Debug)]
pub enum StepKind {
    Prefill { req_id: u64, duration: SimDuration },
    Decode { duration: SimDuration },
}

impl StepKind {
    pub fn duration(&self) -> SimDuration {
        match self {
            StepKind::Prefill { duration, .. } | StepKind::Decode { duration } => *duration,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelConfig};

    fn engine() -> EngineModel {
        EngineModel::new(ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    fn req(id: u64, input: u64, output: u64) -> ActiveRequest {
        ActiveRequest::new(id, SimTime::ZERO, input, output)
    }

    #[test]
    fn admit_and_fit() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        assert!(inst.fits(&e, &req(1, 1000, 100)));
        assert!(!inst.fits(&e, &req(2, 50_000, 100)), "long must not fit TP1");
        inst.admit(req(1, 1000, 100));
        assert_eq!(inst.active_count(), 1);
        assert!(inst.load(&e) > 0.0);
        inst.debug_assert_consistent();
    }

    #[test]
    fn capacity_projection_blocks_overcommit() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        let cap = inst.kv_capacity(&e);
        let mut admitted = 0u64;
        loop {
            let r = req(admitted, 3000, 200);
            if !inst.fits(&e, &r) {
                break;
            }
            inst.admit(r);
            admitted += 1;
            assert!(admitted < 100_000, "runaway");
        }
        let committed: u64 = inst.prefill_queue.iter().map(|r| r.final_len()).sum();
        assert!(committed <= cap);
        assert_eq!(committed, inst.committed_tokens(), "aggregate matches rescan");
        assert!(admitted > 0);
    }

    #[test]
    fn step_kind_sequence() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        assert!(inst.next_step(&e, 64).is_none());
        inst.admit(req(1, 1000, 4));
        match inst.next_step(&e, 64) {
            Some(StepKind::Prefill { req_id: 1, .. }) => {}
            other => panic!("expected prefill, got {other:?}"),
        }
        // move to decode
        let r = inst.complete_prefill(1).unwrap();
        inst.enqueue_running(r);
        match inst.next_step(&e, 64) {
            Some(StepKind::Decode { .. }) => {}
            other => panic!("expected decode, got {other:?}"),
        }
        inst.debug_assert_consistent();
    }

    #[test]
    fn pp_sp_penalty_applies() {
        let e = engine();
        let mut tp = Instance::new(0, 0, vec![0, 1, 2, 3], 4);
        let mut r = req(1, 1000, 64);
        r.phase = Phase::Decode;
        r.generated = 1;
        tp.enqueue_running(r.clone());
        let t_tp = tp.next_step(&e, 64).unwrap().duration();
        let mut pp = Instance::new(1, 0, vec![4, 5, 6, 7], 4);
        pp.kind = ParallelKind::Pp;
        pp.enqueue_running(r);
        let t_pp = pp.next_step(&e, 64).unwrap().duration();
        let ratio = t_pp.as_secs_f64() / t_tp.as_secs_f64();
        assert!((ratio - 1.0 / (1.0 - 0.435)).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn long_req_detection_for_scale_down() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0, 1, 2, 3], 4);
        let mut r = req(1, 30_000, 256);
        r.phase = Phase::Decode;
        inst.enqueue_running(r);
        assert!(inst.has_long_req(&e, 1), "30K ctx exceeds TP1 max");
        assert!(!inst.has_long_req(&e, 2), "30K fits TP2");
    }

    #[test]
    fn full_lifecycle_drains_kv_exactly() {
        let mut inst = Instance::new(0, 0, vec![0], 1);
        inst.admit(req(1, 100, 3));
        let r = inst.complete_prefill(1).unwrap();
        assert_eq!(inst.kv_tokens, 101);
        inst.enqueue_running(r);
        let (mut stepped, mut finished) = (Vec::new(), Vec::new());
        // 2 more tokens to reach output_len = 3
        inst.decode_advance(8, &mut stepped, &mut finished);
        assert_eq!(inst.kv_tokens, 102);
        assert!(finished.is_empty());
        inst.decode_advance(8, &mut stepped, &mut finished);
        assert_eq!(finished, vec![1]);
        assert!(inst.is_idle());
        assert_eq!(inst.kv_tokens, 0, "drained instance holds no KV");
        inst.debug_assert_consistent();
    }

    #[test]
    fn decode_window_rotates_for_fairness() {
        let mut inst = Instance::new(0, 0, vec![0], 1);
        for id in 0..4u64 {
            inst.admit(req(id, 10, 100));
            let r = inst.complete_prefill(id).unwrap();
            inst.enqueue_running(r);
        }
        let (mut stepped, mut finished) = (Vec::new(), Vec::new());
        inst.decode_advance(2, &mut stepped, &mut finished);
        assert_eq!(stepped, vec![0, 1]);
        // The stepped pair rotated behind the waiting pair.
        let order: Vec<u64> = inst.running.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 0, 1]);
        stepped.clear();
        inst.decode_advance(2, &mut stepped, &mut finished);
        assert_eq!(stepped, vec![2, 3]);
        inst.debug_assert_consistent();
    }
}
