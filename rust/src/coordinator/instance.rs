//! Serving-instance state machine: a TP (or baseline PP/SP) group of
//! workers with its KV pool, request queues, and transformation state.

use super::request::{ActiveRequest, Phase};
use crate::config::calib::baselines;
use crate::sim::clock::{SimDuration, SimTime};
use crate::sim::EngineModel;
use crate::transform::TransformExec;
use std::collections::VecDeque;

/// Parallelism family of an instance (TP for Gyges; PP/SP for the
/// KunServe/LoongServe baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelKind {
    Tp,
    /// Pipeline parallelism (KunServe-style dynamic PP).
    Pp,
    /// Sequence parallelism (LoongServe-style elastic SP).
    Sp,
}

/// An in-flight transformation on an instance.
#[derive(Debug)]
pub struct TransformState {
    pub exec: TransformExec,
    /// Set for blocking mechanisms (Seesaw): serving resumes at this time.
    pub blocked_until: Option<SimTime>,
}

/// One serving instance.
#[derive(Debug)]
pub struct Instance {
    pub id: usize,
    pub host: usize,
    /// Global GPU ids owned by this instance.
    pub workers: Vec<usize>,
    pub degree: u64,
    pub kind: ParallelKind,
    /// Requests currently decoding.
    pub running: Vec<ActiveRequest>,
    /// Requests admitted but awaiting prefill.
    pub prefill_queue: VecDeque<ActiveRequest>,
    /// KV tokens currently stored.
    pub kv_tokens: u64,
    pub transforming: Option<TransformState>,
    pub last_transform: SimTime,
    /// True while a Step event is outstanding in the event queue.
    pub stepping: bool,
    /// Retired flag (merged into another instance).
    pub retired: bool,
}

impl Instance {
    pub fn new(id: usize, host: usize, workers: Vec<usize>, degree: u64) -> Instance {
        Instance {
            id,
            host,
            workers,
            degree,
            kind: ParallelKind::Tp,
            running: Vec::new(),
            prefill_queue: VecDeque::new(),
            kv_tokens: 0,
            transforming: None,
            last_transform: SimTime::ZERO,
            stepping: false,
            retired: false,
        }
    }

    /// KV capacity in tokens for this instance under `engine`'s model.
    pub fn kv_capacity(&self, engine: &EngineModel) -> u64 {
        engine.kv_capacity_tokens(self.degree)
    }

    /// Maximum supported sequence length.
    pub fn max_seq(&self, engine: &EngineModel) -> u64 {
        engine.max_seq(self.degree)
    }

    /// Load metric used by the schedulers: KV occupancy projected to
    /// completion of admitted requests.
    pub fn load(&self, engine: &EngineModel) -> f64 {
        let cap = self.kv_capacity(engine).max(1);
        let committed: u64 = self
            .running
            .iter()
            .map(|r| r.final_len())
            .chain(self.prefill_queue.iter().map(|r| r.final_len()))
            .sum();
        committed as f64 / cap as f64
    }

    /// Would admitting `req` fit (projected to completion)?
    pub fn fits(&self, engine: &EngineModel, req: &ActiveRequest) -> bool {
        if req.final_len() > self.max_seq(engine) {
            return false;
        }
        let cap = self.kv_capacity(engine);
        let committed: u64 = self
            .running
            .iter()
            .map(|r| r.final_len())
            .chain(self.prefill_queue.iter().map(|r| r.final_len()))
            .sum();
        committed + req.final_len() <= cap
    }

    /// Any running/queued request that exceeds the next-lower degree's
    /// max sequence (Algorithm 2's `no_long_req` check)?
    pub fn has_long_req(&self, engine: &EngineModel, lower_tp: u64) -> bool {
        let lower_max = engine.max_seq(lower_tp);
        self.running
            .iter()
            .chain(self.prefill_queue.iter())
            .any(|r| r.final_len() > lower_max)
    }

    pub fn admit(&mut self, mut req: ActiveRequest) {
        req.phase = Phase::Prefill;
        self.prefill_queue.push_back(req);
    }

    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.prefill_queue.is_empty()
    }

    /// Total active requests.
    pub fn active_count(&self) -> usize {
        self.running.len() + self.prefill_queue.len()
    }

    /// Duration of the next serving step; also describes what it does.
    pub fn next_step(&self, engine: &EngineModel, max_batch: usize) -> Option<StepKind> {
        if self.retired {
            return None;
        }
        if let Some(req) = self.prefill_queue.front() {
            let t = self.step_scale(engine.prefill(self.degree, req.input_len));
            return Some(StepKind::Prefill { req_id: req.id, duration: t });
        }
        if !self.running.is_empty() {
            let batch = self.running.len().min(max_batch) as u64;
            let avg_ctx = self.running.iter().map(|r| r.context_len()).sum::<u64>()
                / self.running.len() as u64;
            let t = self.step_scale(engine.decode_step(self.degree, batch, avg_ctx));
            return Some(StepKind::Decode { duration: t });
        }
        None
    }

    /// Apply the PP/SP efficiency penalty (§2 / §3.3: PP and SP activate a
    /// fraction of GPUs per time slot; measured as 43.5% extra throughput
    /// degradation) to a step duration.
    fn step_scale(&self, d: SimDuration) -> SimDuration {
        match self.kind {
            ParallelKind::Tp => d,
            ParallelKind::Pp | ParallelKind::Sp => {
                if self.degree > 1 {
                    d.scale(1.0 / (1.0 - baselines::PP_SP_EXTRA_DEGRADATION))
                } else {
                    d
                }
            }
        }
    }
}

/// What the next step of an instance does.
#[derive(Clone, Copy, Debug)]
pub enum StepKind {
    Prefill { req_id: u64, duration: SimDuration },
    Decode { duration: SimDuration },
}

impl StepKind {
    pub fn duration(&self) -> SimDuration {
        match self {
            StepKind::Prefill { duration, .. } | StepKind::Decode { duration } => *duration,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelConfig};

    fn engine() -> EngineModel {
        EngineModel::new(ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    fn req(id: u64, input: u64, output: u64) -> ActiveRequest {
        ActiveRequest::new(id, SimTime::ZERO, input, output)
    }

    #[test]
    fn admit_and_fit() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        assert!(inst.fits(&e, &req(1, 1000, 100)));
        assert!(!inst.fits(&e, &req(2, 50_000, 100)), "long must not fit TP1");
        inst.admit(req(1, 1000, 100));
        assert_eq!(inst.active_count(), 1);
        assert!(inst.load(&e) > 0.0);
    }

    #[test]
    fn capacity_projection_blocks_overcommit() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        let cap = inst.kv_capacity(&e);
        let mut admitted = 0u64;
        loop {
            let r = req(admitted, 3000, 200);
            if !inst.fits(&e, &r) {
                break;
            }
            inst.admit(r);
            admitted += 1;
            assert!(admitted < 100_000, "runaway");
        }
        let committed: u64 = inst.prefill_queue.iter().map(|r| r.final_len()).sum();
        assert!(committed <= cap);
        assert!(admitted > 0);
    }

    #[test]
    fn step_kind_sequence() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0], 1);
        assert!(inst.next_step(&e, 64).is_none());
        inst.admit(req(1, 1000, 4));
        match inst.next_step(&e, 64) {
            Some(StepKind::Prefill { req_id: 1, .. }) => {}
            other => panic!("expected prefill, got {other:?}"),
        }
        // move to decode
        let mut r = inst.prefill_queue.pop_front().unwrap();
        r.phase = Phase::Decode;
        inst.running.push(r);
        match inst.next_step(&e, 64) {
            Some(StepKind::Decode { .. }) => {}
            other => panic!("expected decode, got {other:?}"),
        }
    }

    #[test]
    fn pp_sp_penalty_applies() {
        let e = engine();
        let mut tp = Instance::new(0, 0, vec![0, 1, 2, 3], 4);
        let mut r = req(1, 1000, 64);
        r.phase = Phase::Decode;
        tp.running.push(r.clone());
        let t_tp = tp.next_step(&e, 64).unwrap().duration();
        let mut pp = Instance::new(1, 0, vec![4, 5, 6, 7], 4);
        pp.kind = ParallelKind::Pp;
        pp.running.push(r);
        let t_pp = pp.next_step(&e, 64).unwrap().duration();
        let ratio = t_pp.as_secs_f64() / t_tp.as_secs_f64();
        assert!((ratio - 1.0 / (1.0 - 0.435)).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn long_req_detection_for_scale_down() {
        let e = engine();
        let mut inst = Instance::new(0, 0, vec![0, 1, 2, 3], 4);
        let mut r = req(1, 30_000, 256);
        r.phase = Phase::Decode;
        inst.running.push(r);
        assert!(inst.has_long_req(&e, 1), "30K ctx exceeds TP1 max");
        assert!(!inst.has_long_req(&e, 2), "30K fits TP2");
    }
}
