//! The Gyges coordinator (paper §5): request/instance state machines, the
//! transformation-aware scheduler with RR/LLF baselines, and the
//! event-driven cluster simulation the evaluation runs on.

pub mod cluster;
pub mod instance;
pub mod request;
pub mod scheduler;

pub use cluster::{run_system, ClusterSim, SimCounters, SimOutcome, SystemKind};
pub use instance::{Instance, ParallelKind, StepKind, TransformState};
pub use request::{ActiveRequest, Phase};
pub use scheduler::{
    default_scale_down, make_policy, needed_tp, pick_merge_group, ClusterView, GygesPolicy,
    LeastLoadPolicy, Route, RoundRobinPolicy, RoutePolicy,
};
