//! The Gyges coordinator (paper §5): request/instance state machines, the
//! transformation-aware scheduler with RR/LLF baselines, and the
//! event-driven cluster simulation the evaluation runs on.

pub mod cluster;
pub mod instance;
pub mod request;
pub mod scheduler;

pub use cluster::{
    run_system, ClusterSim, SimCounters, SimError, SimOutcome, SimProfile, SystemKind,
};
pub use instance::{Instance, ParallelKind, StepKind, TransformState};
pub use request::{ActiveRequest, Phase};
pub use cluster::RunStatus;
pub use scheduler::{
    default_scale_down, make_policy, needed_tp, pick_merge_group, pick_merge_group_into,
    ClusterView, GygesPolicy, HIGH_TP_SHORT_PENALTY, HostIndex, LeastLoadPolicy, LoadIndex,
    PolicyState, Route, RoundRobinPolicy, RoutePolicy,
};
