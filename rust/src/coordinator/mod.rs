//! The Gyges coordinator (paper §5): request/instance state machines, the
//! transformation-aware scheduler with RR/LLF baselines, and the
//! event-driven cluster simulation the evaluation runs on.

pub mod cluster;
pub mod instance;
pub mod pipeline;
pub mod request;
pub mod scheduler;

pub use cluster::{
    run_system, ClusterSim, SimCounters, SimError, SimOutcome, SimProfile, SystemKind,
};
pub use instance::{Instance, ParallelKind, StepKind, TransformState};
pub use pipeline::{FilterPlugin, PipelinePolicy, RouteCtx, ScorePlugin};
pub use request::{ActiveRequest, Phase};
pub use cluster::RunStatus;
#[cfg(any(test, feature = "legacy-policies"))]
pub use scheduler::{GygesPolicy, LeastLoadPolicy, RoundRobinPolicy};
pub use scheduler::{
    default_scale_down, make_policy, needed_tp, pick_merge_group, pick_merge_group_into,
    ClusterView, HIGH_TP_SHORT_PENALTY, HostIndex, LoadIndex, PolicyState, Route, RoutePolicy,
};
#[cfg(any(test, feature = "legacy-policies"))]
pub use scheduler::{legacy_routing, set_legacy_routing};
