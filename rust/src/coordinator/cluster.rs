//! The cluster simulation driver: event-driven serving of a request trace
//! across instances, with runtime parallelism transformation.
//!
//! This is the L3 "leader" logic the paper's experiments run on: arrivals
//! are routed by a [`RoutePolicy`], instances execute prefill/decode steps
//! timed by the calibrated [`EngineModel`], and transformations are merged
//! /split live with their visible overhead charged to serving steps.
//!
//! Hot-path contract (see PERF.md): per-event work is O(1)/O(batch) — the
//! merge-candidate [`HostIndex`] and the least-load/live-ring
//! [`LoadIndex`] are maintained incrementally at every mutation that
//! changes topology or an instance's `load()` inputs (admit, prefill
//! completion, decode finishes, merge, split, retire, transform
//! start/finish) instead of being rebuilt or rescanned per routed
//! request, decode completions use the O(batch) rotation in
//! [`Instance::decode_advance`], and the recorder calls are O(1) slab
//! updates. Deferred-request retries are bounded by a cooldown +
//! [`Event::BacklogWakeup`] deadline instead of re-routing the whole
//! backlog on every finish under sustained overload. The event loop is
//! bounded by `ClusterConfig::max_events`; hitting the cap surfaces as
//! [`SimError::EventCapExceeded`] in the [`SimOutcome`] instead of
//! aborting the process. Per-event-type wall-time attribution
//! ([`SimProfile`]) is opt-in via [`ClusterSim::enable_profiling`] so the
//! default loop pays no `Instant::now` calls.
//!
//! Arrivals stream in from an [`ArrivalFeed`] rather than being
//! pre-pushed into the event queue: the loop merges the two streams
//! (arrivals win timestamp ties, matching the seed's sequence-number
//! ordering), so a multi-hour trace replays with O(segment) peak trace
//! memory and output byte-identical to whole-trace replay — see
//! `rust/src/workload/source.rs` and PERF.md.

use super::instance::{Instance, ParallelKind, StepKind, TransformState};
use super::request::ActiveRequest;
use super::scheduler::{
    make_policy, make_policy_with_hold, ClusterView, HostIndex, LoadIndex, Route, RoutePolicy,
};
use crate::cache::{CacheCounters, ClusterCache};
use crate::config::{ClusterConfig, Policy, PolicyId};
use crate::faults::{Fault, FaultKind, FaultPlan, RetryPolicy};
use crate::metrics::{Recorder, RunReport};
use crate::sim::clock::{SimDuration, SimTime};
use crate::sim::{EngineModel, EventQueue};
use crate::snapshot::state::{
    DeferredSnap, EventKindSnap, EventSnap, InstanceSnap, PendingSnap, RecorderSnap, ReqSnap,
    RunContext, SimSnapshot, SimState, TransformSnap,
};
use crate::transform::{estimate, Direction, Mechanism, TransformExec, TransformPlan};
use crate::workload::{ArrivalFeed, SloClass, Trace, TraceRequest, TraceSource};
use std::collections::VecDeque;
use std::fmt;
use std::time::Instant;

/// Which end-to-end system is being simulated (Figure 14 series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Full Gyges (TP transformation, header-centric KV, padding, overlap).
    Gyges,
    /// Gyges without overlapping (ablation, §6.3).
    GygesNoOverlap,
    /// TP transformation with basic KV/weight mechanisms.
    Basic,
    /// Seesaw: blocking CPU-shared-memory re-sharding.
    Seesaw,
    /// KunServe: dynamic pipeline parallelism.
    KunServe,
    /// LoongServe: elastic sequence parallelism.
    LoongServe,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Gyges => "gyges",
            SystemKind::GygesNoOverlap => "gyges-",
            SystemKind::Basic => "basic",
            SystemKind::Seesaw => "seesaw",
            SystemKind::KunServe => "kunserve",
            SystemKind::LoongServe => "loongserve",
        }
    }

    /// Inverse of [`SystemKind::name`] (CLI + snapshot decoding).
    pub fn by_name(s: &str) -> Option<SystemKind> {
        match s {
            "gyges" => Some(SystemKind::Gyges),
            "gyges-" => Some(SystemKind::GygesNoOverlap),
            "basic" => Some(SystemKind::Basic),
            "seesaw" => Some(SystemKind::Seesaw),
            "kunserve" => Some(SystemKind::KunServe),
            "loongserve" => Some(SystemKind::LoongServe),
            _ => None,
        }
    }

    fn parallel_kind(&self) -> ParallelKind {
        match self {
            SystemKind::KunServe => ParallelKind::Pp,
            SystemKind::LoongServe => ParallelKind::Sp,
            _ => ParallelKind::Tp,
        }
    }

    fn mechanism(&self) -> Option<Mechanism> {
        match self {
            SystemKind::Gyges => Some(Mechanism::Gyges),
            SystemKind::GygesNoOverlap => Some(Mechanism::GygesNoOverlap),
            SystemKind::Basic => Some(Mechanism::Basic),
            SystemKind::Seesaw => Some(Mechanism::Seesaw),
            // PP/SP re-grouping needs no KV/weight re-shard: cheap and
            // non-blocking (their cost is steady-state inefficiency).
            SystemKind::KunServe | SystemKind::LoongServe => None,
        }
    }
}

/// Runtime events. Arrivals are NOT queue events: the loop merges the
/// queue with the [`ArrivalFeed`] stream directly (arrivals win
/// timestamp ties, reproducing the seed ordering where pre-pushed
/// arrivals always carried the lowest sequence numbers) — which is what
/// makes streamed-segment replay byte-identical to whole-trace replay.
enum Event {
    /// (instance id, epoch) — stale epochs are dropped.
    Step(usize, u64),
    TransformDone(usize, u64),
    /// Deferred-queue retry deadline: re-route the backlog once the
    /// cooldown after a no-progress drain pass has elapsed.
    BacklogWakeup,
    /// Injected fault number `idx` of the armed [`FaultPlan`] fires.
    /// Exactly one fault event is outstanding at a time: handling fault
    /// `idx` schedules fault `idx + 1`, so an empty plan pushes nothing
    /// and the event/sequence stream stays byte-identical to an
    /// unfaulted run.
    Fault(usize),
    /// A crashed host's MTTR elapsed: fresh TP1 instances rejoin.
    HostRestore(usize),
    /// (instance id, epoch) — a transient stall window closed; stale
    /// epochs are dropped like Step events.
    StallEnd(usize, u64),
    /// A KV-migration link outage window closed.
    LinkRestore(usize),
}

/// What the in-flight step of an instance will do when it completes.
#[derive(Clone, Copy, Debug)]
enum Pending {
    Prefill { req_id: u64 },
    Decode,
    /// Idle-time transformation drain.
    Maintenance,
}

/// Counters describing cluster-level behaviour. Everything here is a
/// deterministic function of the trace + config (no wall-clock), so the
/// determinism tests compare whole counter sets across runs; wall-time
/// attribution lives in the opt-in [`SimProfile`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimCounters {
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Requests deferred at arrival time (first deferral only).
    pub deferred: u64,
    pub steps: u64,
    /// Total events processed by the loop (sum of the per-type counts).
    pub events: u64,
    /// Per-event-type breakdown of `events`.
    pub arrival_events: u64,
    pub step_events: u64,
    pub transform_done_events: u64,
    /// Step/TransformDone events dropped because their instance epoch was
    /// invalidated (merge/split) or the instance retired.
    pub stale_events: u64,
    /// BacklogWakeup events processed (deferred-queue retry deadlines).
    pub backlog_wakeup_events: u64,
    /// Routing sub-phase: `RoutePolicy::route` invocations (arrivals +
    /// backlog retries).
    pub routes: u64,
    /// Stepping sub-phase: `kick` invocations.
    pub kicks: u64,
    /// Backlog sub-phase: route attempts for previously-deferred requests.
    pub backlog_retries: u64,
    /// Backlog retries that deferred again (re-queued).
    pub backlog_requeues: u64,
    /// Whole drain passes skipped because the retry cooldown was active.
    pub backlog_suppressed: u64,
    /// Total simulated time deferred requests waited between their first
    /// deferral and their eventual assignment (deferral latency).
    pub backlog_wait: SimDuration,
    /// Injected [`Event::Fault`] events processed.
    pub fault_events: u64,
    /// HostRestore/StallEnd/LinkRestore events processed (fault recovery).
    pub recovery_events: u64,
    /// Instances killed by a host crash (their KV cache is lost).
    pub crashed_instances: u64,
    /// In-flight requests requeued through the backlog after losing
    /// their serving state to a crash or rollback (KV gone; they restart
    /// from scratch but keep their original arrival stamp).
    pub crash_requeued: u64,
    /// Requests shed by admission control: the bounded [`RetryPolicy`]
    /// exhausted its attempts and the request was dropped instead of
    /// parked again (graceful degradation under capacity < demand).
    pub dropped: u64,
    /// Mid-flight transformations aborted and rolled back to `from_tp`
    /// (fault-charged: the rollback itself costs blocked time).
    pub transform_rollbacks: u64,
    /// Transient instance stalls injected (in-flight step discarded).
    pub stalled_instances: u64,
    /// ScaleUp routes refused because the target host was degraded or
    /// its KV-migration link was down (failure-aware policy backstop).
    pub scale_up_blocked: u64,
    /// Queued batch-class prefills evicted (requeued through the
    /// backlog) to make room for an interactive request (`-slo`
    /// policies' preemption lane).
    pub preemptions: u64,
    /// Subset of `dropped` shed by the decision stage itself
    /// ([`Route::Drop`], `-admit` policies' deadline check) rather than
    /// by retry exhaustion.
    pub admission_dropped: u64,
}

/// Wall-clock attribution of the event loop, accumulated only when
/// [`ClusterSim::enable_profiling`] was called (the bench harness does;
/// the default loop pays nothing). Event-handler buckets partition the
/// loop body by event type; the sub-phase buckets (`route_s`, `kick_s`,
/// `drain_backlog_s`) are measured *inside* the handlers and therefore
/// overlap them (and each other: a drain pass contains route and kick
/// calls). Matching call counts live in [`SimCounters`], which stays
/// deterministic.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimProfile {
    pub arrival_s: f64,
    pub step_s: f64,
    pub transform_done_s: f64,
    pub backlog_wakeup_s: f64,
    /// Fault-injection and recovery events (all four kinds).
    pub fault_s: f64,
    pub route_s: f64,
    pub kick_s: f64,
    pub drain_backlog_s: f64,
}

/// A structured simulation failure (the run still yields its partial
/// report; callers decide whether to treat it as fatal).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimError {
    /// The event loop hit `ClusterConfig::max_events` before draining —
    /// a runaway schedule or a cap set too low for the trace.
    /// `pending_events` counts queued runtime events plus the immediate
    /// next arrival (never arrivals further up the stream, so the value
    /// is identical however the trace is segmented).
    EventCapExceeded { cap: u64, pending_events: u64 },
    /// The streamed trace source failed (I/O error, tampered segment,
    /// violated segment invariants); arrivals stopped at the failure
    /// point and the report covers only the requests fed before it.
    TraceSource { detail: String },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::EventCapExceeded { cap, pending_events } => write!(
                f,
                "event cap exceeded: processed {cap} events with {pending_events} still queued"
            ),
            SimError::TraceSource { detail } => write!(f, "trace source failed: {detail}"),
        }
    }
}

/// Result of one simulation run.
pub struct SimOutcome {
    pub report: RunReport,
    pub recorder: Recorder,
    pub counters: SimCounters,
    /// Wall-time attribution; `Some` only when profiling was enabled.
    pub profile: Option<SimProfile>,
    /// Set when the run terminated abnormally (e.g. event-cap overflow);
    /// the report then covers only the work completed before the cut.
    pub error: Option<SimError>,
    /// High-water mark of trace requests buffered by the arrival feed:
    /// the whole trace for classic replay, at most one segment for
    /// streamed replay (the O(segment) memory-bound witness; not part of
    /// any serialized row, so streamed and whole-trace outputs stay
    /// byte-identical).
    pub trace_peak_buffered: usize,
    /// Prefix-cache counters, `Some` only when the cache model was
    /// armed. Kept out of [`SimCounters`] (which serializes every field
    /// unconditionally) so cache-off sweep rows stay byte-identical to
    /// pre-cache builds.
    pub cache: Option<CacheCounters>,
}

/// A deferred request parked in the backlog, stamped with its *first*
/// deferral time so `SimCounters::backlog_wait` measures true deferral
/// latency across re-queues, plus its [`RetryPolicy`] state: how many
/// placement attempts have failed and when the exponential-backoff
/// window reopens. With the legacy unlimited policy both fields are
/// inert (`attempts` grows but never exhausts; `next_retry` equals the
/// enqueue time), so unfaulted runs stay byte-identical.
struct Deferred {
    req: ActiveRequest,
    since: SimTime,
    attempts: u32,
    next_retry: SimTime,
}

/// The cluster simulator.
pub struct ClusterSim {
    pub cfg: ClusterConfig,
    pub engine: EngineModel,
    pub system: SystemKind,
    instances: Vec<Instance>,
    epochs: Vec<u64>,
    pending: Vec<Option<Pending>>,
    queue: EventQueue<Event>,
    feed: ArrivalFeed,
    policy: Box<dyn RoutePolicy>,
    backlog: VecDeque<Deferred>,
    pub recorder: Recorder,
    pub counters: SimCounters,
    /// When set, ScaleUp routes become Defer and scale-down never fires
    /// (static deployments, §3.3 baseline).
    transformation_disabled: bool,
    /// Per-instance: an idle dwell re-check event is outstanding.
    dwell_check_scheduled: Vec<bool>,
    /// Incremental merge-candidate index (kept in lockstep with every
    /// topology mutation; see module docs).
    tp1_index: HostIndex,
    /// Incremental load index (least-load picks + RR live ring), kept in
    /// lockstep with every load-affecting mutation via `reindex`.
    load_index: LoadIndex,
    /// When false, routing views carry no indices and the policies fall
    /// back to full scans — the measured baseline for the routing
    /// microbench and the decision-equivalence tests.
    use_routing_index: bool,
    /// Accumulate wall-time attribution into `profile`.
    profiling: bool,
    profile: SimProfile,
    /// No backlog drain pass runs before this time (armed after a pass
    /// that made no progress; a BacklogWakeup retries at the deadline).
    backlog_cooldown_until: SimTime,
    /// A BacklogWakeup event is outstanding in the queue.
    backlog_wakeup_scheduled: bool,
    /// Armed fault schedule; empty means no fault events ever enter the
    /// queue (byte-identical to an unfaulted run).
    fault_plan: FaultPlan,
    /// Index of the next plan entry to fire (== plan length once spent).
    fault_cursor: usize,
    /// Per-host: crashed until this time (ZERO / past = healthy).
    degraded_until: Vec<SimTime>,
    /// Per-host: KV-migration link down until this time.
    link_down_until: Vec<SimTime>,
    /// Per-host derived flag: degraded OR link down, recomputed at every
    /// fault/recovery transition event (between events it cannot change),
    /// and consulted identically by the indexed and scanning routing
    /// paths via [`ClusterView::blocked_hosts`].
    host_blocked: Vec<bool>,
    /// Per-instance: frozen by an injected stall until this time.
    stall_until: Vec<SimTime>,
    /// Bounded-retry/backoff policy for backlog parking (from
    /// `ClusterConfig::retry_max_attempts` / `retry_backoff_base_s`;
    /// defaults reproduce the legacy retry-forever behaviour).
    retry: RetryPolicy,
    /// Reused per-decode-step id buffers (allocation-free event loop).
    scratch_stepped: Vec<u64>,
    scratch_finished: Vec<u64>,
    /// Reused request-transfer buffers for merge/split/crash paths
    /// (drained empty after every use; the capacity is the pool). See
    /// PERF.md arena rules: requests themselves live inline in the
    /// instances' ring buffers, so reusing the transfer scratch removes
    /// the last per-transform allocation.
    pool_running: Vec<ActiveRequest>,
    pool_prefill: Vec<ActiveRequest>,
    /// Terminal failure of this run, set by the loop (event cap). A
    /// field rather than a `run`-local so a paused run ([`ClusterSim::
    /// run_until`]) carries it to [`ClusterSim::finish`].
    error: Option<SimError>,
    /// Armed prefix-cache model (`None` = cache off, the byte-identical
    /// pre-cache path). Armed automatically for `-cache` policies, or
    /// explicitly via [`ClusterSim::arm_cache`] for track-only
    /// measurement under load-only policies (the fig-cache baselines).
    cache: Option<ClusterCache>,
}

/// How [`ClusterSim::run_until`] returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    /// Nothing left to process (or the run hit a terminal error) — call
    /// [`ClusterSim::finish`] for the outcome.
    Done,
    /// The next event/arrival lies at or beyond the stop time; the
    /// simulation is between events and can be snapshotted or resumed.
    Paused,
}

impl ClusterSim {
    /// Build a simulator with `cfg.total_gpus()` initial TP1 instances,
    /// replaying a fully materialized trace (one-segment feed).
    pub fn new(cfg: ClusterConfig, system: SystemKind, trace: Trace) -> ClusterSim {
        Self::with_feed(cfg, system, ArrivalFeed::from_trace(trace))
    }

    /// Build a simulator fed by a streaming [`TraceSource`] — arrivals
    /// are pulled segment by segment, so peak trace memory is bounded by
    /// one segment while results stay byte-identical to whole-trace
    /// replay of the same request stream.
    pub fn with_source(
        cfg: ClusterConfig,
        system: SystemKind,
        source: Box<dyn TraceSource>,
    ) -> ClusterSim {
        Self::with_feed(cfg, system, ArrivalFeed::new(source))
    }

    fn with_feed(cfg: ClusterConfig, system: SystemKind, feed: ArrivalFeed) -> ClusterSim {
        let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
        let mut instances = Vec::new();
        for host in 0..cfg.hosts {
            for g in 0..cfg.gpus_per_host {
                let id = instances.len();
                instances.push(Instance::new(id, host, vec![host * cfg.gpus_per_host + g], 1));
            }
        }
        let policy: Box<dyn RoutePolicy> = match system {
            SystemKind::Gyges
            | SystemKind::GygesNoOverlap
            | SystemKind::Basic
            | SystemKind::Seesaw => make_policy(cfg.policy),
            // Baseline systems ship their own (least-load) scheduler.
            SystemKind::KunServe | SystemKind::LoongServe => make_policy(Policy::LeastLoadFirst),
        };
        let n = instances.len();
        let tp1_index = HostIndex::build(&instances, cfg.hosts);
        let load_index = LoadIndex::build(&instances, &engine);
        let retry = RetryPolicy {
            max_attempts: cfg.retry_max_attempts,
            backoff_base_s: cfg.retry_backoff_base_s,
        };
        let hosts = cfg.hosts;
        let cache = cfg.policy.cache.then(|| ClusterCache::new(crate::cache::DEFAULT_BLOCK_TOKENS));
        ClusterSim {
            cfg,
            engine,
            system,
            instances,
            epochs: vec![0; n],
            pending: vec![None; n],
            queue: EventQueue::new(),
            feed,
            policy,
            backlog: VecDeque::new(),
            recorder: Recorder::new(),
            counters: SimCounters::default(),
            transformation_disabled: false,
            dwell_check_scheduled: vec![false; n],
            tp1_index,
            load_index,
            use_routing_index: true,
            profiling: false,
            profile: SimProfile::default(),
            backlog_cooldown_until: SimTime::ZERO,
            backlog_wakeup_scheduled: false,
            fault_plan: FaultPlan::empty(),
            fault_cursor: 0,
            degraded_until: vec![SimTime::ZERO; hosts],
            link_down_until: vec![SimTime::ZERO; hosts],
            host_blocked: vec![false; hosts],
            stall_until: vec![SimTime::ZERO; n],
            retry,
            scratch_stepped: Vec::new(),
            scratch_finished: Vec::new(),
            pool_running: Vec::new(),
            pool_prefill: Vec::new(),
            error: None,
            cache,
        }
    }

    /// Replace the initial instance layout (static-hybrid baseline). The
    /// callback receives (host, first_gpu_of_host) and returns
    /// (host, workers, degree) triples for that host.
    pub fn replace_instances(
        &mut self,
        mut layout: impl FnMut(usize, usize) -> Vec<(usize, Vec<usize>, u64)>,
    ) {
        self.instances.clear();
        for host in 0..self.cfg.hosts {
            for (h, workers, degree) in layout(host, host * self.cfg.gpus_per_host) {
                let id = self.instances.len();
                self.instances.push(Instance::new(id, h, workers, degree));
            }
        }
        self.epochs = vec![0; self.instances.len()];
        self.pending = vec![None; self.instances.len()];
        self.dwell_check_scheduled = vec![false; self.instances.len()];
        self.stall_until = vec![SimTime::ZERO; self.instances.len()];
        self.tp1_index = HostIndex::build(&self.instances, self.cfg.hosts);
        self.load_index = LoadIndex::build(&self.instances, &self.engine);
    }

    /// Disable runtime transformation (static deployments).
    pub fn disable_transformation(&mut self) {
        self.transformation_disabled = true;
    }

    /// Route through full instance-table scans instead of the incremental
    /// indices — the measured baseline for the routing microbench and the
    /// decision-equivalence (byte-identical figures) tests. Index
    /// maintenance is skipped too, so the baseline pays neither the index
    /// upkeep nor its query costs.
    pub fn disable_routing_index(&mut self) {
        self.use_routing_index = false;
    }

    /// Accumulate per-event-type wall-time attribution into
    /// `SimOutcome::profile`. Off by default: the loop then performs no
    /// `Instant::now` calls.
    pub fn enable_profiling(&mut self) {
        self.profiling = true;
    }

    /// Arm a deterministic fault schedule. Call once, before running:
    /// the first fault enters the [`EventQueue`] as a first-class event
    /// and each fault schedules its successor on firing, so an empty
    /// plan pushes nothing (byte-identical to an unfaulted run) and the
    /// whole storm replays identically from any snapshot (plan + cursor
    /// serialize in schema v2).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) -> Result<(), String> {
        if !self.fault_plan.is_empty() {
            return Err("a fault plan is already armed".into());
        }
        plan.validate(self.cfg.hosts, self.cfg.gpus_per_host)?;
        if let Some(first) = plan.faults.first() {
            self.queue.push(first.at, Event::Fault(0));
        }
        self.fault_plan = plan;
        self.fault_cursor = 0;
        Ok(())
    }

    /// The routing view's blocked-host mask: `None` while no fault plan
    /// is armed (the unfaulted case — policies skip the check entirely,
    /// preserving byte-identity with pre-fault builds), `Some` once one
    /// is. Both the indexed and scanning routing paths consult the same
    /// mask, so decision equivalence carries over.
    fn blocked_hosts_view(&self) -> Option<&[bool]> {
        if self.fault_plan.is_empty() {
            None
        } else {
            Some(&self.host_blocked)
        }
    }

    /// Reconcile both incremental indices with instance `iid`'s current
    /// state. Must be called after every mutation that changes the
    /// instance's `retired`/`degree`/`transforming` state or its `load()`
    /// inputs (committed tokens); see PERF.md for the audit of call sites.
    fn reindex(&mut self, iid: usize) {
        if !self.use_routing_index {
            return;
        }
        self.tp1_index.note(&self.instances[iid]);
        self.load_index.note(&self.instances[iid], &self.engine);
    }

    #[inline]
    fn prof_start(&self) -> Option<Instant> {
        if self.profiling {
            Some(Instant::now())
        } else {
            None
        }
    }

    #[inline]
    fn prof_add(t0: Option<Instant>, slot: &mut f64) {
        if let Some(t) = t0 {
            *slot += t.elapsed().as_secs_f64();
        }
    }

    /// Tune the Gyges policy's anti-oscillation hold (ablation A3).
    /// No-op for non-Gyges-based policies; slo/admit stage flags are
    /// preserved (the composition is rebuilt, resetting decision state —
    /// call before running, as the ablation harness does).
    pub fn set_gyges_hold(&mut self, hold_s: f64) {
        if let Some(id) = PolicyId::parse(self.policy.name()) {
            if id.base == Policy::Gyges {
                self.policy = make_policy_with_hold(id, hold_s);
            }
        }
    }

    /// Override the routing policy (Figure 12 compares policies on the
    /// same Gyges transformation machinery). Accepts a plain [`Policy`]
    /// or a composed [`PolicyId`]. A `-cache` id arms the cache model if
    /// it wasn't already; a cache-free id leaves an armed cache in place
    /// (track-only measurement — fig-cache's load-only baselines).
    pub fn with_policy(mut self, policy: impl Into<PolicyId>) -> ClusterSim {
        let id = policy.into();
        if id.cache {
            self.arm_cache();
        }
        self.policy = make_policy(id);
        self
    }

    /// Arm the prefix-cache model (idempotent). Call before running:
    /// cached-token prefill shortening and hit/evict counters switch on
    /// for every policy, cache-aware or not. Never armed ⇒ the run is
    /// byte-identical to a pre-cache build.
    pub fn arm_cache(&mut self) {
        if self.cache.is_none() {
            self.cache = Some(ClusterCache::new(crate::cache::DEFAULT_BLOCK_TOKENS));
        }
    }

    /// Prefix-cache counters so far; `None` while the cache is unarmed.
    pub fn cache_counters(&self) -> Option<CacheCounters> {
        self.cache.as_ref().map(|c| c.counters)
    }

    /// Install an already-built policy object (lockstep tests drive the
    /// legacy reference impls through this without touching the
    /// process-global `legacy_routing` flag, which is unsafe under
    /// parallel test threads).
    pub fn with_boxed_policy(mut self, policy: Box<dyn RoutePolicy>) -> ClusterSim {
        self.policy = policy;
        self
    }

    /// Run to completion (or the event cap) and summarize.
    ///
    /// The loop merges two streams: queued runtime events and the
    /// arrival feed. Whichever is earlier is processed next; at equal
    /// timestamps the arrival wins — exactly the seed ordering, where
    /// arrivals were pre-pushed and therefore always held the lowest
    /// queue sequence numbers at their timestamp. Because the merge
    /// never looks past the *next* arrival, the outcome is independent
    /// of how the feed segments the trace — streamed replay is
    /// byte-identical to whole-trace replay by construction.
    pub fn run(mut self) -> SimOutcome {
        let _ = self.run_until(None);
        self.finish()
    }

    /// Drive the loop until nothing remains ([`RunStatus::Done`]) or the
    /// next event/arrival would be at or beyond `stop_at`
    /// ([`RunStatus::Paused`]). A paused simulation sits *between*
    /// events — the next thing it would process carries a timestamp
    /// `>= stop_at` — which is exactly the boundary [`ClusterSim::
    /// snapshot`] captures: every decision the loop makes is a pure
    /// function of the state serialized there, so resuming is
    /// indistinguishable from never having paused. Re-invoking after
    /// `Done` is a no-op (a terminal error stays terminal).
    pub fn run_until(&mut self, stop_at: Option<SimTime>) -> RunStatus {
        let cap = self.cfg.max_events.max(1);
        if self.error.is_some() {
            return RunStatus::Done;
        }
        loop {
            let next_arrival = self.feed.peek_time();
            let next_event = self.queue.peek_time();
            let take_arrival = match (next_arrival, next_event) {
                (None, None) => return RunStatus::Done,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(a), Some(e)) => a <= e,
            };
            if let Some(stop) = stop_at {
                let next = if take_arrival {
                    // gyges-lint: allow(D06) take_arrival is only true when next_arrival is Some
                    next_arrival.expect("arrival peeked")
                } else {
                    // gyges-lint: allow(D06) the (None, None) arm returned Done above
                    next_event.expect("event peeked")
                };
                if next >= stop {
                    return RunStatus::Paused;
                }
            }
            if self.counters.events >= cap {
                let pending = self.queue.len() as u64 + u64::from(take_arrival);
                self.error = Some(SimError::EventCapExceeded { cap, pending_events: pending });
                return RunStatus::Done;
            }
            self.counters.events += 1;
            if take_arrival {
                // gyges-lint: allow(D06) peek_time returned Some for this branch to be taken
                let req = self.feed.pop().expect("peeked arrival must pop");
                self.queue.advance_to(req.arrival);
                let t0 = self.prof_start();
                self.counters.arrival_events += 1;
                self.on_arrival(req);
                Self::prof_add(t0, &mut self.profile.arrival_s);
                continue;
            }
            // gyges-lint: allow(D06) peek_time returned Some for this branch to be taken
            let (now, ev) = self.queue.pop().expect("peeked event must pop");
            let t0 = self.prof_start();
            match ev {
                Event::Step(iid, epoch) => {
                    if self.epochs[iid] == epoch && !self.instances[iid].retired {
                        self.counters.step_events += 1;
                        self.on_step(now, iid);
                    } else {
                        self.counters.stale_events += 1;
                    }
                    Self::prof_add(t0, &mut self.profile.step_s);
                }
                Event::TransformDone(iid, epoch) => {
                    if self.epochs[iid] == epoch && !self.instances[iid].retired {
                        self.counters.transform_done_events += 1;
                        self.on_transform_done(now, iid);
                    } else {
                        self.counters.stale_events += 1;
                    }
                    Self::prof_add(t0, &mut self.profile.transform_done_s);
                }
                Event::BacklogWakeup => {
                    self.backlog_wakeup_scheduled = false;
                    self.counters.backlog_wakeup_events += 1;
                    self.drain_backlog(now);
                    Self::prof_add(t0, &mut self.profile.backlog_wakeup_s);
                }
                Event::Fault(idx) => {
                    self.counters.fault_events += 1;
                    self.on_fault(now, idx);
                    Self::prof_add(t0, &mut self.profile.fault_s);
                }
                Event::HostRestore(host) => {
                    self.counters.recovery_events += 1;
                    self.on_host_restore(now, host);
                    Self::prof_add(t0, &mut self.profile.fault_s);
                }
                Event::StallEnd(iid, epoch) => {
                    if self.epochs[iid] == epoch && !self.instances[iid].retired {
                        self.counters.recovery_events += 1;
                        self.kick(now, iid);
                    } else {
                        self.counters.stale_events += 1;
                    }
                    Self::prof_add(t0, &mut self.profile.fault_s);
                }
                Event::LinkRestore(host) => {
                    self.counters.recovery_events += 1;
                    self.on_link_restore(now, host);
                    Self::prof_add(t0, &mut self.profile.fault_s);
                }
            }
        }
    }

    /// Summarize a finished (or cut) run. Call after [`ClusterSim::
    /// run_until`] returned [`RunStatus::Done`]; calling it on a merely
    /// paused run summarizes the partial timeline.
    pub fn finish(self) -> SimOutcome {
        let mut error = self.error;
        // A trace-source failure outranks an event-cap cut: the cap may
        // itself be downstream of the truncated/corrupt workload, and
        // the tamper/IO diagnosis must never be masked by it.
        if let Some(detail) = self.feed.error() {
            error = Some(SimError::TraceSource { detail: detail.to_string() });
        }
        if self.use_routing_index {
            #[cfg(debug_assertions)]
            {
                self.tp1_index.debug_verify(&self.instances);
                self.load_index.debug_verify(&self.instances, &self.engine);
            }
        }
        let label = format!("{}/{}", self.system.name(), self.policy.name());
        let report = RunReport::from_recorder(&label, &self.recorder);
        SimOutcome {
            report,
            recorder: self.recorder,
            counters: self.counters,
            profile: if self.profiling { Some(self.profile) } else { None },
            error,
            trace_peak_buffered: self.feed.peak_buffered(),
            cache: self.cache.as_ref().map(|c| c.counters),
        }
    }

    // -----------------------------------------------------------------
    // Snapshot / resume (schema v1; see rust/src/snapshot/state.rs)
    // -----------------------------------------------------------------

    /// Simulated clock (checkpoint cadence bookkeeping).
    pub fn sim_now(&self) -> SimTime {
        self.queue.now()
    }

    /// Live instances with a transformation in flight (test hook for
    /// the adversarial-instant resume coverage).
    pub fn in_flight_transforms(&self) -> usize {
        self.instances.iter().filter(|i| !i.retired && i.transforming.is_some()).count()
    }

    /// Deferred requests currently parked.
    pub fn backlog_len(&self) -> usize {
        self.backlog.len()
    }

    /// Hosts currently crashed (pre-restore) — test hook for the
    /// adversarial mid-crash snapshot coverage.
    pub fn degraded_hosts(&self) -> usize {
        let now = self.queue.now();
        self.degraded_until.iter().filter(|&&until| now < until).count()
    }

    /// Backlog entries with at least one failed placement attempt —
    /// test hook for the armed-retry-backoff snapshot coverage.
    pub fn armed_retries(&self) -> usize {
        self.backlog.iter().filter(|d| d.attempts > 0).count()
    }

    /// Deadline before which no backlog drain pass runs (ZERO = no
    /// cooldown armed).
    pub fn backlog_cooldown_deadline(&self) -> SimTime {
        self.backlog_cooldown_until
    }

    /// Capture complete simulator state between two events (pause via
    /// [`ClusterSim::run_until`] first). Refuses terminal and profiling
    /// runs: an errored run has nothing to resume, and wall-clock
    /// profile attribution is not simulation state.
    pub fn snapshot(&self) -> Result<SimSnapshot, String> {
        self.snapshot_with_context(None)
    }

    /// [`ClusterSim::snapshot`] with a run descriptor attached for the
    /// resume/branch CLIs.
    pub fn snapshot_with_context(
        &self,
        context: Option<RunContext>,
    ) -> Result<SimSnapshot, String> {
        if self.profiling {
            return Err("cannot snapshot a profiling run: wall-clock attribution is not \
                        resumable state"
                .into());
        }
        if let Some(e) = &self.error {
            return Err(format!("cannot snapshot a terminated run: {e}"));
        }
        let req_snap = |r: &ActiveRequest| ReqSnap {
            id: r.id,
            arrival: r.arrival,
            input_len: r.input_len,
            output_len: r.output_len,
            generated: r.generated,
            phase: r.phase.name().to_string(),
            class: r.class,
            prefix: r.prefix.clone(),
            cached_tokens: r.cached_tokens,
        };
        let events = self
            .queue
            .entries()
            .into_iter()
            .map(|(at, seq, ev)| EventSnap {
                at,
                seq,
                kind: match ev {
                    Event::Step(iid, epoch) => EventKindSnap::Step { iid: *iid, epoch: *epoch },
                    Event::TransformDone(iid, epoch) => {
                        EventKindSnap::TransformDone { iid: *iid, epoch: *epoch }
                    }
                    Event::BacklogWakeup => EventKindSnap::BacklogWakeup,
                    Event::Fault(idx) => EventKindSnap::Fault { idx: *idx },
                    Event::HostRestore(host) => EventKindSnap::HostRestore { host: *host },
                    Event::StallEnd(iid, epoch) => {
                        EventKindSnap::StallEnd { iid: *iid, epoch: *epoch }
                    }
                    Event::LinkRestore(host) => EventKindSnap::LinkRestore { host: *host },
                },
            })
            .collect();
        let instances = self
            .instances
            .iter()
            .map(|i| InstanceSnap {
                id: i.id,
                host: i.host,
                workers: i.workers.clone(),
                degree: i.degree,
                kind: i.kind.name().to_string(),
                running: i.running.iter().map(req_snap).collect(),
                prefill: i.prefill_queue.iter().map(req_snap).collect(),
                kv_tokens: i.kv_tokens,
                transforming: i.transforming.as_ref().map(|ts| TransformSnap {
                    from_tp: ts.exec.plan.from_tp,
                    to_tp: ts.exec.plan.to_tp,
                    ops_per_step: ts.exec.plan.ops_per_step,
                    mech: ts.exec.mech.name().to_string(),
                    per_op_visible: ts.exec.per_op_visible(),
                    step: ts.exec.step,
                    blocked_until: ts.blocked_until,
                }),
                last_transform: i.last_transform,
                stepping: i.stepping,
                retired: i.retired,
            })
            .collect();
        let pending = self
            .pending
            .iter()
            .map(|p| match p {
                None => PendingSnap::None,
                Some(Pending::Prefill { req_id }) => PendingSnap::Prefill { req_id: *req_id },
                Some(Pending::Decode) => PendingSnap::Decode,
                Some(Pending::Maintenance) => PendingSnap::Maintenance,
            })
            .collect();
        let backlog = self
            .backlog
            .iter()
            .map(|d| DeferredSnap {
                req: req_snap(&d.req),
                since: d.since,
                attempts: d.attempts,
                next_retry: d.next_retry,
            })
            .collect();
        let recorder = RecorderSnap {
            rows: self.recorder.records().map(|(id, r)| (id, r.clone())).collect(),
            tps_buckets: self.recorder.tps_buckets().to_vec(),
            horizon: self.recorder.horizon,
        };
        Ok(SimSnapshot {
            system: self.system.name().to_string(),
            config_fingerprint: crate::snapshot::state::config_fingerprint(&self.cfg),
            sim_time: self.queue.now(),
            context,
            state: SimState {
                queue_seq: self.queue.seq(),
                events,
                instances,
                epochs: self.epochs.clone(),
                pending,
                dwell_check_scheduled: self.dwell_check_scheduled.clone(),
                backlog,
                counters: self.counters,
                policy: self.policy.snapshot_state(),
                transformation_disabled: self.transformation_disabled,
                use_routing_index: self.use_routing_index,
                backlog_cooldown_until: self.backlog_cooldown_until,
                backlog_wakeup_scheduled: self.backlog_wakeup_scheduled,
                fault_plan: self.fault_plan.clone(),
                fault_cursor: self.fault_cursor,
                degraded_until: self.degraded_until.clone(),
                link_down_until: self.link_down_until.clone(),
                stall_until: self.stall_until.clone(),
                recorder,
                feed: self.feed.snapshot()?,
                cache: self.cache.clone(),
            },
        })
    }

    /// Rebuild a paused simulation from a snapshot. `cfg` must be the
    /// exact configuration the snapshotting process ran under (proven
    /// by the embedded fingerprint); derived routing indices are
    /// rebuilt from the restored instance table and re-checked against
    /// it in debug builds. Continuing the restored simulation is
    /// byte-identical to never having paused (enforced by
    /// `rust/tests/snapshot.rs`).
    pub fn from_snapshot(cfg: ClusterConfig, snap: &SimSnapshot) -> Result<ClusterSim, String> {
        let fp = crate::snapshot::state::config_fingerprint(&cfg);
        if fp != snap.config_fingerprint {
            return Err(format!(
                "config fingerprint {fp} does not match the snapshot's {} — resume with the \
                 exact configuration the run was started with",
                snap.config_fingerprint
            ));
        }
        let system = SystemKind::by_name(&snap.system)
            .ok_or_else(|| format!("unknown system {:?} in snapshot", snap.system))?;
        let s = &snap.state;
        let n = s.instances.len();
        if s.epochs.len() != n
            || s.pending.len() != n
            || s.dwell_check_scheduled.len() != n
            || s.stall_until.len() != n
        {
            return Err(format!(
                "snapshot inconsistency: {n} instances but {} epochs / {} pending / {} dwell \
                 flags / {} stall deadlines",
                s.epochs.len(),
                s.pending.len(),
                s.dwell_check_scheduled.len(),
                s.stall_until.len()
            ));
        }
        if s.degraded_until.len() != cfg.hosts || s.link_down_until.len() != cfg.hosts {
            return Err(format!(
                "snapshot inconsistency: {} hosts but {} degraded / {} link deadlines",
                cfg.hosts,
                s.degraded_until.len(),
                s.link_down_until.len()
            ));
        }
        s.fault_plan.validate(cfg.hosts, cfg.gpus_per_host)?;
        if s.fault_cursor > s.fault_plan.len() {
            return Err(format!(
                "snapshot inconsistency: fault cursor {} beyond plan length {}",
                s.fault_cursor,
                s.fault_plan.len()
            ));
        }
        let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
        let req_back = |r: &ReqSnap| -> Result<ActiveRequest, String> {
            Ok(ActiveRequest {
                id: r.id,
                arrival: r.arrival,
                input_len: r.input_len,
                output_len: r.output_len,
                generated: r.generated,
                phase: super::request::Phase::by_name(&r.phase)
                    .ok_or_else(|| format!("unknown request phase {:?}", r.phase))?,
                class: r.class,
                prefix: r.prefix.clone(),
                cached_tokens: r.cached_tokens,
            })
        };
        let mut instances = Vec::with_capacity(n);
        for (idx, i) in s.instances.iter().enumerate() {
            if i.id != idx {
                return Err(format!(
                    "snapshot inconsistency: instance at slot {idx} declares id {}",
                    i.id
                ));
            }
            let transforming = match &i.transforming {
                None => None,
                Some(t) => {
                    if t.ops_per_step < 2 || t.ops_per_step % 2 != 0 {
                        return Err(format!(
                            "instance {idx}: transform ops_per_step {} is not an even \
                             positive count",
                            t.ops_per_step
                        ));
                    }
                    if t.from_tp == t.to_tp {
                        return Err(format!(
                            "instance {idx}: transform endpoints are equal (tp {})",
                            t.from_tp
                        ));
                    }
                    let mech = Mechanism::by_name(&t.mech)
                        .ok_or_else(|| format!("unknown transform mechanism {:?}", t.mech))?;
                    let plan =
                        TransformPlan::build(&cfg.model, t.from_tp, t.to_tp, t.ops_per_step / 2);
                    Some(TransformState {
                        exec: TransformExec::from_parts(plan, mech, t.per_op_visible, t.step),
                        blocked_until: t.blocked_until,
                    })
                }
            };
            let running = i
                .running
                .iter()
                .map(req_back)
                .collect::<Result<VecDeque<ActiveRequest>, String>>()?;
            let prefill = i
                .prefill
                .iter()
                .map(req_back)
                .collect::<Result<VecDeque<ActiveRequest>, String>>()?;
            let kind = super::instance::ParallelKind::by_name(&i.kind)
                .ok_or_else(|| format!("unknown parallel kind {:?}", i.kind))?;
            let inst = Instance::restore(
                i.id,
                i.host,
                i.workers.clone(),
                i.degree,
                kind,
                running,
                prefill,
                i.kv_tokens,
                transforming,
                i.last_transform,
                i.stepping,
                i.retired,
            );
            inst.debug_assert_consistent();
            instances.push(inst);
        }
        let mut entries = Vec::with_capacity(s.events.len());
        for e in &s.events {
            let ev = match e.kind {
                EventKindSnap::Step { iid, epoch } => {
                    if iid >= n {
                        return Err(format!("event references unknown instance {iid}"));
                    }
                    Event::Step(iid, epoch)
                }
                EventKindSnap::TransformDone { iid, epoch } => {
                    if iid >= n {
                        return Err(format!("event references unknown instance {iid}"));
                    }
                    Event::TransformDone(iid, epoch)
                }
                EventKindSnap::BacklogWakeup => Event::BacklogWakeup,
                EventKindSnap::Fault { idx } => {
                    if idx >= s.fault_plan.len() {
                        return Err(format!("fault event references unknown plan entry {idx}"));
                    }
                    Event::Fault(idx)
                }
                EventKindSnap::HostRestore { host } => {
                    if host >= cfg.hosts {
                        return Err(format!("event references unknown host {host}"));
                    }
                    Event::HostRestore(host)
                }
                EventKindSnap::StallEnd { iid, epoch } => {
                    if iid >= n {
                        return Err(format!("event references unknown instance {iid}"));
                    }
                    Event::StallEnd(iid, epoch)
                }
                EventKindSnap::LinkRestore { host } => {
                    if host >= cfg.hosts {
                        return Err(format!("event references unknown host {host}"));
                    }
                    Event::LinkRestore(host)
                }
            };
            entries.push((e.at, e.seq, ev));
        }
        let queue = EventQueue::restore(snap.sim_time, s.queue_seq, entries)?;
        let mut backlog = VecDeque::with_capacity(s.backlog.len());
        for d in &s.backlog {
            backlog.push_back(Deferred {
                req: req_back(&d.req)?,
                since: d.since,
                attempts: d.attempts,
                next_retry: d.next_retry,
            });
        }
        let retry = RetryPolicy {
            max_attempts: cfg.retry_max_attempts,
            backoff_base_s: cfg.retry_backoff_base_s,
        };
        let hosts = cfg.hosts;
        let tp1_index = HostIndex::build(&instances, cfg.hosts);
        let load_index = LoadIndex::build(&instances, &engine);
        if s.use_routing_index {
            // The rebuild IS the full rescan the end-of-run check
            // compares against; re-verify here so a restore in a debug
            // build proves the invariant at the resume boundary too.
            #[cfg(debug_assertions)]
            {
                tp1_index.debug_verify(&instances);
                load_index.debug_verify(&instances, &engine);
            }
        }
        let mut sim = ClusterSim {
            cfg,
            engine,
            system,
            instances,
            epochs: s.epochs.clone(),
            pending: s
                .pending
                .iter()
                .map(|p| match p {
                    PendingSnap::None => None,
                    PendingSnap::Prefill { req_id } => Some(Pending::Prefill { req_id: *req_id }),
                    PendingSnap::Decode => Some(Pending::Decode),
                    PendingSnap::Maintenance => Some(Pending::Maintenance),
                })
                .collect(),
            queue,
            feed: ArrivalFeed::restore(s.feed.clone())?,
            policy: s.policy.restore(),
            backlog,
            recorder: Recorder::restore(
                s.recorder.rows.clone(),
                s.recorder.tps_buckets.clone(),
                s.recorder.horizon,
            ),
            counters: s.counters,
            transformation_disabled: s.transformation_disabled,
            dwell_check_scheduled: s.dwell_check_scheduled.clone(),
            tp1_index,
            load_index,
            use_routing_index: s.use_routing_index,
            profiling: false,
            profile: SimProfile::default(),
            backlog_cooldown_until: s.backlog_cooldown_until,
            backlog_wakeup_scheduled: s.backlog_wakeup_scheduled,
            fault_plan: s.fault_plan.clone(),
            fault_cursor: s.fault_cursor,
            degraded_until: s.degraded_until.clone(),
            link_down_until: s.link_down_until.clone(),
            host_blocked: vec![false; hosts],
            stall_until: s.stall_until.clone(),
            retry,
            scratch_stepped: Vec::new(),
            scratch_finished: Vec::new(),
            pool_running: Vec::new(),
            pool_prefill: Vec::new(),
            error: None,
            cache: s.cache.clone(),
        };
        // Derived state: the blocked mask is a pure function of the
        // serialized crash/link windows at the snapshot instant.
        sim.refresh_host_blocked(snap.sim_time);
        Ok(sim)
    }

    // -----------------------------------------------------------------
    // Event handlers
    // -----------------------------------------------------------------

    fn on_arrival(&mut self, tr: TraceRequest) {
        let now = tr.arrival;
        self.recorder.on_arrival_classed(tr.id, now, tr.input_len, tr.output_len, tr.class);
        let req = ActiveRequest::new(tr.id, now, tr.input_len, tr.output_len)
            .with_class(tr.class)
            .with_prefix(tr.prefix);
        self.route_one(now, req, None);
    }

    /// Route one request — a fresh arrival (`deferred: None`) or a
    /// backlog retry carrying its (first-deferral time, failed-attempt
    /// count). Returns true when the request was placed (assign or
    /// scale-up), false when it (re-)joined the backlog or was dropped
    /// by an exhausted [`RetryPolicy`].
    fn route_one(
        &mut self,
        now: SimTime,
        req: ActiveRequest,
        deferred: Option<(SimTime, u32)>,
    ) -> bool {
        let (tp1, load) = if self.use_routing_index {
            (Some(&self.tp1_index), Some(&self.load_index))
        } else {
            (None, None)
        };
        let view = ClusterView {
            instances: &self.instances,
            engine: &self.engine,
            cfg: &self.cfg,
            now,
            tp1,
            load,
            blocked_hosts: self.blocked_hosts_view(),
            cache: self.cache.as_ref(),
        };
        self.counters.routes += 1;
        if deferred.is_some() {
            self.counters.backlog_retries += 1;
        }
        let t0 = self.prof_start();
        let route = self.policy.route(&req, &view);
        Self::prof_add(t0, &mut self.profile.route_s);
        // Resolve preemption against exact pending state: the policy's
        // victim check is optimistic (it cannot see which queued prefill
        // already has its completion event in flight), so a failed plan
        // degrades to Defer here rather than inside the policy.
        let route = match route {
            Route::Preempt { victim } => {
                if self.try_preempt(now, victim, &req) {
                    Route::Assign(victim)
                } else {
                    Route::Defer
                }
            }
            r => r,
        };
        // Failure-aware backstop: even if a policy ignores the blocked
        // mask, no transformation may target a crashed host or migrate
        // KV over a dead link.
        let route = match route {
            Route::ScaleUp { ref members, .. }
                if !self.transformation_disabled
                    && self.host_blocked[self.instances[members[0]].host] =>
            {
                self.counters.scale_up_blocked += 1;
                Route::Defer
            }
            r => r,
        };
        let placed = |sim: &mut ClusterSim, iid: usize, mut req: ActiveRequest| {
            if let Some((since, _)) = deferred {
                sim.counters.backlog_wait += now.since(since);
            }
            // Armed cache: record the placement on the instance's prefix
            // tree and credit the matched tokens against the prefill
            // duration. Matched tokens never exceed the prompt: the
            // prefix path covers prompt tokens by construction, but a
            // snapshot-restored tree plus a mid-stream re-route could
            // otherwise over-credit a shorter retry.
            if let Some(cache) = sim.cache.as_mut() {
                let cap = sim.instances[iid].kv_capacity(&sim.engine);
                let matched = cache.observe(iid, &req.prefix, now, cap);
                req.cached_tokens = matched.min(req.input_len);
            }
            sim.instances[iid].admit(req);
            sim.reindex(iid);
            sim.kick(now, iid);
        };
        match route {
            Route::Assign(iid) => {
                placed(self, iid, req);
                true
            }
            Route::ScaleUp { members, to_tp } if !self.transformation_disabled => {
                let iid = self.scale_up(now, members, to_tp);
                placed(self, iid, req);
                true
            }
            Route::Drop => {
                // Deadline-aware admission control: the decision stage
                // shed the request outright. It never re-enters the
                // backlog; the recorder keeps its arrival row (an
                // accepted-then-unserved request, like retry exhaustion).
                self.counters.dropped += 1;
                self.counters.admission_dropped += 1;
                false
            }
            Route::Preempt { .. } => unreachable!("preemption resolved above"),
            // ScaleUp with transformation disabled degrades to Defer.
            Route::ScaleUp { .. } | Route::Defer => {
                let (since, prior) = match deferred {
                    None => {
                        self.counters.deferred += 1;
                        (now, 0)
                    }
                    Some((s, a)) => {
                        self.counters.backlog_requeues += 1;
                        (s, a)
                    }
                };
                let attempts = prior + 1;
                if self.retry.exhausted(attempts) {
                    // Admission control: shed the request instead of
                    // livelocking the backlog when capacity < demand.
                    self.counters.dropped += 1;
                    return false;
                }
                let next_retry = self.retry.next_retry(now, attempts);
                self.backlog.push_back(Deferred { req, since, attempts, next_retry });
                false
            }
        }
    }

    /// Execute a [`Route::Preempt`] decision: evict the minimal set of
    /// queued batch-class prefills from `victim` (newest first, never
    /// the one whose completion event is in flight) so `req` fits, and
    /// requeue them through the backlog as fresh attempts (`attempts:
    /// 0` — being preempted is not a placement failure). Queued
    /// prefills hold no KV and have recorded no progress, so eviction
    /// is pure queue/aggregate surgery. Returns false (caller defers
    /// `req`) when even the full evictable set falls short.
    fn try_preempt(&mut self, now: SimTime, victim: usize, req: &ActiveRequest) -> bool {
        let inflight = match self.pending[victim] {
            Some(Pending::Prefill { req_id }) => Some(req_id),
            _ => None,
        };
        let Some(plan) =
            self.instances[victim].preempt_plan(&self.engine, inflight, req)
        else {
            return false;
        };
        if plan.is_empty() {
            return true; // already fits — nothing to evict
        }
        let evicted = self.instances[victim].evict_prefills(&plan);
        self.counters.preemptions += evicted.len() as u64;
        for r in evicted {
            // The rebuilt request keeps its prefix path (a later
            // placement can still cache-hit) but drops `cached_tokens` —
            // the credit belongs to the instance it was evicted from.
            let back = ActiveRequest::new(r.id, r.arrival, r.input_len, r.output_len)
                .with_class(r.class)
                .with_prefix(r.prefix);
            self.backlog.push_back(Deferred {
                req: back,
                since: now,
                attempts: 0,
                next_retry: now,
            });
        }
        self.reindex(victim);
        true
    }

    fn on_step(&mut self, now: SimTime, iid: usize) {
        self.counters.steps += 1;
        self.instances[iid].stepping = false;
        self.dwell_check_scheduled[iid] = false;
        let pending = self.pending[iid].take();
        let mut finished_any = false;
        match pending {
            Some(Pending::Prefill { req_id }) => {
                if let Some(req) = self.instances[iid].complete_prefill(req_id) {
                    self.recorder.on_first_token(req_id, now);
                    if req.done() {
                        self.instances[iid].release_kv(req.context_len());
                        self.recorder.on_finish(req_id, now);
                        finished_any = true;
                    } else {
                        self.instances[iid].enqueue_running(req);
                    }
                }
            }
            Some(Pending::Decode) => {
                // Only the continuous batch (max_batch_size slots) advances
                // this step; the rest wait and the window rotates so every
                // running request makes progress across steps.
                let mut stepped = std::mem::take(&mut self.scratch_stepped);
                let mut finished = std::mem::take(&mut self.scratch_finished);
                stepped.clear();
                finished.clear();
                self.instances[iid].decode_advance(
                    self.cfg.max_batch_size,
                    &mut stepped,
                    &mut finished,
                );
                for &id in &stepped {
                    self.recorder.on_token(id, now);
                }
                for &id in &finished {
                    self.recorder.on_finish(id, now);
                }
                finished_any = !finished.is_empty();
                self.scratch_stepped = stepped;
                self.scratch_finished = finished;
            }
            Some(Pending::Maintenance) => {
                // Idle transformation drain completed.
                if let Some(ts) = &mut self.instances[iid].transforming {
                    while ts.exec.advance().is_some() {}
                }
                self.clear_transform_if_done(now, iid);
            }
            None => {}
        }
        if self.instances[iid].is_idle() {
            // Exact-bookkeeping invariant: a drained instance holds no KV.
            self.instances[iid].debug_assert_consistent();
        }
        // Prefill completions and decode finishes change committed tokens.
        self.reindex(iid);
        self.clear_transform_if_done(now, iid);
        self.maybe_scale_down(now, iid);
        if !self.instances[iid].retired {
            self.kick(now, iid);
        }
        if finished_any {
            self.drain_backlog(now);
        }
    }

    fn on_transform_done(&mut self, now: SimTime, iid: usize) {
        let inst = &mut self.instances[iid];
        let mut cleared = false;
        if let Some(ts) = &mut inst.transforming {
            if let Some(until) = ts.blocked_until {
                if now >= until {
                    inst.transforming = None;
                    inst.last_transform = now;
                    cleared = true;
                }
            }
        }
        if cleared {
            self.reindex(iid);
        }
        self.kick(now, iid);
        self.drain_backlog(now);
    }

    // -----------------------------------------------------------------
    // Stepping
    // -----------------------------------------------------------------

    /// Schedule the next step of `iid` if it has work and none scheduled.
    fn kick(&mut self, now: SimTime, iid: usize) {
        self.counters.kicks += 1;
        let t0 = self.prof_start();
        self.kick_inner(now, iid);
        Self::prof_add(t0, &mut self.profile.kick_s);
    }

    fn kick_inner(&mut self, now: SimTime, iid: usize) {
        let max_batch = self.cfg.max_batch_size;
        let inst = &self.instances[iid];
        if inst.retired || inst.stepping {
            return;
        }
        if now < self.stall_until[iid] {
            // Frozen by an injected stall; the StallEnd event re-kicks.
            return;
        }
        if let Some(ts) = &inst.transforming {
            if let Some(until) = ts.blocked_until {
                // Blocked (Seesaw): wait for TransformDone.
                let _ = until;
                return;
            }
        }
        let step = self.instances[iid].next_step(&self.engine, max_batch);
        let (pending, mut duration) = match step {
            Some(StepKind::Prefill { req_id, duration }) => {
                (Pending::Prefill { req_id }, duration)
            }
            Some(StepKind::Decode { duration }) => (Pending::Decode, duration),
            None => {
                // Idle: drain any non-blocking transformation in one quantum.
                if let Some(ts) = &self.instances[iid].transforming {
                    if ts.blocked_until.is_none() && !ts.exec.done() {
                        let remaining_steps =
                            (ts.exec.plan.num_steps() - ts.exec.step) as u64;
                        let d = SimDuration::from_millis_f64(5.0 * remaining_steps as f64);
                        self.pending[iid] = Some(Pending::Maintenance);
                        self.instances[iid].stepping = true;
                        self.queue.push(now + d, Event::Step(iid, self.epochs[iid]));
                    }
                } else if self.instances[iid].degree > 1
                    && !self.transformation_disabled
                    && !self.dwell_check_scheduled[iid]
                {
                    // Idle high-TP instance: re-check scale-down once the
                    // dwell window has elapsed (Algorithm 2 would
                    // otherwise never fire without serving steps). At most
                    // one re-check per idle period.
                    let d = SimDuration::from_secs_f64(self.cfg.min_dwell_s);
                    self.pending[iid] = None;
                    self.instances[iid].stepping = true;
                    self.dwell_check_scheduled[iid] = true;
                    self.queue.push(now + d, Event::Step(iid, self.epochs[iid]));
                }
                return;
            }
        };
        // Charge in-flight transformation overhead to this step.
        if let Some(ts) = &mut self.instances[iid].transforming {
            if ts.blocked_until.is_none() {
                if let Some(extra) = ts.exec.advance() {
                    duration += extra;
                }
            }
        }
        self.pending[iid] = Some(pending);
        self.instances[iid].stepping = true;
        self.queue.push(now + duration, Event::Step(iid, self.epochs[iid]));
    }

    fn clear_transform_if_done(&mut self, now: SimTime, iid: usize) {
        let inst = &mut self.instances[iid];
        let mut cleared = false;
        if let Some(ts) = &inst.transforming {
            if ts.blocked_until.is_none() && ts.exec.done() {
                inst.transforming = None;
                inst.last_transform = now;
                cleared = true;
            }
        }
        if cleared {
            self.reindex(iid);
        }
    }

    /// Retry the deferred queue. A pass routes every parked request once;
    /// a pass that places nothing arms a cooldown (no further passes until
    /// it elapses — calls in between are O(1) suppressions that guarantee
    /// a [`Event::BacklogWakeup`] retries at the deadline), so retries
    /// keep happening under sustained overload without re-routing the
    /// whole backlog on every finish/transform event. A no-progress pass
    /// only re-arms while *other* events are pending: with nothing left
    /// that could change cluster state, an unserveable backlog stops
    /// generating wakeups and the run terminates. A suppressed call, by
    /// contrast, always schedules the wakeup — state may have changed
    /// since the pass that armed the cooldown (a finish freed capacity),
    /// and the wakeup's own pass is never suppressed, so no request is
    /// stranded by the cooldown.
    fn drain_backlog(&mut self, now: SimTime) {
        let t0 = self.prof_start();
        self.drain_backlog_inner(now);
        Self::prof_add(t0, &mut self.profile.drain_backlog_s);
    }

    fn drain_backlog_inner(&mut self, now: SimTime) {
        if self.backlog.is_empty() {
            return;
        }
        if now < self.backlog_cooldown_until {
            self.counters.backlog_suppressed += 1;
            self.schedule_backlog_wakeup();
            return;
        }
        // SLO lanes: stable-partition the backlog interactive-first, so
        // every retry pass places interactive work before batch work.
        // Plain policies never reach this (wants_slo_lanes is false), so
        // their backlog order — and output bytes — are untouched.
        if self.policy.wants_slo_lanes() && self.backlog.len() > 1 {
            let mut lanes: VecDeque<Deferred> = VecDeque::with_capacity(self.backlog.len());
            let mut batch: Vec<Deferred> = Vec::new();
            for d in self.backlog.drain(..) {
                match d.req.class {
                    SloClass::Interactive => lanes.push_back(d),
                    SloClass::Batch => batch.push(d),
                }
            }
            lanes.extend(batch);
            self.backlog = lanes;
        }
        let mut progress = false;
        let mut tries = self.backlog.len();
        while tries > 0 {
            tries -= 1;
            let Some(d) = self.backlog.pop_front() else { break };
            if now < d.next_retry {
                // Exponential-backoff window still open: rotate the
                // entry back untouched — not an attempt, not progress.
                self.backlog.push_back(d);
                continue;
            }
            if self.route_one(now, d.req, Some((d.since, d.attempts))) {
                progress = true;
            }
        }
        if progress {
            self.backlog_cooldown_until = SimTime::ZERO;
        } else if !self.backlog.is_empty() {
            let cooldown = SimDuration::from_secs_f64(self.cfg.backlog_retry_cooldown_s);
            // Pending future arrivals count as "other events" here: in
            // the pre-streaming loop they sat in the event queue, and a
            // wakeup must keep retrying while anything can still change
            // cluster state. Under a *bounded* retry policy the backlog
            // itself keeps the wakeup chain alive even when every other
            // event source is drained (a fault can empty the fleet with
            // nothing else queued): each retry pass increments attempt
            // counts, so the chain terminates in counted drops instead
            // of an unbounded wakeup loop.
            if cooldown > SimDuration::ZERO
                && (!self.queue.is_empty()
                    || self.feed.pending()
                    || (self.retry.bounded() && !self.backlog.is_empty()))
            {
                let mut deadline = now + cooldown;
                // If every parked entry is backing off past the
                // cooldown, waking earlier would be a guaranteed
                // no-op pass: push the wakeup to first eligibility.
                if let Some(min_retry) = self.backlog.iter().map(|d| d.next_retry).min() {
                    if min_retry > deadline {
                        deadline = min_retry;
                    }
                }
                self.backlog_cooldown_until = deadline;
                self.schedule_backlog_wakeup();
            }
        }
    }

    fn schedule_backlog_wakeup(&mut self) {
        if !self.backlog_wakeup_scheduled {
            self.queue.push(self.backlog_cooldown_until, Event::BacklogWakeup);
            self.backlog_wakeup_scheduled = true;
        }
    }

    // -----------------------------------------------------------------
    // Transformation
    // -----------------------------------------------------------------

    /// Merge `members` (TP1, same host) into one instance of degree
    /// `to_tp`; returns the new instance id.
    fn scale_up(&mut self, now: SimTime, members: Vec<usize>, to_tp: u64) -> usize {
        assert_eq!(members.len() as u64, to_tp, "member count must equal target degree");
        self.counters.scale_ups += 1;
        let host = self.instances[members[0]].host;
        let new_id = self.instances.len();
        let mut merged = Instance::new(new_id, host, Vec::new(), to_tp);
        merged.kind = self.system.parallel_kind();
        let mut avg_util = 0.0;
        let mut running = std::mem::take(&mut self.pool_running);
        let mut prefill = std::mem::take(&mut self.pool_prefill);
        for &m in &members {
            assert_eq!(self.instances[m].host, host, "cross-host merge");
            assert_eq!(self.instances[m].degree, 1, "only TP1 members merge");
            // Sample utilization BEFORE the drain empties the member (as
            // scale_down already does): the merge's transformation cost is
            // charged at the members' real KV occupancy, not the 0.05
            // clamp floor the drained-then-sampled seed ordering produced.
            avg_util += self.instances[m].load(&self.engine) / members.len() as f64;
            let inst = &mut self.instances[m];
            inst.retired = true;
            merged.workers.extend(inst.workers.drain(..));
            merged.kv_tokens += inst.drain_work_into(&mut running, &mut prefill);
            for r in running.drain(..) {
                merged.enqueue_running(r);
            }
            for r in prefill.drain(..) {
                merged.enqueue_prefill(r);
            }
            self.epochs[m] += 1; // invalidate in-flight events
            self.reindex(m);
            // The member's KV re-shards into the merged layout — its
            // prefix cache does not survive the transformation.
            if let Some(c) = self.cache.as_mut() {
                c.retire(m);
            }
        }
        self.pool_running = running;
        self.pool_prefill = prefill;
        merged.last_transform = now;
        // A stalled member's freeze carries into the merged instance
        // (its workers are the same stalled GPUs); the members' own
        // StallEnd events went stale with their epoch bump, so re-arm
        // one for the merged id.
        let inherited_stall =
            members.iter().map(|&m| self.stall_until[m]).max().unwrap_or(SimTime::ZERO);
        self.instances.push(merged);
        self.epochs.push(0);
        self.pending.push(None);
        self.dwell_check_scheduled.push(false);
        self.stall_until.push(inherited_stall);
        if inherited_stall > now {
            self.queue.push(inherited_stall, Event::StallEnd(new_id, 0));
        }
        self.attach_transform(now, new_id, 1, to_tp, avg_util);
        new_id
    }

    /// Split a TP>1 instance back into TP1 instances (Algorithm 2 action).
    fn scale_down(&mut self, now: SimTime, iid: usize) {
        self.counters.scale_downs += 1;
        let from_tp = self.instances[iid].degree;
        let host = self.instances[iid].host;
        let util = self.instances[iid].load(&self.engine);
        let mut running = std::mem::take(&mut self.pool_running);
        let mut prefill = std::mem::take(&mut self.pool_prefill);
        let workers = {
            let inst = &mut self.instances[iid];
            inst.retired = true;
            self.epochs[iid] += 1;
            let workers = std::mem::take(&mut inst.workers);
            let _stale_kv = inst.drain_work_into(&mut running, &mut prefill);
            workers
        };
        self.reindex(iid);
        // Split: the parent's prefix cache dies with its sharded KV; the
        // TP1 children start cold.
        if let Some(c) = self.cache.as_mut() {
            c.retire(iid);
        }
        let parent_stall = self.stall_until[iid];
        let n = from_tp as usize;
        let mut new_ids = Vec::with_capacity(n);
        for k in 0..n {
            let id = self.instances.len();
            let mut inst = Instance::new(id, host, vec![workers[k]], 1);
            inst.last_transform = now;
            self.instances.push(inst);
            self.epochs.push(0);
            self.pending.push(None);
            self.dwell_check_scheduled.push(false);
            // Split children of a stalled parent stay frozen until the
            // stall window closes (their GPUs are the stalled ones).
            self.stall_until.push(parent_stall);
            if parent_stall > now {
                self.queue.push(parent_stall, Event::StallEnd(id, 0));
            }
            new_ids.push(id);
        }
        // Redistribute work round-robin; everything fits by the
        // `should_scale_down` precondition (no long requests). KV moves
        // with each request at its exact current context length.
        for (k, r) in running.drain(..).enumerate() {
            self.instances[new_ids[k % n]].receive_running(r);
        }
        for (k, r) in prefill.drain(..).enumerate() {
            self.instances[new_ids[k % n]].enqueue_prefill(r);
        }
        self.pool_running = running;
        self.pool_prefill = prefill;
        for &id in &new_ids {
            self.attach_transform(now, id, from_tp, 1, util);
            self.kick(now, id);
        }
    }

    /// Attach the transformation cost machinery to an instance.
    fn attach_transform(
        &mut self,
        now: SimTime,
        iid: usize,
        from_tp: u64,
        to_tp: u64,
        kv_util: f64,
    ) {
        let kv_util = kv_util.clamp(0.05, 0.95);
        match self.system.mechanism() {
            Some(mech) => {
                let plan = TransformPlan::build(&self.cfg.model, from_tp, to_tp, 1);
                let exec =
                    TransformExec::new(&self.cfg.model, &self.cfg.gpu, plan, kv_util, mech);
                let cost =
                    estimate(&self.cfg.model, &self.cfg.gpu, from_tp, to_tp, kv_util, mech);
                let blocked_until = if cost.blocking { Some(now + cost.total) } else { None };
                if let Some(until) = blocked_until {
                    self.queue.push(until, Event::TransformDone(iid, self.epochs[iid]));
                }
                self.instances[iid].transforming = Some(TransformState { exec, blocked_until });
            }
            None => {
                // PP/SP re-grouping: a brief non-blocking reconfiguration.
                let until = now + SimDuration::from_millis_f64(100.0);
                self.instances[iid].transforming = Some(TransformState {
                    exec: TransformExec::new(
                        &self.cfg.model,
                        &self.cfg.gpu,
                        TransformPlan::build(
                            &self.cfg.model,
                            from_tp,
                            to_tp,
                            self.cfg.model.num_layers as usize,
                        ),
                        kv_util,
                        Mechanism::Gyges,
                    ),
                    blocked_until: Some(until),
                });
                self.queue.push(until, Event::TransformDone(iid, self.epochs[iid]));
            }
        }
        self.reindex(iid);
    }

    fn maybe_scale_down(&mut self, now: SimTime, iid: usize) {
        if self.transformation_disabled {
            return;
        }
        let (tp1, load) = if self.use_routing_index {
            (Some(&self.tp1_index), Some(&self.load_index))
        } else {
            (None, None)
        };
        let view = ClusterView {
            instances: &self.instances,
            engine: &self.engine,
            cfg: &self.cfg,
            now,
            tp1,
            load,
            blocked_hosts: self.blocked_hosts_view(),
            cache: self.cache.as_ref(),
        };
        let inst = &self.instances[iid];
        if self.policy.should_scale_down(inst, &view) {
            self.scale_down(now, iid);
        }
    }

    // -----------------------------------------------------------------
    // Fault injection (see rust/src/faults/ and PERF.md)
    // -----------------------------------------------------------------

    /// Dispatch fault `idx` of the armed plan and schedule its successor.
    fn on_fault(&mut self, now: SimTime, idx: usize) {
        let Fault { kind, .. } = self.fault_plan.faults[idx];
        self.fault_cursor = idx + 1;
        if let Some(next) = self.fault_plan.faults.get(self.fault_cursor) {
            self.queue.push(next.at, Event::Fault(self.fault_cursor));
        }
        match kind {
            FaultKind::HostCrash { host, mttr } => self.on_host_crash(now, host, mttr),
            FaultKind::InstanceStall { worker, dur } => self.on_instance_stall(now, worker, dur),
            FaultKind::TransformAbort { worker } => self.on_transform_abort(now, worker),
            FaultKind::LinkDown { host, dur } => self.on_link_down(now, host, dur),
        }
    }

    /// Recompute the per-host blocked flags from the crash/link windows.
    /// Called only at fault/recovery transition events — between events
    /// the flags cannot change, so routing views read exact state.
    fn refresh_host_blocked(&mut self, now: SimTime) {
        for h in 0..self.cfg.hosts {
            self.host_blocked[h] = now < self.degraded_until[h] || now < self.link_down_until[h];
        }
    }

    /// A host dies: every instance on it loses its KV cache and weights;
    /// their in-flight requests restart from scratch through the backlog
    /// (original arrival stamps preserved, so TTFT/latency metrics charge
    /// the crash to the request). The host rejoins after `mttr`.
    fn on_host_crash(&mut self, now: SimTime, host: usize, mttr: SimDuration) {
        if now < self.degraded_until[host] {
            return; // already down: nothing left on it to kill
        }
        let victims: Vec<usize> = self
            .instances
            .iter()
            .filter(|i| !i.retired && i.host == host)
            .map(|i| i.id)
            .collect();
        for iid in victims {
            self.crash_instance(now, iid);
        }
        self.degraded_until[host] = now + mttr;
        self.refresh_host_blocked(now);
        self.queue.push(now + mttr, Event::HostRestore(host));
        self.drain_backlog(now);
    }

    /// Kill one instance: retire it, invalidate its in-flight events,
    /// and requeue whatever it was serving.
    fn crash_instance(&mut self, now: SimTime, iid: usize) {
        self.counters.crashed_instances += 1;
        self.epochs[iid] += 1; // in-flight Step/TransformDone go stale
        self.pending[iid] = None;
        self.dwell_check_scheduled[iid] = false;
        self.stall_until[iid] = SimTime::ZERO;
        let mut running = std::mem::take(&mut self.pool_running);
        let mut prefill = std::mem::take(&mut self.pool_prefill);
        {
            let inst = &mut self.instances[iid];
            inst.retired = true;
            inst.transforming = None;
            inst.stepping = false;
            inst.workers.clear();
            let _lost_kv = inst.drain_work_into(&mut running, &mut prefill);
        }
        self.reindex(iid);
        // Crash: every cached prefix block on the instance is gone.
        if let Some(c) = self.cache.as_mut() {
            c.retire(iid);
        }
        for r in running.drain(..).chain(prefill.drain(..)) {
            self.requeue_lost(now, r);
        }
        self.pool_running = running;
        self.pool_prefill = prefill;
    }

    /// A request whose serving state died with its instance: generated
    /// tokens and KV are gone. Re-register it with the recorder at its
    /// ORIGINAL arrival (unwinding the lost progress from the totals)
    /// and send the rebuilt request through the backlog as a fresh
    /// attempt (`attempts: 0` — a crash is not a placement failure).
    fn requeue_lost(&mut self, now: SimTime, r: ActiveRequest) {
        self.counters.crash_requeued += 1;
        self.recorder.on_arrival_classed(r.id, r.arrival, r.input_len, r.output_len, r.class);
        let req = ActiveRequest::new(r.id, r.arrival, r.input_len, r.output_len)
            .with_class(r.class)
            .with_prefix(r.prefix);
        self.backlog.push_back(Deferred { req, since: now, attempts: 0, next_retry: now });
    }

    /// MTTR elapsed: the host's GPUs rejoin as fresh TP1 instances
    /// (cold — no KV, no running work) through the same `reindex` path
    /// every other topology mutation uses.
    fn on_host_restore(&mut self, now: SimTime, host: usize) {
        if now < self.degraded_until[host] {
            return; // superseded by a later crash of the same host
        }
        for g in 0..self.cfg.gpus_per_host {
            let id = self.instances.len();
            let mut inst = Instance::new(id, host, vec![host * self.cfg.gpus_per_host + g], 1);
            inst.last_transform = now;
            self.instances.push(inst);
            self.epochs.push(0);
            self.pending.push(None);
            self.dwell_check_scheduled.push(false);
            self.stall_until.push(SimTime::ZERO);
            self.reindex(id);
        }
        self.refresh_host_blocked(now);
        self.drain_backlog(now);
    }

    /// A transient stall freezes the instance owning `worker`: the
    /// in-flight step is discarded (epoch bump) and no new step is
    /// scheduled until the window closes. Request state survives intact.
    fn on_instance_stall(&mut self, now: SimTime, worker: usize, dur: SimDuration) {
        let Some(iid) = self
            .instances
            .iter()
            .position(|i| !i.retired && i.workers.contains(&worker))
        else {
            return; // worker currently unowned (its host is down)
        };
        self.counters.stalled_instances += 1;
        self.epochs[iid] += 1;
        self.pending[iid] = None;
        self.instances[iid].stepping = false;
        self.dwell_check_scheduled[iid] = false;
        let until = self.stall_until[iid].max(now + dur);
        self.stall_until[iid] = until;
        // A blocked (Seesaw) transform's TransformDone went stale with
        // the epoch bump: extend it past the stall and re-arm it.
        let mut re_push = None;
        if let Some(ts) = &mut self.instances[iid].transforming {
            if let Some(b) = ts.blocked_until {
                let nb = b.max(until);
                ts.blocked_until = Some(nb);
                re_push = Some(nb);
            }
        }
        if let Some(at) = re_push {
            self.queue.push(at, Event::TransformDone(iid, self.epochs[iid]));
        }
        self.queue.push(until, Event::StallEnd(iid, self.epochs[iid]));
    }

    /// Abort the in-flight (non-blocked, unfinished) transformation on
    /// the instance owning `worker`, rolling it back to `from_tp`.
    fn on_transform_abort(&mut self, now: SimTime, worker: usize) {
        let Some(iid) = self.instances.iter().position(|i| {
            !i.retired
                && i.workers.contains(&worker)
                && i.transforming
                    .as_ref()
                    .map(|ts| ts.blocked_until.is_none() && !ts.exec.done())
                    .unwrap_or(false)
        }) else {
            return; // nothing transforming there — the abort fizzles
        };
        self.rollback_transform(now, iid);
    }

    /// Roll a mid-flight transformation back to its `from_tp` topology
    /// with a charged rollback cost. Direction decides the mechanics:
    ///
    /// - **ScaleUp exec** (a merged instance still re-sharding): the
    ///   merge un-does — split back into TP1 instances, each blocked
    ///   for the reverse re-shard cost scaled by how far the aborted
    ///   transform had progressed. Requests that no longer fit a TP1
    ///   (the long request that motivated the merge) lost their KV
    ///   mid-migration and retry through the backlog.
    /// - **ScaleDown exec** (a TP1 still draining its split): the
    ///   executor restarts at step 0 — the already-transformed layers
    ///   re-transform, re-charging their visible overhead.
    fn rollback_transform(&mut self, now: SimTime, iid: usize) {
        self.counters.transform_rollbacks += 1;
        let (direction, to_tp, mech, progress) = {
            // gyges-lint: allow(D06) every caller dispatches on transforming.is_some()
            let ts = self.instances[iid].transforming.as_ref().expect("caller checked");
            (ts.exec.plan.direction, ts.exec.plan.to_tp, ts.exec.mech, ts.exec.progress())
        };
        match direction {
            Direction::ScaleDown => {
                let inst = &mut self.instances[iid];
                if let Some(ts) = &mut inst.transforming {
                    let plan = ts.exec.plan.clone();
                    let pov = ts.exec.per_op_visible();
                    ts.exec = TransformExec::from_parts(plan, mech, pov, 0);
                }
                self.reindex(iid);
                // Aborting mid-re-shard scrambles the block layout; the
                // instance keeps serving but its prefix cache is cold.
                if let Some(c) = self.cache.as_mut() {
                    c.invalidate(iid);
                }
            }
            Direction::ScaleUp => {
                let host = self.instances[iid].host;
                let util = self.instances[iid].load(&self.engine).clamp(0.05, 0.95);
                self.epochs[iid] += 1;
                self.pending[iid] = None;
                self.dwell_check_scheduled[iid] = false;
                let parent_stall = self.stall_until[iid];
                self.stall_until[iid] = SimTime::ZERO;
                let mut running = std::mem::take(&mut self.pool_running);
                let mut prefill = std::mem::take(&mut self.pool_prefill);
                let workers = {
                    let inst = &mut self.instances[iid];
                    inst.retired = true;
                    inst.transforming = None;
                    inst.stepping = false;
                    let workers = std::mem::take(&mut inst.workers);
                    let _kv = inst.drain_work_into(&mut running, &mut prefill);
                    workers
                };
                self.reindex(iid);
                // The aborted parent is retired; its replacement TP1
                // children start with cold prefix caches.
                if let Some(c) = self.cache.as_mut() {
                    c.retire(iid);
                }
                let n = workers.len();
                let mut new_ids = Vec::with_capacity(n);
                for k in 0..n {
                    let id = self.instances.len();
                    let mut inst = Instance::new(id, host, vec![workers[k]], 1);
                    inst.last_transform = now;
                    self.instances.push(inst);
                    self.epochs.push(0);
                    self.pending.push(None);
                    self.dwell_check_scheduled.push(false);
                    self.stall_until.push(parent_stall);
                    if parent_stall > now {
                        self.queue.push(parent_stall, Event::StallEnd(id, 0));
                    }
                    new_ids.push(id);
                }
                let tp1_max = self.engine.max_seq(1);
                let mut k = 0usize;
                for r in running.drain(..) {
                    if r.final_len() <= tp1_max {
                        self.instances[new_ids[k % n]].receive_running(r);
                        k += 1;
                    } else {
                        self.requeue_lost(now, r);
                    }
                }
                for r in prefill.drain(..) {
                    if r.final_len() <= tp1_max {
                        self.instances[new_ids[k % n]].enqueue_prefill(r);
                        k += 1;
                    } else {
                        self.requeue_lost(now, r);
                    }
                }
                self.pool_running = running;
                self.pool_prefill = prefill;
                // Charge the rollback: each TP1 blocks for the reverse
                // re-shard, scaled by the aborted transform's progress
                // (aborting at 10% un-does less than at 90%).
                let cost = estimate(&self.cfg.model, &self.cfg.gpu, to_tp, 1, util, mech);
                let charge = cost.total.scale(progress);
                let rb_plan = TransformPlan::build(&self.cfg.model, to_tp, 1, 1);
                let rb_steps = rb_plan.num_steps();
                for &id in &new_ids {
                    let until = now + charge;
                    self.instances[id].transforming = Some(TransformState {
                        exec: TransformExec::from_parts(
                            rb_plan.clone(),
                            mech,
                            SimDuration::ZERO,
                            rb_steps,
                        ),
                        blocked_until: Some(until),
                    });
                    self.queue.push(until, Event::TransformDone(id, 0));
                    self.reindex(id);
                }
                self.drain_backlog(now);
            }
        }
    }

    /// A KV-migration link outage: in-flight (non-blocked) transforms on
    /// the host abort mid-migration, and no new transformation may
    /// target the host until the link restores.
    fn on_link_down(&mut self, now: SimTime, host: usize, dur: SimDuration) {
        let victims: Vec<usize> = self
            .instances
            .iter()
            .filter(|i| {
                !i.retired
                    && i.host == host
                    && i.transforming
                        .as_ref()
                        .map(|ts| ts.blocked_until.is_none() && !ts.exec.done())
                        .unwrap_or(false)
            })
            .map(|i| i.id)
            .collect();
        for iid in victims {
            self.rollback_transform(now, iid);
        }
        let until = now + dur;
        if until > self.link_down_until[host] {
            self.link_down_until[host] = until;
            self.queue.push(until, Event::LinkRestore(host));
        }
        self.refresh_host_blocked(now);
    }

    /// The link outage window closed (unless a later outage extended it,
    /// in which case that outage's own LinkRestore event governs).
    fn on_link_restore(&mut self, now: SimTime, host: usize) {
        if now < self.link_down_until[host] {
            return;
        }
        self.refresh_host_blocked(now);
        self.drain_backlog(now);
    }
}

/// Convenience: run a full experiment.
pub fn run_system(
    cfg: ClusterConfig,
    system: SystemKind,
    policy: Option<PolicyId>,
    trace: Trace,
) -> SimOutcome {
    let mut sim = ClusterSim::new(cfg, system, trace);
    if let Some(p) = policy {
        sim = sim.with_policy(p);
    }
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn small_cfg() -> ClusterConfig {
        ClusterConfig::paper_default(ModelConfig::qwen2_5_32b())
    }

    fn short_trace(n: usize) -> Trace {
        let mut t = Trace::default();
        for i in 0..n {
            t.requests.push(crate::workload::TraceRequest {
                id: i as u64,
                arrival: SimTime::from_secs_f64(i as f64 * 0.5),
                input_len: 1000,
                output_len: 50,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        t
    }

    #[test]
    fn serves_short_trace_completely() {
        let out = run_system(small_cfg(), SystemKind::Gyges, None, short_trace(40));
        assert_eq!(out.report.completed, 40, "all requests must finish");
        assert_eq!(out.counters.scale_ups, 0, "shorts never trigger scale-up");
        assert!(out.report.throughput_tps > 0.0);
        assert!(out.error.is_none());
        assert!(out.counters.events >= out.counters.steps);
    }

    #[test]
    fn long_request_triggers_scale_up_and_completes() {
        let mut trace = short_trace(10);
        trace.requests.push(crate::workload::TraceRequest {
            id: 10,
            arrival: SimTime::from_secs_f64(1.0),
            input_len: 50_000,
            output_len: 64,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
        trace.sort();
        let out = run_system(small_cfg(), SystemKind::Gyges, None, trace);
        assert_eq!(out.report.completed, 11);
        assert!(out.counters.scale_ups >= 1);
    }

    #[test]
    fn scale_down_happens_after_long_work_drains() {
        let mut trace = Trace::default();
        trace.requests.push(crate::workload::TraceRequest {
            id: 0,
            arrival: SimTime::ZERO,
            input_len: 50_000,
            output_len: 32,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
        // steady shorts afterwards so steps keep firing post-drain
        for i in 1..200u64 {
            trace.requests.push(crate::workload::TraceRequest {
                id: i,
                arrival: SimTime::from_secs_f64(20.0 + i as f64 * 0.5),
                input_len: 1000,
                output_len: 20,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        trace.sort();
        let out = run_system(small_cfg(), SystemKind::Gyges, None, trace);
        assert!(out.counters.scale_ups >= 1);
        assert!(out.counters.scale_downs >= 1, "TP4 must split back");
        assert_eq!(out.report.completed, 200);
    }

    #[test]
    fn deterministic_runs() {
        let t = Trace::hybrid_paper(5, 120.0);
        let a = run_system(small_cfg(), SystemKind::Gyges, None, t.clone());
        let b = run_system(small_cfg(), SystemKind::Gyges, None, t);
        assert_eq!(a.report.completed, b.report.completed);
        assert!((a.report.throughput_tps - b.report.throughput_tps).abs() < 1e-9);
        assert_eq!(a.counters.scale_ups, b.counters.scale_ups);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn policies_differ_on_hybrid_load() {
        let t = Trace::hybrid_paper(11, 240.0);
        let gy = run_system(small_cfg(), SystemKind::Gyges, Some(Policy::Gyges.into()), t.clone());
        let rr =
            run_system(small_cfg(), SystemKind::Gyges, Some(Policy::RoundRobin.into()), t.clone());
        let llf =
            run_system(small_cfg(), SystemKind::Gyges, Some(Policy::LeastLoadFirst.into()), t);
        // Gyges should not transform more often than the baselines.
        assert!(gy.counters.scale_ups <= rr.counters.scale_ups.max(llf.counters.scale_ups));
    }

    #[test]
    fn seesaw_blocks_and_hurts_ttft() {
        let mut trace = short_trace(20);
        trace.requests.push(crate::workload::TraceRequest {
            id: 20,
            arrival: SimTime::from_secs_f64(2.0),
            input_len: 50_000,
            output_len: 32,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
        trace.sort();
        let gy = run_system(small_cfg(), SystemKind::Gyges, None, trace.clone());
        let ss = run_system(small_cfg(), SystemKind::Seesaw, None, trace);
        assert!(ss.report.ttft_p99_s > gy.report.ttft_p99_s, "seesaw blocking must show");
    }

    #[test]
    fn kunserve_decodes_slower_at_high_degree() {
        let mut trace = Trace::default();
        trace.requests.push(crate::workload::TraceRequest {
            id: 0,
            arrival: SimTime::ZERO,
            input_len: 50_000,
            output_len: 128,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
        trace.sort();
        let gy = run_system(small_cfg(), SystemKind::Gyges, None, trace.clone());
        let ks = run_system(small_cfg(), SystemKind::KunServe, None, trace);
        assert_eq!(gy.report.completed, 1);
        assert_eq!(ks.report.completed, 1);
        assert!(
            ks.report.tpot_p50_s > gy.report.tpot_p50_s,
            "PP decode must be slower: {} vs {}",
            ks.report.tpot_p50_s,
            gy.report.tpot_p50_s
        );
    }

    #[test]
    fn streamed_replay_matches_whole_trace_replay() {
        let trace = Trace::hybrid_paper(0xAB, 90.0);
        let whole = run_system(small_cfg(), SystemKind::Gyges, None, trace.clone());
        let chunked = crate::workload::ChunkedTrace::with_horizon(trace, 7.5, 90.0);
        let streamed =
            ClusterSim::with_source(small_cfg(), SystemKind::Gyges, Box::new(chunked)).run();
        assert_eq!(
            whole.report.to_json().to_string(),
            streamed.report.to_json().to_string(),
            "streamed replay must be byte-identical to whole-trace replay"
        );
        assert_eq!(whole.counters, streamed.counters);
        assert!(whole.error.is_none() && streamed.error.is_none());
        assert!(
            streamed.trace_peak_buffered < whole.trace_peak_buffered,
            "streamed feed must hold less than the whole trace ({} vs {})",
            streamed.trace_peak_buffered,
            whole.trace_peak_buffered
        );
    }

    #[test]
    fn trace_source_failure_surfaces_as_structured_error() {
        use crate::workload::{TraceSegment, TraceSource};
        struct Failing(usize);
        impl TraceSource for Failing {
            fn next_segment(&mut self) -> Option<Result<TraceSegment, String>> {
                let k = self.0;
                self.0 += 1;
                match k {
                    0 => Some(Ok(TraceSegment {
                        index: 0,
                        start: SimTime::ZERO,
                        end: SimTime::from_secs_f64(5.0),
                        requests: vec![crate::workload::TraceRequest {
                            id: 0,
                            arrival: SimTime::from_secs_f64(1.0),
                            input_len: 1000,
                            output_len: 20,
                            class: SloClass::Interactive,
                            prefix: Vec::new(),
                        }],
                    })),
                    1 => Some(Err("disk on fire".into())),
                    _ => None,
                }
            }
        }
        let out =
            ClusterSim::with_source(small_cfg(), SystemKind::Gyges, Box::new(Failing(0))).run();
        // The request fed before the failure still completes; the run is
        // flagged with the source failure.
        assert_eq!(out.report.completed, 1);
        match out.error {
            Some(SimError::TraceSource { ref detail }) => {
                assert!(detail.contains("disk on fire"), "{detail}")
            }
            ref other => panic!("expected TraceSource error, got {other:?}"),
        }
    }

    /// Full-run lockstep: every plain pipeline composition must produce
    /// the same report bytes and counters as its legacy reference impl.
    /// The legacy policy is installed via `with_boxed_policy` — not the
    /// process-global `legacy_routing` flag, which would race with other
    /// tests on parallel threads.
    #[test]
    fn pipeline_matches_legacy_reference_end_to_end() {
        use super::super::scheduler::{GygesPolicy, LeastLoadPolicy, RoundRobinPolicy};
        let t = Trace::hybrid_paper(7, 180.0);
        let pairs: Vec<(PolicyId, Box<dyn RoutePolicy>)> = vec![
            (Policy::Gyges.into(), Box::new(GygesPolicy::default())),
            (Policy::RoundRobin.into(), Box::new(RoundRobinPolicy::default())),
            (Policy::LeastLoadFirst.into(), Box::new(LeastLoadPolicy)),
        ];
        for (id, legacy) in pairs {
            let pipe =
                ClusterSim::new(small_cfg(), SystemKind::Gyges, t.clone()).with_policy(id).run();
            let refr = ClusterSim::new(small_cfg(), SystemKind::Gyges, t.clone())
                .with_boxed_policy(legacy)
                .run();
            assert_eq!(
                pipe.report.to_json().to_string(),
                refr.report.to_json().to_string(),
                "pipeline {} diverged from the legacy reference",
                id.name()
            );
            assert_eq!(pipe.counters, refr.counters, "{} counters diverged", id.name());
        }
    }

    /// Saturate every instance with batch-class work, then send
    /// interactive arrivals: the `-slo` composition must preempt queued
    /// batch prefills (and lose nothing), the plain one must not.
    #[test]
    fn slo_lanes_preempt_queued_batch_work() {
        let cfg = small_cfg();
        let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
        // Batch requests sized to the TP1 sequence limit pack each
        // instance's KV with no leftover an interactive request could
        // slip into; twice the fleet-wide fit keeps backlogs deep.
        let bfl = engine.max_seq(1);
        let per_inst = (engine.kv_capacity_tokens(1) / bfl).max(1) as usize;
        let n_batch = 2 * cfg.hosts * cfg.gpus_per_host * per_inst;
        let mut trace = Trace::default();
        for i in 0..n_batch {
            trace.requests.push(crate::workload::TraceRequest {
                id: i as u64,
                arrival: SimTime::ZERO,
                input_len: bfl - 200,
                output_len: 200,
                class: SloClass::Batch,
                prefix: Vec::new(),
            });
        }
        // Interactive arrivals land before any batch prefill completes,
        // so each instance still holds evictable queued prefills.
        for k in 0..8u64 {
            trace.requests.push(crate::workload::TraceRequest {
                id: n_batch as u64 + k,
                arrival: SimTime::from_secs_f64(0.01),
                input_len: bfl - 50,
                output_len: 50,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        trace.sort();
        let plain = run_system(
            small_cfg(),
            SystemKind::Gyges,
            Some(Policy::Gyges.into()),
            trace.clone(),
        );
        let slo = run_system(
            small_cfg(),
            SystemKind::Gyges,
            Some(PolicyId::parse("gyges-slo").unwrap()),
            trace,
        );
        assert_eq!(plain.counters.preemptions, 0, "plain gyges must never preempt");
        assert!(slo.counters.preemptions >= 1, "interactive work must preempt batch prefills");
        assert_eq!(
            plain.report.completed, slo.report.completed,
            "preemption-by-requeue must not lose requests"
        );
        assert!(plain.error.is_none() && slo.error.is_none());
    }

    /// Under sustained overload with a binding deadline, the `-admit`
    /// composition sheds aged requests at the decision stage.
    #[test]
    fn admit_policy_sheds_past_deadline_work() {
        let mut cfg = small_cfg();
        cfg.slo_interactive_deadline_s = 2.0;
        cfg.slo_batch_deadline_s = 4.0;
        let mut trace = Trace::default();
        for i in 0..400u64 {
            trace.requests.push(crate::workload::TraceRequest {
                id: i,
                arrival: SimTime::from_secs_f64(i as f64 * 0.001),
                input_len: 3000,
                output_len: 200,
                class: SloClass::Interactive,
                prefix: Vec::new(),
            });
        }
        trace.sort();
        let out = run_system(
            cfg,
            SystemKind::Gyges,
            Some(PolicyId::parse("gyges-admit").unwrap()),
            trace,
        );
        assert!(out.error.is_none());
        assert!(out.counters.admission_dropped > 0, "deadline must bind under overload");
        assert!(out.counters.dropped >= out.counters.admission_dropped);
        assert!(out.report.completed > 0, "admission control sheds the tail, not everything");
    }

    #[test]
    fn event_cap_returns_structured_error() {
        let mut cfg = small_cfg();
        cfg.max_events = 50; // far below what 40 requests need
        let out = run_system(cfg, SystemKind::Gyges, None, short_trace(40));
        match out.error {
            Some(SimError::EventCapExceeded { cap, .. }) => assert_eq!(cap, 50),
            other => panic!("expected event-cap error, got {other:?}"),
        }
        assert!(out.report.completed < 40, "cut run cannot have finished everything");
        assert_eq!(out.counters.events, 50);
    }
}
