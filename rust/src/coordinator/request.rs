//! Request lifecycle state inside the serving cluster.

use crate::sim::clock::SimTime;
use crate::workload::SloClass;

/// Serving phase of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for an instance (cluster queue) or for KV room (instance
    /// queue).
    Queued,
    /// Prefill scheduled/running.
    Prefill,
    /// Token-by-token decode.
    Decode,
    Finished,
}

impl Phase {
    /// Stable identifier used by snapshots.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::Finished => "finished",
        }
    }

    pub fn by_name(s: &str) -> Option<Phase> {
        match s {
            "queued" => Some(Phase::Queued),
            "prefill" => Some(Phase::Prefill),
            "decode" => Some(Phase::Decode),
            "finished" => Some(Phase::Finished),
            _ => None,
        }
    }
}

/// A request being served.
#[derive(Clone, Debug)]
pub struct ActiveRequest {
    pub id: u64,
    pub arrival: SimTime,
    pub input_len: u64,
    pub output_len: u64,
    pub generated: u64,
    pub phase: Phase,
    pub class: SloClass,
    /// Shared-prefix block path carried over from the trace (empty for
    /// prefix-free workloads).
    pub prefix: Vec<u64>,
    /// Prompt tokens whose KV was found in the placed instance's prefix
    /// cache at assignment time. Shortens the modelled prefill *duration*
    /// only — KV capacity accounting still charges the full prompt, so a
    /// cache hit never admits a request the instance could not hold.
    pub cached_tokens: u64,
}

impl ActiveRequest {
    pub fn new(id: u64, arrival: SimTime, input_len: u64, output_len: u64) -> ActiveRequest {
        ActiveRequest {
            id,
            arrival,
            input_len,
            output_len,
            generated: 0,
            phase: Phase::Queued,
            class: SloClass::Interactive,
            prefix: Vec::new(),
            cached_tokens: 0,
        }
    }

    /// Builder: tag the request with an SLO class.
    pub fn with_class(mut self, class: SloClass) -> ActiveRequest {
        self.class = class;
        self
    }

    /// Builder: attach the trace's shared-prefix block path.
    pub fn with_prefix(mut self, prefix: Vec<u64>) -> ActiveRequest {
        self.prefix = prefix;
        self
    }

    /// Current context length (input + generated tokens).
    pub fn context_len(&self) -> u64 {
        self.input_len + self.generated
    }

    /// KV tokens this request will occupy at completion.
    pub fn final_len(&self) -> u64 {
        self.input_len + self.output_len
    }

    pub fn done(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Is this a "long" request relative to a TP1 instance's max sequence?
    pub fn is_long(&self, tp1_max_seq: u64) -> bool {
        self.final_len() > tp1_max_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_math() {
        let mut r = ActiveRequest::new(1, SimTime::ZERO, 100, 10);
        assert_eq!(r.context_len(), 100);
        assert_eq!(r.final_len(), 110);
        assert!(!r.done());
        r.generated = 10;
        assert!(r.done());
        assert_eq!(r.context_len(), 110);
    }

    #[test]
    fn long_classification() {
        let r = ActiveRequest::new(1, SimTime::ZERO, 50_000, 256);
        assert!(r.is_long(3750));
        let s = ActiveRequest::new(2, SimTime::ZERO, 1000, 100);
        assert!(!s.is_long(3750));
    }
}
