//! Request-routing policies: the transformation-aware Gyges scheduler
//! (Algorithms 1 & 2) and the Round-Robin / Least-Load-First baselines of
//! §6.2.4.
//!
//! Hot-path contract (see PERF.md): routing a request must not allocate on
//! the `Route::Assign` path of a warm cluster, and must not scan the live
//! instance table. The per-host candidate sets the policies consult come
//! from [`HostIndex`], and the least-load picks plus the RR rotation ring
//! come from [`LoadIndex`] — both maintained incrementally by
//! [`crate::coordinator::ClusterSim`] at every mutation that changes an
//! instance's topology or `load()` inputs. The policies reuse internal
//! scratch buffers instead of collecting fresh `Vec`s per request, and
//! every indexed decision is byte-identical to the scanning fallback
//! (`tp1: None, load: None` views), which stays available for tests and
//! the scan-baseline bench.

use super::instance::Instance;
use super::request::ActiveRequest;
use crate::config::ClusterConfig;
use crate::sim::clock::SimTime;
use crate::sim::EngineModel;

/// Incrementally-maintained index of the cluster topology: which live,
/// non-transforming TP1 instances sit on each host (the merge candidates
/// of Algorithm 1), plus the count of live TP>1 instances.
///
/// [`HostIndex::note`] is the single update entry point: call it with an
/// instance after any change to its `retired` / `degree` / `transforming`
/// state and the index converges to the truth. Per-host candidate lists
/// are kept sorted by instance id so consumers see the same deterministic
/// order a full rescan would produce.
#[derive(Clone, Debug, Default)]
pub struct HostIndex {
    /// Per host: ids of live, non-transforming TP1 instances, ascending.
    per_host: Vec<Vec<usize>>,
    /// Per instance id: currently present in its host's candidate list?
    mergeable: Vec<bool>,
    /// Per instance id: currently counted as a live TP>1 instance?
    high: Vec<bool>,
    /// Count of live TP>1 instances.
    high_live: usize,
}

impl HostIndex {
    pub fn new(hosts: usize) -> HostIndex {
        HostIndex { per_host: vec![Vec::new(); hosts], ..HostIndex::default() }
    }

    /// Index an existing instance table from scratch.
    pub fn build(instances: &[Instance], hosts: usize) -> HostIndex {
        let mut idx = HostIndex::new(hosts);
        for inst in instances {
            idx.note(inst);
        }
        idx
    }

    /// Reconcile the index with `inst`'s current state.
    pub fn note(&mut self, inst: &Instance) {
        if inst.id >= self.mergeable.len() {
            self.mergeable.resize(inst.id + 1, false);
            self.high.resize(inst.id + 1, false);
        }
        if inst.host >= self.per_host.len() {
            self.per_host.resize_with(inst.host + 1, Vec::new);
        }
        let m = !inst.retired && inst.degree == 1 && inst.transforming.is_none();
        if m != self.mergeable[inst.id] {
            self.mergeable[inst.id] = m;
            let list = &mut self.per_host[inst.host];
            if m {
                let pos = list.partition_point(|&x| x < inst.id);
                list.insert(pos, inst.id);
            } else if let Ok(pos) = list.binary_search(&inst.id) {
                list.remove(pos);
            }
        }
        let h = !inst.retired && inst.degree > 1;
        if h != self.high[inst.id] {
            self.high[inst.id] = h;
            if h {
                self.high_live += 1;
            } else {
                self.high_live -= 1;
            }
        }
    }

    /// Mergeable TP1 instance ids on `host`, ascending.
    pub fn mergeable_on(&self, host: usize) -> &[usize] {
        self.per_host.get(host).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn count(&self, host: usize) -> usize {
        self.per_host.get(host).map(|v| v.len()).unwrap_or(0)
    }

    pub fn hosts(&self) -> usize {
        self.per_host.len()
    }

    /// Any live TP>1 instance in the cluster?
    pub fn has_high_tp(&self) -> bool {
        self.high_live > 0
    }

    /// Recompute from scratch and compare (debug builds; test hook).
    pub fn debug_verify(&self, instances: &[Instance]) {
        #[cfg(debug_assertions)]
        {
            let rebuilt = HostIndex::build(instances, self.per_host.len());
            assert_eq!(
                rebuilt.per_host, self.per_host,
                "host index diverged from the instance table"
            );
            assert_eq!(rebuilt.high_live, self.high_live, "high-TP count diverged");
        }
        #[cfg(not(debug_assertions))]
        let _ = instances;
    }
}

/// Penalty [`GygesPolicy`] adds to a TP>1 instance's load when scoring it
/// for a *short* request (Algorithm 2 "reduces the request rate to these
/// instances to facilitate scaling down"). Shared by the scanning scorer
/// and the [`LoadIndex`] fast path so both produce identical decisions.
/// Chosen so `HIGH_TP_SHORT_PENALTY * LOAD_QUANT` is an exact integer in
/// f64 (`0.75 * 64 = 48`): a high-TP instance's score level is then its
/// load level shifted by a whole number of buckets.
pub const HIGH_TP_SHORT_PENALTY: f64 = 0.75;

/// Load-bucket quantum: loads are bucketed at `floor(load * LOAD_QUANT)`.
/// A power of two, so `load * LOAD_QUANT` is computed exactly in f64.
const LOAD_QUANT: f64 = 64.0;

/// `HIGH_TP_SHORT_PENALTY * LOAD_QUANT`, exact.
const PENALTY_LEVELS: usize = 48;

/// Loads at or above `MAX_LOAD_BUCKET / LOAD_QUANT` (4.0 — only reachable
/// through over-committed hand-built test states) collapse into one
/// overflow bucket; members there are compared exactly like any others.
const MAX_LOAD_BUCKET: usize = 256;
const NUM_LOAD_BUCKETS: usize = MAX_LOAD_BUCKET + 1;

/// Membership record of one instance inside the [`LoadIndex`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct LoadSlot {
    /// Index into `LoadIndex::classes`, or `u32::MAX` when absent
    /// (retired / never seen).
    class: u32,
    bucket: u32,
}

const NO_SLOT: LoadSlot = LoadSlot { class: u32::MAX, bucket: 0 };

/// All live instances of one TP degree, bucketed by quantized load.
#[derive(Clone, Debug)]
struct LoadClass {
    degree: u64,
    /// `NUM_LOAD_BUCKETS` id lists, each ascending.
    buckets: Vec<Vec<usize>>,
    /// Total members across all buckets.
    len: usize,
    /// Highest occupied bucket index (0 when empty): the query loops stop
    /// here instead of probing every quantization level, so a request
    /// that fits nothing costs O(occupied levels), not O(all levels).
    top: usize,
}

/// Incrementally-maintained load index: every live instance, grouped by TP
/// degree and bucketed by quantized `load()`, plus the ascending live-id
/// ring Round-Robin rotates over. [`LoadIndex::note`] is the single update
/// entry point — [`crate::coordinator::ClusterSim`] calls it after every
/// mutation that changes an instance's `retired`/`degree` state or its
/// `load()` inputs (admit, prefill completion, decode finishes, merge,
/// split, retirement), so the least-load queries below run in
/// O(buckets + candidates examined) instead of O(live instances).
///
/// Decision equivalence with a full scan is exact, not approximate: a
/// candidate's bucket level never exceeds `floor(score * LOAD_QUANT)`
/// (levels are derived from the same f64 `load()` the scan compares, and
/// the high-TP penalty shifts levels by the integer `PENALTY_LEVELS`), so
/// scanning levels until the level passes the current best score's bucket
/// examines every candidate that could beat *or tie* the best, and the
/// exact `(score, id)` comparison below resolves ties the way a first-win
/// ascending-id scan does. `prop_routing_decisions_are_sound` and the
/// mutation-sequence property test enforce this, and `ClusterSim::run`
/// re-verifies the index against a from-scratch rebuild in debug builds.
#[derive(Clone, Debug, Default)]
pub struct LoadIndex {
    classes: Vec<LoadClass>,
    /// Per degree: index into `classes`, `u32::MAX` when unseen.
    class_by_degree: Vec<u32>,
    /// Per instance id: current membership.
    slots: Vec<LoadSlot>,
    /// Ascending ids of live (non-retired) instances — the RR ring.
    live: Vec<usize>,
}

impl LoadIndex {
    /// Index an existing instance table from scratch.
    pub fn build(instances: &[Instance], engine: &EngineModel) -> LoadIndex {
        let mut idx = LoadIndex::default();
        for inst in instances {
            idx.note(inst, engine);
        }
        idx
    }

    fn bucket_for(load: f64) -> usize {
        // f64→usize casts saturate, so degenerate loads stay in range.
        ((load * LOAD_QUANT).floor() as usize).min(MAX_LOAD_BUCKET)
    }

    fn class_for(&mut self, degree: u64) -> u32 {
        let d = degree as usize;
        if d >= self.class_by_degree.len() {
            self.class_by_degree.resize(d + 1, u32::MAX);
        }
        if self.class_by_degree[d] == u32::MAX {
            self.class_by_degree[d] = self.classes.len() as u32;
            self.classes.push(LoadClass {
                degree,
                buckets: vec![Vec::new(); NUM_LOAD_BUCKETS],
                len: 0,
                top: 0,
            });
        }
        self.class_by_degree[d]
    }

    /// Reconcile the index with `inst`'s current state. O(log candidates)
    /// plus an O(candidates) shift when the bucket membership changes; a
    /// no-op when neither the degree class, the load bucket, nor liveness
    /// changed (e.g. a `transforming` toggle — queries read that flag off
    /// the instance directly).
    pub fn note(&mut self, inst: &Instance, engine: &EngineModel) {
        if inst.id >= self.slots.len() {
            self.slots.resize(inst.id + 1, NO_SLOT);
        }
        let new = if inst.retired {
            NO_SLOT
        } else {
            LoadSlot {
                class: self.class_for(inst.degree),
                bucket: Self::bucket_for(inst.load(engine)) as u32,
            }
        };
        let old = self.slots[inst.id];
        if old == new {
            return;
        }
        if old != NO_SLOT {
            let class = &mut self.classes[old.class as usize];
            let list = &mut class.buckets[old.bucket as usize];
            if let Ok(pos) = list.binary_search(&inst.id) {
                list.remove(pos);
                class.len -= 1;
                // Walk the high-water mark down past drained buckets
                // (amortised: paid for by the insertions that raised it).
                while class.top > 0 && class.buckets[class.top].is_empty() {
                    class.top -= 1;
                }
            }
        }
        if new != NO_SLOT {
            let class = &mut self.classes[new.class as usize];
            let b = new.bucket as usize;
            let list = &mut class.buckets[b];
            let pos = list.partition_point(|&x| x < inst.id);
            list.insert(pos, inst.id);
            class.len += 1;
            if b > class.top {
                class.top = b;
            }
        }
        if (old == NO_SLOT) != (new == NO_SLOT) {
            if new != NO_SLOT {
                let pos = self.live.partition_point(|&x| x < inst.id);
                self.live.insert(pos, inst.id);
            } else if let Ok(pos) = self.live.binary_search(&inst.id) {
                self.live.remove(pos);
            }
        }
        self.slots[inst.id] = new;
    }

    /// Ascending ids of live instances — exactly what a
    /// `view.live().map(|i| i.id)` scan would collect.
    pub fn live_ids(&self) -> &[usize] {
        &self.live
    }

    /// Short-request pick: the `(score, id)`-minimal live instance that
    /// fits `req`, where `score = load + HIGH_TP_SHORT_PENALTY·[degree>1]`,
    /// skipping transforming TP1 instances and over-cap reserved ones —
    /// byte-identical to [`GygesPolicy::route_short`]'s scan.
    pub fn pick_short(
        &self,
        instances: &[Instance],
        engine: &EngineModel,
        req: &ActiveRequest,
        reserved: &[usize],
        reserve_cap: f64,
    ) -> Option<usize> {
        // Only levels up to the highest occupied bucket (plus the high-TP
        // penalty shift) can hold candidates; a request that fits nothing
        // therefore stops at the occupancy high-water mark instead of
        // probing every quantization level.
        let Some(max_level) = self
            .classes
            .iter()
            .filter(|c| c.len > 0)
            .map(|c| c.top + if c.degree > 1 { PENALTY_LEVELS } else { 0 })
            .max()
        else {
            return None;
        };
        let mut best: Option<(f64, usize)> = None;
        for level in 0..=max_level {
            if let Some((score, _)) = best {
                if level > (score * LOAD_QUANT).floor() as usize {
                    break;
                }
            }
            for class in &self.classes {
                if class.len == 0 {
                    continue;
                }
                let pen = if class.degree > 1 { PENALTY_LEVELS } else { 0 };
                let Some(b) = level.checked_sub(pen) else { continue };
                if b > class.top {
                    continue;
                }
                for &id in &class.buckets[b] {
                    let inst = &instances[id];
                    if inst.transforming.is_some() && inst.degree == 1 {
                        continue;
                    }
                    if !inst.fits(engine, req) {
                        continue;
                    }
                    let l = inst.load(engine);
                    if l > reserve_cap && reserved.contains(&id) {
                        continue;
                    }
                    let score = l + if inst.degree > 1 { HIGH_TP_SHORT_PENALTY } else { 0.0 };
                    let better = match best {
                        None => true,
                        Some((bs, bid)) => score < bs || (score == bs && id < bid),
                    };
                    if better {
                        best = Some((score, id));
                    }
                }
            }
        }
        best.map(|(_, id)| id)
    }

    /// Long-request pick: the `(load, id)`-minimal live TP>1 instance that
    /// fits `req` and is not transforming — byte-identical to the
    /// higher-TP preference scan in [`GygesPolicy::route`].
    pub fn pick_long(
        &self,
        instances: &[Instance],
        engine: &EngineModel,
        req: &ActiveRequest,
    ) -> Option<usize> {
        let Some(max_level) = self
            .classes
            .iter()
            .filter(|c| c.degree > 1 && c.len > 0)
            .map(|c| c.top)
            .max()
        else {
            return None;
        };
        let mut best: Option<(f64, usize)> = None;
        for level in 0..=max_level {
            if let Some((load, _)) = best {
                if level > (load * LOAD_QUANT).floor() as usize {
                    break;
                }
            }
            for class in &self.classes {
                if class.degree <= 1 || class.len == 0 || level > class.top {
                    continue;
                }
                for &id in &class.buckets[level] {
                    let inst = &instances[id];
                    if inst.transforming.is_some() || !inst.fits(engine, req) {
                        continue;
                    }
                    let l = inst.load(engine);
                    let better = match best {
                        None => true,
                        Some((bl, bid)) => l < bl || (l == bl && id < bid),
                    };
                    if better {
                        best = Some((l, id));
                    }
                }
            }
        }
        best.map(|(_, id)| id)
    }

    /// Recompute from scratch and compare (debug builds; test hook).
    pub fn debug_verify(&self, instances: &[Instance], engine: &EngineModel) {
        #[cfg(debug_assertions)]
        {
            let rebuilt = LoadIndex::build(instances, engine);
            assert_eq!(rebuilt.live, self.live, "load-index live ring diverged");
            let flatten = |idx: &LoadIndex| {
                let mut m = std::collections::BTreeMap::new();
                for class in &idx.classes {
                    for (b, list) in class.buckets.iter().enumerate() {
                        if !list.is_empty() {
                            m.insert((class.degree, b), list.clone());
                        }
                    }
                }
                m
            };
            assert_eq!(
                flatten(&rebuilt),
                flatten(self),
                "load-index buckets diverged from the instance table"
            );
            for class in &self.classes {
                let total: usize = class.buckets.iter().map(Vec::len).sum();
                assert_eq!(total, class.len, "load-index class len drifted");
                let highest = class.buckets.iter().rposition(|b| !b.is_empty()).unwrap_or(0);
                assert_eq!(highest, class.top, "load-index class top drifted");
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = (instances, engine);
    }
}

/// Immutable view of the cluster a policy routes against.
pub struct ClusterView<'a> {
    pub instances: &'a [Instance],
    pub engine: &'a EngineModel,
    pub cfg: &'a ClusterConfig,
    pub now: SimTime,
    /// Incremental merge-candidate index. `None` falls back to scanning
    /// `instances` (tests and ad-hoc views); the simulator always supplies
    /// it, keeping routing allocation-free.
    pub tp1: Option<&'a HostIndex>,
    /// Incremental load index (least-load picks + RR live ring). `None`
    /// falls back to scanning `instances`; the simulator supplies it
    /// unless `ClusterSim::disable_routing_index` was called (scan
    /// baseline for benches and the equivalence tests).
    pub load: Option<&'a LoadIndex>,
    /// Per-host failure mask (crashed / KV-migration link down). `None`
    /// when no fault plan is armed — the unfaulted fast path. Both the
    /// indexed and scanning merge-candidate paths consult the same mask,
    /// so no transformation ever targets a degraded host and decision
    /// equivalence carries over under faults.
    pub blocked_hosts: Option<&'a [bool]>,
    /// Per-instance prefix-cache model. `None` when the cache is not
    /// armed (every pre-cache composition) — cache-aware score plugins
    /// treat absence as a universal miss, so a `None` view routes
    /// exactly like the pre-cache scheduler.
    pub cache: Option<&'a crate::cache::ClusterCache>,
}

impl<'a> ClusterView<'a> {
    /// Live (non-retired) instances — the assignment-candidate source
    /// every pipeline stage iterates. Assignment candidates deliberately
    /// include instances on degraded hosts: a crashed host has no live
    /// instances to list, while a host whose KV-migration link is down
    /// still *serves* (only transformations are barred) — that mask
    /// applies to the merge-candidate accessors below.
    pub fn live(&self) -> impl Iterator<Item = &Instance> {
        self.instances.iter().filter(|i| !i.retired)
    }

    /// Alias of [`Self::live`] under the pipeline's vocabulary: the
    /// candidate source a [`crate::coordinator::pipeline`] composition
    /// filters and scores.
    pub fn candidates(&self) -> impl Iterator<Item = &Instance> {
        self.live()
    }

    fn is_mergeable(i: &Instance) -> bool {
        i.degree == 1 && i.transforming.is_none()
    }

    /// Is `host` degraded (crashed or its KV-migration link down)?
    pub fn host_blocked(&self, host: usize) -> bool {
        self.blocked_hosts.is_some_and(|b| b.get(host).copied().unwrap_or(false))
    }

    /// Number of host slots merge-candidate iteration covers (the index
    /// may have grown past `cfg.hosts` as instances appeared).
    fn num_hosts(&self) -> usize {
        match self.tp1 {
            Some(idx) => idx.hosts(),
            None => {
                let seen = self.instances.iter().map(|i| i.host + 1).max().unwrap_or(0);
                self.cfg.hosts.max(seen)
            }
        }
    }

    /// Count of mergeable TP1 instances on `host`, `0` when the host is
    /// degraded. This is the ONE blocked-host-aware merge-candidate
    /// accessor — both the indexed and scanning paths of every merge
    /// query below go through it, so no plugin or policy can consult a
    /// candidate count that bypasses the failure mask.
    pub fn merge_count(&self, host: usize) -> usize {
        if self.host_blocked(host) {
            return 0;
        }
        match self.tp1 {
            Some(idx) => idx.count(host),
            None => self.live().filter(|i| i.host == host && Self::is_mergeable(i)).count(),
        }
    }

    /// Any live TP>1 instance?
    pub fn has_high_tp(&self) -> bool {
        match self.tp1 {
            Some(idx) => idx.has_high_tp(),
            None => self.live().any(|i| i.degree > 1),
        }
    }

    /// Fill `out` with the live TP1-degree instance ids on `host`,
    /// ascending, without allocating (beyond `out`'s retained capacity).
    pub fn tp1_on_host_into(&self, host: usize, out: &mut Vec<usize>) {
        out.clear();
        if self.host_blocked(host) {
            return; // no merge candidates on a degraded host
        }
        match self.tp1 {
            Some(idx) => out.extend_from_slice(idx.mergeable_on(host)),
            None => out.extend(
                self.live().filter(|i| i.host == host && Self::is_mergeable(i)).map(|i| i.id),
            ),
        }
    }

    /// Live TP1-degree instances on `host` (allocating convenience).
    pub fn tp1_on_host(&self, host: usize) -> Vec<usize> {
        let mut v = Vec::new();
        self.tp1_on_host_into(host, &mut v);
        v
    }

    /// Host with the most mergeable TP1 instances, requiring at least `n`
    /// (ties resolve to the lowest host id; degraded hosts excluded via
    /// [`Self::merge_count`]). Allocation-free on the indexed path.
    pub fn best_merge_host(&self, n: usize) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (count, host)
        for host in 0..self.num_hosts() {
            let count = self.merge_count(host);
            if best.map(|(c, _)| count > c).unwrap_or(true) {
                best = Some((count, host));
            }
        }
        match best {
            Some((count, host)) if count >= n => Some(host),
            _ => None,
        }
    }

    /// Hosts ordered by count of mergeable TP1 instances (desc; ties
    /// ascend by host id), degraded hosts excluded. Allocates — prefer
    /// [`Self::best_merge_host`].
    pub fn hosts_by_tp1(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = (0..self.num_hosts())
            .map(|h| (h, self.merge_count(h)))
            .filter(|&(_, c)| c > 0)
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }
}

/// A routing decision.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Route {
    /// Serve on this existing instance.
    Assign(usize),
    /// Merge `members` (same host, TP1) into one instance of degree
    /// `to_tp`, then serve there.
    ScaleUp { members: Vec<usize>, to_tp: u64 },
    /// No capacity right now; retry later.
    Defer,
    /// Shed the request at the decision stage (deadline-aware admission
    /// control, `-admit` policies): counted as dropped, never retried.
    Drop,
    /// Requeue queued batch-class prefills from `victim` until the
    /// request fits there, then assign it (`-slo` policies' interactive
    /// lane). The simulator resolves this against exact pending state
    /// into `Assign(victim)` — or `Defer` when even a full eviction of
    /// the evictable batch work would not make room.
    Preempt { victim: usize },
}

/// A routing policy.
pub trait RoutePolicy: Send {
    fn name(&self) -> &'static str;
    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route;
    /// Should `inst` scale down now? (Algorithm 2; baselines use the same
    /// safety conditions so comparisons isolate the *routing* behaviour.)
    fn should_scale_down(&mut self, inst: &Instance, view: &ClusterView<'_>) -> bool {
        default_scale_down(inst, view)
    }
    /// Does this policy want class-separated backlog lanes (interactive
    /// entries retried before batch entries in every drain pass)?
    fn wants_slo_lanes(&self) -> bool {
        false
    }
    /// The policy's persistent decision state, for snapshots. Scratch
    /// buffers are excluded — only what a future `route` /
    /// `should_scale_down` call can observe.
    fn snapshot_state(&self) -> PolicyState;
}

/// Serializable routing-policy state (snapshot schema v1): which policy
/// is installed plus every field a future decision can depend on.
/// Restoring through [`PolicyState::restore`] reproduces decisions
/// byte-identically — scratch buffers never affect decisions and are
/// rebuilt empty.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyState {
    Gyges {
        reserved: Vec<usize>,
        reserve_cap: f64,
        last_long_seen: Option<SimTime>,
        long_hold_s: f64,
    },
    RoundRobin { cursor: usize },
    LeastLoad,
    /// A composed pipeline policy (schema v4; `cache` added in v5): the
    /// stage flags plus the base policy's own state. `base` is always
    /// one of the plain variants above — plain pipeline policies
    /// snapshot *as* those variants directly, so pre-pipeline snapshots
    /// stay byte-identical and restore transparently.
    Pipeline { cache: bool, slo: bool, admit: bool, base: Box<PolicyState> },
}

impl PolicyState {
    /// Rebuild the boxed policy this state describes. Every state —
    /// including the legacy-kind plain variants — restores to a
    /// [`PipelinePolicy`](super::pipeline::PipelinePolicy) composition,
    /// which is decision-identical to the legacy implementations
    /// (property-tested in lockstep).
    pub fn restore(&self) -> Box<dyn RoutePolicy> {
        Box::new(super::pipeline::PipelinePolicy::from_state(self))
    }
}

/// Algorithm 2's safety conditions: TP>1, no long request in flight, load
/// under threshold, dwell time elapsed, not already transforming.
pub fn default_scale_down(inst: &Instance, view: &ClusterView<'_>) -> bool {
    if inst.degree <= 1 || inst.transforming.is_some() || inst.retired {
        return false;
    }
    // Failure awareness: a split re-shards KV across the host's GPUs —
    // never start one while the host is degraded or its link is down.
    if view.host_blocked(inst.host) {
        return false;
    }
    // Scale-down decomposes all the way back to TP1 ("the TP4 instance can
    // be elastically decomposed into four TP1 instances", §1) — every
    // in-flight request must fit a TP1 instance.
    let lower = 1;
    if inst.has_long_req(view.engine, lower) {
        return false;
    }
    if inst.load(view.engine) >= view.cfg.scale_down_threshold {
        return false;
    }
    let dwell = view.now.since(inst.last_transform).as_secs_f64();
    dwell >= view.cfg.min_dwell_s
}

/// Pick the TP degree needed to serve `req` (smallest allowed degree whose
/// max-seq covers the request).
pub fn needed_tp(req: &ActiveRequest, view: &ClusterView<'_>) -> Option<u64> {
    view.cfg
        .tp_choices
        .iter()
        .copied()
        .find(|&tp| view.engine.max_seq(tp) >= req.final_len())
}

/// Select `n` mergeable TP1 instances on one host into `out`, preferring
/// the host with the most candidates, then the least-loaded instances.
/// Returns false (and clears `out`) when no host has `n` candidates.
/// Allocation-free given retained `out` capacity (the candidate list is at
/// most `gpus_per_host` long, below the stable sort's allocation cutover).
pub fn pick_merge_group_into(view: &ClusterView<'_>, n: usize, out: &mut Vec<usize>) -> bool {
    let Some(host) = view.best_merge_host(n) else {
        out.clear();
        return false;
    };
    view.tp1_on_host_into(host, out);
    out.sort_by(|&a, &b| {
        let la = view.instances[a].load(view.engine);
        let lb = view.instances[b].load(view.engine);
        // total_cmp would order -0.0 < +0.0 and could reshuffle proven-identical groups
        // gyges-lint: allow(D06) loads are finite by construction, so partial_cmp is total here
        la.partial_cmp(&lb).unwrap()
    });
    out.truncate(n);
    true
}

/// Allocating convenience wrapper over [`pick_merge_group_into`].
pub fn pick_merge_group(view: &ClusterView<'_>, n: usize) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    pick_merge_group_into(view, n, &mut out).then_some(out)
}

// ---------------------------------------------------------------------
// Legacy policy implementations
//
// These are the original hand-rolled `RoutePolicy` impls the pipeline
// compositions in `super::pipeline` re-express. Production builds route
// exclusively through the pipeline; the legacy structs are kept behind
// `cfg(any(test, feature = "legacy-policies"))` purely as the lockstep
// reference the equivalence property tests and the CI
// `policy-pipeline-verify` byte-comparison drive.
// ---------------------------------------------------------------------

/// The transformation-aware scheduler (legacy reference impl).
#[cfg(any(test, feature = "legacy-policies"))]
pub struct GygesPolicy {
    /// Instances currently reserved as scale-up headroom: the scheduler
    /// keeps their load low so a transformation cannot OOM
    /// (`check_reserve` in Algorithm 1). Small; linear scans beat set
    /// lookups and the buffer is reused across requests.
    pub reserved: Vec<usize>,
    /// Load cap applied to reserved instances for short traffic.
    pub reserve_cap: f64,
    /// Most recent long-request arrival the scheduler has seen. Scale-down
    /// is held off while long traffic is active ("when consecutive long
    /// requests occur, the scheduler prioritizes instances already
    /// operating in higher TP configurations to minimize the number of
    /// required transformations", §5) — this is the anti-oscillation
    /// hysteresis that Challenge-3 calls for.
    pub last_long_seen: Option<SimTime>,
    /// How long after the last long request a TP>1 instance is retained.
    pub long_hold_s: f64,
    /// Reused candidate buffer for reserve computation.
    scratch: Vec<usize>,
}

#[cfg(any(test, feature = "legacy-policies"))]
impl Default for GygesPolicy {
    fn default() -> Self {
        GygesPolicy {
            reserved: Vec::new(),
            reserve_cap: 0.55,
            last_long_seen: None,
            long_hold_s: 45.0,
            scratch: Vec::new(),
        }
    }
}

#[cfg(any(test, feature = "legacy-policies"))]
impl GygesPolicy {
    /// Policy with a custom anti-oscillation hold (ablation A3, sweep
    /// jobs with a `gyges_hold` override).
    pub fn with_long_hold(hold_s: f64) -> GygesPolicy {
        GygesPolicy { long_hold_s: hold_s, ..GygesPolicy::default() }
    }

    /// Recompute the reserve (`update_reserve` in Algorithm 2): if no
    /// TP>1 instance exists, reserve the least-loaded mergeable TP1 group;
    /// otherwise no reserve is needed.
    fn update_reserve(&mut self, view: &ClusterView<'_>) {
        self.reserved.clear();
        if view.has_high_tp() {
            return;
        }
        let n = (view.cfg.max_tp() as usize).min(view.cfg.gpus_per_host);
        if pick_merge_group_into(view, n, &mut self.scratch) {
            self.reserved.extend_from_slice(&self.scratch);
            // Ascending-id order, matching the ordered set this used to be
            // (scale-up member selection draws from the front).
            self.reserved.sort_unstable();
        }
    }
}

#[cfg(any(test, feature = "legacy-policies"))]
impl RoutePolicy for GygesPolicy {
    fn name(&self) -> &'static str {
        "gyges"
    }

    fn should_scale_down(&mut self, inst: &Instance, view: &ClusterView<'_>) -> bool {
        // Hysteresis: while long traffic is (recently) active, keep the
        // high-TP instance so follow-up longs reuse it instead of forcing
        // fresh transformations (Figure 13's behaviour).
        if let Some(t) = self.last_long_seen {
            if view.now.since(t).as_secs_f64() < self.long_hold_s {
                return false;
            }
        }
        default_scale_down(inst, view)
    }

    fn snapshot_state(&self) -> PolicyState {
        PolicyState::Gyges {
            reserved: self.reserved.clone(),
            reserve_cap: self.reserve_cap,
            last_long_seen: self.last_long_seen,
            long_hold_s: self.long_hold_s,
        }
    }

    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        self.update_reserve(view);
        let tp1_max = view.engine.max_seq(1);
        let long = req.is_long(tp1_max);
        if long {
            self.last_long_seen = Some(view.now);
        }

        if long {
            // Prefer instances already operating at higher TP (minimises
            // transformations; Figure 13's key behaviour). Indexed picks
            // examine only the lowest occupied load buckets; the scan
            // fallback walks every live instance.
            let picked = match view.load {
                Some(idx) => idx.pick_long(view.instances, view.engine, req),
                None => {
                    let mut best: Option<(usize, f64)> = None;
                    for i in view.live().filter(|i| i.degree > 1) {
                        if i.fits(view.engine, req) && i.transforming.is_none() {
                            let l = i.load(view.engine);
                            if best.map(|(_, bl)| l < bl).unwrap_or(true) {
                                best = Some((i.id, l));
                            }
                        }
                    }
                    best.map(|(id, _)| id)
                }
            };
            if let Some(id) = picked {
                return Route::Assign(id);
            }
            // Scale up: need a degree that can hold the request.
            let Some(to_tp) = needed_tp(req, view) else {
                return Route::Defer;
            };
            if to_tp == 1 {
                // Long by classification but fits TP1 (edge case).
                return self.route_short(req, view);
            }
            // Prefer the reserved group (it was kept under-loaded).
            let reserved: Vec<usize> = self
                .reserved
                .iter()
                .copied()
                .filter(|&id| {
                    let i = &view.instances[id];
                    !i.retired && i.degree == 1 && i.transforming.is_none()
                })
                .collect();
            if reserved.len() >= to_tp as usize {
                let mut members = reserved;
                members.truncate(to_tp as usize);
                return Route::ScaleUp { members, to_tp };
            }
            if let Some(members) = pick_merge_group(view, to_tp as usize) {
                return Route::ScaleUp { members, to_tp };
            }
            return Route::Defer;
        }

        self.route_short(req, view)
    }
}

#[cfg(any(test, feature = "legacy-policies"))]
impl GygesPolicy {
    /// Short-request routing: least expected load among fitting instances,
    /// skipping reserved instances above the reserve cap and de-preferring
    /// TP>1 instances (Algorithm 2 "reduces the request rate to these
    /// instances to facilitate scaling down"). With a [`LoadIndex`] the
    /// pick is O(buckets + candidates); the scan fallback walks every
    /// live instance and must stay decision-identical (property-tested).
    fn route_short(&self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        if let Some(idx) = view.load {
            return match idx.pick_short(
                view.instances,
                view.engine,
                req,
                &self.reserved,
                self.reserve_cap,
            ) {
                Some(id) => Route::Assign(id),
                None => Route::Defer,
            };
        }
        let mut best: Option<(usize, f64)> = None;
        for i in view.live() {
            if i.transforming.is_some() && i.degree == 1 {
                continue;
            }
            if !i.fits(view.engine, req) {
                continue;
            }
            let l = i.load(view.engine);
            if self.reserved.contains(&i.id) && l > self.reserve_cap {
                continue; // keep scale-up headroom (check_reserve)
            }
            // Penalise high-TP instances so they drain and scale down.
            let score = l + if i.degree > 1 { HIGH_TP_SHORT_PENALTY } else { 0.0 };
            if best.map(|(_, bs)| score < bs).unwrap_or(true) {
                best = Some((i.id, score));
            }
        }
        match best {
            Some((id, _)) => Route::Assign(id),
            None => Route::Defer,
        }
    }
}

// ---------------------------------------------------------------------
// Baseline policies
// ---------------------------------------------------------------------

/// Round-Robin: next instance in rotation; if it cannot hold the request,
/// it "collaborates with neighbouring instances" to scale up (§6.2.4).
/// Legacy reference impl — see the module note above.
#[cfg(any(test, feature = "legacy-policies"))]
#[derive(Default)]
pub struct RoundRobinPolicy {
    cursor: usize,
    /// Reused live-id buffer.
    scratch: Vec<usize>,
}

#[cfg(any(test, feature = "legacy-policies"))]
impl RoutePolicy for RoundRobinPolicy {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        // The maintained live-id ring makes RR O(candidates visited) with
        // no per-request rebuild; its content and order match the scan.
        if let Some(idx) = view.load {
            return self.route_over(req, view, idx.live_ids());
        }
        // Scan fallback: reuse the live-id buffer across calls
        // (allocation-free once warm); take it out of `self` so the
        // cursor stays mutable.
        let mut live = std::mem::take(&mut self.scratch);
        live.clear();
        live.extend(view.live().map(|i| i.id));
        let route = self.route_over(req, view, &live);
        self.scratch = live;
        route
    }

    fn snapshot_state(&self) -> PolicyState {
        PolicyState::RoundRobin { cursor: self.cursor }
    }
}

#[cfg(any(test, feature = "legacy-policies"))]
impl RoundRobinPolicy {
    fn route_over(&mut self, req: &ActiveRequest, view: &ClusterView<'_>, live: &[usize]) -> Route {
        if live.is_empty() {
            return Route::Defer;
        }
        // Rotate over live instances. RR is oblivious to sequence-length
        // limits (§6.2.4): when its pick cannot hold the sequence, that
        // instance "collaborates with neighbouring instances" to scale up
        // (Figure 13's extra transformation). Instances that merely lack
        // KV room right now are skipped (ordinary replica rotation).
        for k in 0..live.len() {
            let id = live[(self.cursor + k) % live.len()];
            let inst = &view.instances[id];
            if inst.transforming.is_some() {
                continue;
            }
            if inst.fits(view.engine, req) {
                self.cursor = (self.cursor + k + 1) % live.len();
                return Route::Assign(id);
            }
            if req.final_len() > inst.max_seq(view.engine) {
                // The pick can't ever hold this sequence → merge. (The
                // merge pools the members' memory, so capacity follows.)
                self.cursor = (self.cursor + k + 1) % live.len();
                return scale_up_fallback(req, view);
            }
            // capacity-only failure → rotate on
        }
        Route::Defer
    }
}

/// Least-Load-First: route to the least-loaded fitting instance.
///
/// Deliberately unindexed: LLF compares *absolute* committed tokens, which
/// the load-quantized [`LoadIndex`] does not order across degree classes
/// (capacity differs per degree). It is a baseline policy, not a hot path.
/// Legacy reference impl — see the module note above.
#[cfg(any(test, feature = "legacy-policies"))]
pub struct LeastLoadPolicy;

#[cfg(any(test, feature = "legacy-policies"))]
impl RoutePolicy for LeastLoadPolicy {
    fn name(&self) -> &'static str {
        "llf"
    }

    fn route(&mut self, req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
        // Least ABSOLUTE load first — LLF is oblivious to sequence-length
        // limits and to capacity fractions: an empty TP1 beats a TP4 that
        // is serving one long request, so a new long request lands on the
        // TP1 and forces a scale-up (Figure 13). `committed_tokens` is the
        // absolute committed-KV count a capacity-fraction-oblivious
        // scheduler compares.
        let mut best: Option<(usize, u64)> = None;
        for i in view.live() {
            if i.transforming.is_some() {
                continue;
            }
            let l = i.committed_tokens();
            if best.map(|(_, bl)| l < bl).unwrap_or(true) {
                best = Some((i.id, l));
            }
        }
        let Some((id, _)) = best else {
            return Route::Defer;
        };
        let inst = &view.instances[id];
        if inst.fits(view.engine, req) {
            return Route::Assign(id);
        }
        if req.final_len() > inst.max_seq(view.engine) {
            return scale_up_fallback(req, view);
        }
        // Its pick is full: fall back to any fitting instance, else defer.
        for i in view.live() {
            if i.transforming.is_none() && i.fits(view.engine, req) {
                return Route::Assign(i.id);
            }
        }
        Route::Defer
    }

    fn snapshot_state(&self) -> PolicyState {
        PolicyState::LeastLoad
    }
}

/// Shared baseline fallback: form the smallest adequate TP group from the
/// least-loaded TP1 instances, without any reservation logic.
pub fn scale_up_fallback(req: &ActiveRequest, view: &ClusterView<'_>) -> Route {
    let Some(to_tp) = needed_tp(req, view) else {
        return Route::Defer;
    };
    if to_tp <= 1 {
        return Route::Defer; // fits TP1 but nothing had room → wait
    }
    match pick_merge_group(view, to_tp as usize) {
        Some(members) => Route::ScaleUp { members, to_tp },
        None => Route::Defer,
    }
}

/// Process-global switch routing plain policies through the LEGACY
/// implementations instead of the pipeline compositions — the lockstep
/// half of the CI `policy-pipeline-verify` byte comparison
/// (`gyges --legacy-routing ...` under the `legacy-policies` feature).
/// Set once at process start, before any simulation is built; parallel
/// test threads must NOT toggle it (use
/// [`crate::coordinator::ClusterSim::with_boxed_policy`] instead).
#[cfg(any(test, feature = "legacy-policies"))]
static LEGACY_ROUTING: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(any(test, feature = "legacy-policies"))]
pub fn set_legacy_routing(on: bool) {
    LEGACY_ROUTING.store(on, std::sync::atomic::Ordering::SeqCst);
}

#[cfg(any(test, feature = "legacy-policies"))]
pub fn legacy_routing() -> bool {
    LEGACY_ROUTING.load(std::sync::atomic::Ordering::SeqCst)
}

/// Construct a policy from its [`crate::config::PolicyId`] (accepts a
/// bare base [`crate::config::Policy`] too). Every policy is a
/// [`super::pipeline::PipelinePolicy`] composition; composed stage flags
/// (`slo`/`admit`) only exist there. Under `--legacy-routing` (test /
/// `legacy-policies` builds), *plain* ids build the legacy reference
/// impls instead, for lockstep byte comparison.
pub fn make_policy(policy: impl Into<crate::config::PolicyId>) -> Box<dyn RoutePolicy> {
    let id = policy.into();
    #[cfg(any(test, feature = "legacy-policies"))]
    if legacy_routing() && id.plain() {
        return match id.base {
            crate::config::Policy::Gyges => Box::new(GygesPolicy::default()),
            crate::config::Policy::RoundRobin => Box::new(RoundRobinPolicy::default()),
            crate::config::Policy::LeastLoadFirst => Box::new(LeastLoadPolicy),
        };
    }
    Box::new(super::pipeline::PipelinePolicy::new(id))
}

/// [`make_policy`] with a Gyges anti-oscillation hold override (ablation
/// A3 / sweep `gyges_hold` jobs). The caller guarantees `id.base` is
/// Gyges; the same legacy-routing switch applies so held jobs stay
/// lockstep-comparable.
pub fn make_policy_with_hold(
    id: crate::config::PolicyId,
    hold_s: f64,
) -> Box<dyn RoutePolicy> {
    debug_assert_eq!(id.base, crate::config::Policy::Gyges);
    #[cfg(any(test, feature = "legacy-policies"))]
    if legacy_routing() && id.plain() {
        return Box::new(GygesPolicy::with_long_hold(hold_s));
    }
    Box::new(super::pipeline::PipelinePolicy::with_long_hold(id, hold_s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ModelConfig};
    use std::collections::BTreeSet;

    fn setup() -> (ClusterConfig, EngineModel, Vec<Instance>) {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
        let instances: Vec<Instance> =
            (0..8).map(|i| Instance::new(i, 0, vec![i], 1)).collect();
        (cfg, engine, instances)
    }

    fn view<'a>(
        cfg: &'a ClusterConfig,
        engine: &'a EngineModel,
        instances: &'a [Instance],
    ) -> ClusterView<'a> {
        ClusterView {
            instances,
            engine,
            cfg,
            now: SimTime::from_secs_f64(100.0),
            tp1: None,
            load: None,
            blocked_hosts: None,
            cache: None,
        }
    }

    fn long_req() -> ActiveRequest {
        ActiveRequest::new(1, SimTime::ZERO, 50_000, 256)
    }

    fn short_req(id: u64) -> ActiveRequest {
        ActiveRequest::new(id, SimTime::ZERO, 1000, 100)
    }

    fn decoding(mut req: ActiveRequest) -> ActiveRequest {
        req.phase = super::super::request::Phase::Decode;
        req
    }

    #[test]
    fn gyges_long_request_triggers_scale_up_when_no_tp4() {
        let (cfg, engine, instances) = setup();
        let mut p = GygesPolicy::default();
        let r = p.route(&long_req(), &view(&cfg, &engine, &instances));
        match r {
            Route::ScaleUp { members, to_tp } => {
                assert_eq!(to_tp, 4);
                assert_eq!(members.len(), 4);
            }
            other => panic!("expected scale-up, got {other:?}"),
        }
    }

    #[test]
    fn gyges_prefers_existing_tp4_for_long_requests() {
        let (cfg, engine, mut instances) = setup();
        // Replace 4 TP1s with one TP4 that is *more loaded* than the TP1s.
        for i in 0..4 {
            instances[i].retired = true;
        }
        let mut tp4 = Instance::new(8, 0, vec![0, 1, 2, 3], 4);
        tp4.enqueue_running(decoding(ActiveRequest::new(99, SimTime::ZERO, 40_000, 512)));
        instances.push(tp4);
        let mut p = GygesPolicy::default();
        let r = p.route(&long_req(), &view(&cfg, &engine, &instances));
        assert_eq!(r, Route::Assign(8), "must route to the existing TP4");
    }

    #[test]
    fn llf_picks_tp1_when_tp4_is_loaded() {
        // Figure 13: LLF sends the long request to a TP1 instance
        // (triggering another transformation) because TP4 is loaded.
        let (cfg, engine, mut instances) = setup();
        for i in 0..4 {
            instances[i].retired = true;
        }
        let mut tp4 = Instance::new(8, 0, vec![0, 1, 2, 3], 4);
        tp4.enqueue_running(decoding(ActiveRequest::new(99, SimTime::ZERO, 60_000, 512)));
        instances.push(tp4);
        let mut p = LeastLoadPolicy;
        let r = p.route(&long_req(), &view(&cfg, &engine, &instances));
        // TP4 is loaded (60K committed), TP1s are empty but can't fit 50K
        // → LLF falls back to scaling up fresh TP1s.
        match r {
            Route::ScaleUp { to_tp: 4, members } => assert_eq!(members.len(), 4),
            Route::Assign(8) => panic!("llf should not prefer the loaded TP4 here"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rr_rotates_over_short_requests() {
        let (cfg, engine, instances) = setup();
        let mut p = RoundRobinPolicy::default();
        let mut seen = BTreeSet::new();
        for k in 0..8 {
            match p.route(&short_req(k), &view(&cfg, &engine, &instances)) {
                Route::Assign(id) => {
                    seen.insert(id);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(seen.len(), 8, "RR must touch all instances");
    }

    #[test]
    fn gyges_short_avoids_high_tp_instances() {
        let (cfg, engine, mut instances) = setup();
        for i in 0..4 {
            instances[i].retired = true;
        }
        instances.push(Instance::new(8, 0, vec![0, 1, 2, 3], 4));
        let mut p = GygesPolicy::default();
        match p.route(&short_req(1), &view(&cfg, &engine, &instances)) {
            Route::Assign(id) => assert_ne!(id, 8, "short must go to a TP1"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn scale_down_conditions() {
        let (cfg, engine, _) = setup();
        let mut inst = Instance::new(0, 0, vec![0, 1, 2, 3], 4);
        inst.last_transform = SimTime::ZERO;
        let instances = vec![];
        let v = ClusterView {
            instances: &instances,
            engine: &engine,
            cfg: &cfg,
            now: SimTime::from_secs_f64(100.0),
            tp1: None,
            load: None,
            blocked_hosts: None,
            cache: None,
        };
        assert!(default_scale_down(&inst, &v), "idle TP4 should scale down");
        // long request blocks it
        inst.enqueue_running(decoding(ActiveRequest::new(1, SimTime::ZERO, 30_000, 256)));
        assert!(!default_scale_down(&inst, &v));
        let _ = inst.take_work();
        // dwell not elapsed
        inst.last_transform = SimTime::from_secs_f64(99.0);
        assert!(!default_scale_down(&inst, &v));
    }

    #[test]
    fn needed_tp_classification() {
        let (cfg, engine, instances) = setup();
        let v = view(&cfg, &engine, &instances);
        assert_eq!(needed_tp(&short_req(1), &v), Some(1));
        assert_eq!(needed_tp(&long_req(), &v), Some(4));
        let mid = ActiveRequest::new(3, SimTime::ZERO, 20_000, 256);
        assert_eq!(needed_tp(&mid, &v), Some(2));
        let huge = ActiveRequest::new(4, SimTime::ZERO, 200_000, 256);
        assert_eq!(needed_tp(&huge, &v), None);
    }

    #[test]
    fn host_index_matches_scan() {
        let (cfg, engine, mut instances) = setup();
        // Retire one, transform one, raise one to TP2.
        instances[2].retired = true;
        instances[5].degree = 2;
        let mut idx = HostIndex::build(&instances, 1);
        idx.debug_verify(&instances);
        assert_eq!(idx.mergeable_on(0), &[0, 1, 3, 4, 6, 7]);
        assert!(idx.has_high_tp());
        // Flip states and re-note: the index reconciles incrementally.
        instances[2].retired = false;
        idx.note(&instances[2]);
        instances[5].degree = 1;
        idx.note(&instances[5]);
        idx.debug_verify(&instances);
        assert_eq!(idx.mergeable_on(0), &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(!idx.has_high_tp());
        // The indexed view agrees with the scanning fallback.
        let with_idx = ClusterView {
            instances: &instances,
            engine: &engine,
            cfg: &cfg,
            now: SimTime::ZERO,
            tp1: Some(&idx),
            load: None,
            blocked_hosts: None,
            cache: None,
        };
        let scanned = view(&cfg, &engine, &instances);
        assert_eq!(with_idx.tp1_on_host(0), scanned.tp1_on_host(0));
        assert_eq!(with_idx.best_merge_host(4), scanned.best_merge_host(4));
        assert_eq!(with_idx.hosts_by_tp1(), scanned.hosts_by_tp1());
    }

    #[test]
    fn pick_merge_group_reuses_buffer_and_prefers_least_loaded() {
        let (cfg, engine, mut instances) = setup();
        // Load instance 0 so it is not picked for a group of 4.
        for k in 0..3 {
            instances[0].admit(ActiveRequest::new(100 + k, SimTime::ZERO, 3000, 200));
        }
        let idx = HostIndex::build(&instances, 1);
        let v = ClusterView {
            instances: &instances,
            engine: &engine,
            cfg: &cfg,
            now: SimTime::ZERO,
            tp1: Some(&idx),
            load: None,
            blocked_hosts: None,
            cache: None,
        };
        let mut buf = Vec::new();
        assert!(pick_merge_group_into(&v, 4, &mut buf));
        assert_eq!(buf.len(), 4);
        assert!(!buf.contains(&0), "the loaded instance must be skipped");
        // Same answer as the allocating wrapper.
        assert_eq!(pick_merge_group(&v, 4), Some(buf.clone()));
        // Asking for more candidates than exist fails cleanly.
        assert!(!pick_merge_group_into(&v, 9, &mut buf));
        assert!(buf.is_empty());
    }

    #[test]
    fn load_index_tracks_admits_retires_and_degrees() {
        let (_, engine, mut instances) = setup();
        let mut idx = LoadIndex::build(&instances, &engine);
        assert_eq!(idx.live_ids(), &[0, 1, 2, 3, 4, 5, 6, 7]);
        // Load one instance, retire one, raise one to TP2.
        for k in 0..3 {
            instances[1].admit(ActiveRequest::new(100 + k, SimTime::ZERO, 3000, 200));
        }
        idx.note(&instances[1], &engine);
        instances[4].retired = true;
        idx.note(&instances[4], &engine);
        instances[6].degree = 2;
        idx.note(&instances[6], &engine);
        idx.debug_verify(&instances, &engine);
        assert_eq!(idx.live_ids(), &[0, 1, 2, 3, 5, 6, 7]);
        // Un-retire and re-note: the index reconciles incrementally.
        instances[4].retired = false;
        idx.note(&instances[4], &engine);
        idx.debug_verify(&instances, &engine);
        assert_eq!(idx.live_ids(), &[0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn indexed_routes_match_scanning_routes() {
        let (cfg, engine, mut instances) = setup();
        // A mixed state: loads, a TP4, a transforming TP1, a retired TP1.
        for k in 0..4 {
            instances[0].admit(ActiveRequest::new(200 + k, SimTime::ZERO, 2500, 150));
        }
        instances[1].admit(ActiveRequest::new(300, SimTime::ZERO, 1200, 80));
        for i in 4..8 {
            instances[i].retired = true;
        }
        let mut tp4 = Instance::new(8, 0, vec![4, 5, 6, 7], 4);
        tp4.enqueue_running(decoding(ActiveRequest::new(400, SimTime::ZERO, 20_000, 256)));
        instances.push(tp4);
        let hidx = HostIndex::build(&instances, 1);
        let lidx = LoadIndex::build(&instances, &engine);
        let indexed = ClusterView {
            instances: &instances,
            engine: &engine,
            cfg: &cfg,
            now: SimTime::from_secs_f64(100.0),
            tp1: Some(&hidx),
            load: Some(&lidx),
            blocked_hosts: None,
            cache: None,
        };
        let scanning = view(&cfg, &engine, &instances);
        for req in [short_req(1), long_req(), ActiveRequest::new(3, SimTime::ZERO, 20_000, 64)] {
            let mut pi = GygesPolicy::default();
            let mut ps = GygesPolicy::default();
            assert_eq!(
                pi.route(&req, &indexed),
                ps.route(&req, &scanning),
                "gyges diverged on {} tokens",
                req.final_len()
            );
        }
        let mut rr_i = RoundRobinPolicy::default();
        let mut rr_s = RoundRobinPolicy::default();
        for k in 0..6 {
            assert_eq!(
                rr_i.route(&short_req(k), &indexed),
                rr_s.route(&short_req(k), &scanning),
                "rr diverged at step {k}"
            );
        }
    }
}
